"""State-space / linear-recurrence mixers: Mamba (jamba) and RWKV6 (Finch).

Both expose a single entry point operating on [B, T, d] with an optional
recurrent state: train/prefill run the scan over T and return the final
state; decode calls the same function with T == 1 and the carried state.
The sequential `lax.scan` here is the reference path; the chunked Pallas
kernel (`repro.kernels.rwkv6_scan`) implements the throughput path.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# Mamba (S6, selective SSM)  [arXiv:2312.00752]
# ----------------------------------------------------------------------------

class MambaState(NamedTuple):
    conv: jax.Array    # [B, d_conv - 1, d_inner]
    ssm: jax.Array     # [B, d_inner, d_state] float32


def mamba_init_state(batch: int, d_inner: int, d_state: int, d_conv: int,
                     dtype=jnp.bfloat16) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, d_conv - 1, d_inner), dtype),
        ssm=jnp.zeros((batch, d_inner, d_state), jnp.float32),
    )


def mamba_mixer(
    x: jax.Array,                       # [B, T, d]
    p: Dict[str, jax.Array],
    *,
    d_state: int,
    d_conv: int,
    state: Optional[MambaState] = None,
    valid: Optional[jax.Array] = None,       # [B, T] bool (padding at the end)
    chunk_lens: Optional[jax.Array] = None,  # [B] valid-row counts
) -> Tuple[jax.Array, MambaState]:
    B, T, d = x.shape
    xz = x @ p["in_proj"]                               # [B, T, 2*di]
    di = xz.shape[-1] // 2
    xi, z = xz[..., :di], xz[..., di:]
    if valid is not None:
        xi = jnp.where(valid[..., None], xi, 0)

    # causal depthwise conv over time
    conv_in = xi if state is None else jnp.concatenate([state.conv.astype(xi.dtype), xi], axis=1)
    pad = d_conv - 1 if state is None else 0
    conv_in_p = jnp.pad(conv_in, ((0, 0), (pad, 0), (0, 0)))
    # windows: y_t = sum_j w_j * x_{t-(K-1)+j}
    yc = jnp.zeros((B, T, di), jnp.float32)
    for j in range(d_conv):
        yc = yc + conv_in_p[:, j : j + T, :].astype(jnp.float32) * \
            p["conv_w"][j].astype(jnp.float32)
    xi = jax.nn.silu(yc + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    if chunk_lens is None:
        new_conv = jax.lax.dynamic_slice_in_dim(
            conv_in_p, conv_in_p.shape[1] - (d_conv - 1), d_conv - 1, axis=1)
    else:
        # last (d_conv-1) *valid* rows of [old_state | chunk]
        idx = chunk_lens[:, None] + jnp.arange(d_conv - 1)[None, :]  # [B, K-1]
        new_conv = jnp.take_along_axis(conv_in_p, idx[..., None], axis=1)

    # input-dependent SSM parameters
    dtr = p["dt_proj"].shape[0]
    dbc = xi @ p["x_proj"]                              # [B, T, dtr + 2*ds]
    dt_raw = dbc[..., :dtr]
    Bm = dbc[..., dtr : dtr + d_state].astype(jnp.float32)
    Cm = dbc[..., dtr + d_state :].astype(jnp.float32)
    dt = jax.nn.softplus((dt_raw @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [B, T, di]
    if valid is not None:
        dt = dt * valid[..., None]      # frozen state on padded rows (dA=1)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # [di, ds]
    dA = jnp.exp(dt[..., None] * A)                     # [B, T, di, ds]
    dBx = dt[..., None] * Bm[:, :, None, :] * xi.astype(jnp.float32)[..., None]

    h0 = (jnp.zeros((B, di, d_state), jnp.float32) if state is None
          else state.ssm)

    chunk = _mamba_chunk()
    if valid is None and chunk > 0 and T % chunk == 0 and T > chunk:
        # blocked selective scan: associative scan inside each chunk (the
        # S4/S6 parallel form), one state hand-off per chunk — removes the
        # per-token HBM round-trip of the [B, di, ds] state (§Perf).
        L = chunk
        NC = T // L

        def chunk_body(h, inp):
            dA_c, dBx_c, C_c = inp                      # [B, L, di, ds] / ...

            def comb(e1, e2):
                a1, b1 = e1
                a2, b2 = e2
                return a1 * a2, a2 * b1 + b2

            A_cum, b_cum = jax.lax.associative_scan(
                comb, (dA_c, dBx_c), axis=1)
            hs = A_cum * h[:, None] + b_cum             # [B, L, di, ds]
            y_c = jnp.einsum("blds,bls->bld", hs, C_c)
            return hs[:, -1], y_c

        xs = (jnp.stack(jnp.split(dA, NC, axis=1)),
              jnp.stack(jnp.split(dBx, NC, axis=1)),
              jnp.stack(jnp.split(Cm, NC, axis=1)))
        hT, ys = jax.lax.scan(chunk_body, h0, xs)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, T, di)
    else:
        def step(h, inp):
            dA_t, dBx_t, C_t = inp
            h = dA_t * h + dBx_t                        # [B, di, ds]
            y = jnp.einsum("bds,bs->bd", h, C_t)
            return h, y

        hT, ys = jax.lax.scan(step, h0,
                              (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0),
                               jnp.moveaxis(Cm, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1)                      # [B, T, di]
    y = y + xi.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]
    return out, MambaState(conv=new_conv.astype(x.dtype), ssm=hT)


# ----------------------------------------------------------------------------
# RWKV6 (Finch) — data-dependent decay linear attention  [arXiv:2404.05892]
# ----------------------------------------------------------------------------

class RWKVState(NamedTuple):
    tm_x: jax.Array    # [B, d]   last input of the time-mix block
    cm_x: jax.Array    # [B, d]   last input of the channel-mix block
    wkv: jax.Array     # [B, H, dk, dv] float32


def rwkv_init_state(batch: int, d: int, heads: int, head_dim: int,
                    dtype=jnp.bfloat16) -> RWKVState:
    return RWKVState(
        tm_x=jnp.zeros((batch, d), dtype),
        cm_x=jnp.zeros((batch, d), dtype),
        wkv=jnp.zeros((batch, heads, head_dim, head_dim), jnp.float32),
    )


def _token_shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """sx_t = x_{t-1} - x_t with x_{-1} = last (carried across chunks)."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev - x


def _mamba_chunk() -> int:
    """Selective-scan chunk length (0 = per-token lax.scan) — §Perf knob."""
    import os
    return int(os.environ.get("REPRO_MAMBA_CHUNK", "256"))


def _rwkv_chunk() -> int:
    """WKV chunk length for the blocked scan (0 = per-token lax.scan).
    §Perf knob: the per-token scan round-trips the [B,H,D,D] state through
    HBM every token."""
    import os
    return int(os.environ.get("REPRO_RWKV_CHUNK", "64"))


def _wkv_chunked(r, k, v, w, u, S0, chunk: int):
    """Blocked WKV6: o_t = r_t·(S_{t-1} + diag(u) k_t vᵀ_t), S_t = w_t⊙S + kvᵀ.

    Within a chunk (P = inclusive decay product): two MXU matmuls + causal
    mask; across chunks: one rank-D state update per chunk.  Identical math
    to kernels/rwkv6_scan.py (which is its TPU Pallas form)."""
    B, T, H, D = r.shape
    L = chunk
    NC = T // L

    def f32(x):
        return x.astype(jnp.float32)

    rc = f32(r).reshape(B, NC, L, H, D)
    kc = f32(k).reshape(B, NC, L, H, D)
    vc = f32(v).reshape(B, NC, L, H, D)
    logw = jnp.log(jnp.maximum(f32(w), 1e-30)).reshape(B, NC, L, H, D)
    logP = jnp.cumsum(logw, axis=2)                      # inclusive
    P_prev = jnp.exp(logP - logw)                        # exclusive prefix
    kQ = kc * jnp.exp(-logP)
    rP = rc * P_prev
    kS = kc * jnp.exp(logP[:, :, -1:, :, :] - logP)      # k * P_L / P
    P_last = jnp.exp(logP[:, :, -1])                     # [B, NC, H, D]

    A = jnp.einsum("bnlhd,bnmhd->bnhlm", rP, kQ)
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)        # strictly causal
    A = jnp.where(mask[None, None, None], A, 0.0)
    diag = jnp.sum(rc * (f32(u)[None, None, None] * kc), axis=-1)
    intra = jnp.einsum("bnhlm,bnmhd->bnlhd", A, vc) + diag[..., None] * vc

    def body2(S, inp):
        rP_n, kS_n, v_n, Pl_n = inp
        o_inter = jnp.einsum("blhd,bhdv->blhv", rP_n, S)
        S_new = Pl_n[..., None] * S + jnp.einsum("blhd,blhv->bhdv", kS_n, v_n)
        return S_new, o_inter

    xs = (jnp.moveaxis(rP, 1, 0), jnp.moveaxis(kS, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(P_last, 1, 0))
    ST, o_inter = jax.lax.scan(body2, S0, xs)
    o = intra + jnp.moveaxis(o_inter, 0, 1)              # [B, NC, L, H, D]
    return o.reshape(B, T, H, D), ST


def _last_valid_row(x: jax.Array, last: jax.Array,
                    chunk_lens: Optional[jax.Array]) -> jax.Array:
    """New shift-state: x[chunk_len-1] per sequence (old state if len==0)."""
    if chunk_lens is None:
        return x[:, -1, :]
    idx = jnp.maximum(chunk_lens - 1, 0)
    picked = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0, :]
    return jnp.where((chunk_lens > 0)[:, None], picked, last)


def rwkv_time_mix(
    x: jax.Array,                       # [B, T, d]
    p: Dict[str, jax.Array],
    *,
    head_dim: int,
    state: Optional[RWKVState] = None,
    valid: Optional[jax.Array] = None,
    chunk_lens: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out, new_tm_x, new_wkv)."""
    B, T, d = x.shape
    H = d // head_dim
    last = (jnp.zeros((B, d), x.dtype) if state is None else state.tm_x)
    sx = _token_shift(x, last)
    xr = x + sx * p["mu_r"]
    xk = x + sx * p["mu_k"]
    xv = x + sx * p["mu_v"]
    xg = x + sx * p["mu_g"]
    xw = x + sx * p["mu_w"]

    r = (xr @ p["w_r"]).reshape(B, T, H, head_dim)
    k = (xk @ p["w_k"]).reshape(B, T, H, head_dim)
    v = (xv @ p["w_v"]).reshape(B, T, H, head_dim)
    g = jax.nn.silu(xg @ p["w_g"])
    # data-dependent decay (the Finch contribution): w = exp(-exp(w0 + lora))
    dd = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]    # [B, T, d]
    logw = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32)
                             + dd.astype(jnp.float32), -20.0, 10.0))
    w = jnp.exp(logw).reshape(B, T, H, head_dim)         # decay in (0, 1)
    u = p["u"].reshape(H, head_dim).astype(jnp.float32)  # bonus for current token

    S0 = (jnp.zeros((B, H, head_dim, head_dim), jnp.float32) if state is None
          else state.wkv)
    valid_t = (jnp.ones((B, T), jnp.float32) if valid is None
               else valid.astype(jnp.float32))

    def step(S, inp):
        r_t, k_t, v_t, w_t, m_t = inp                    # [B, H, dk] / [B,H,dv]
        kv = k_t.astype(jnp.float32)[..., :, None] * \
            v_t.astype(jnp.float32)[..., None, :]        # [B, H, dk, dv]
        kv = kv * m_t[:, None, None, None]               # padded rows: no-op
        o = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                       S + u[None, :, :, None] * kv)
        w_eff = w_t.astype(jnp.float32) * m_t[:, None, None] + \
            (1.0 - m_t)[:, None, None]                   # decay=1 when padded
        S = w_eff[..., :, None] * S + kv
        return S, o

    chunk = _rwkv_chunk()
    if valid is None and chunk > 0 and T % chunk == 0 and T > chunk:
        # chunked linear recurrence (same math as kernels/rwkv6_scan.py):
        # turns T HBM-round-trip scan steps into T/chunk matmul blocks —
        # the memory-roofline fix measured in EXPERIMENTS.md §Perf.
        o, ST = _wkv_chunked(r, k, v, w, u, S0, chunk)
        o = o.reshape(B, T, d)
    else:
        ST, os = jax.lax.scan(
            step, S0,
            (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
             jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0),
             jnp.moveaxis(valid_t, 1, 0)))
        o = jnp.moveaxis(os, 0, 1).reshape(B, T, d)      # [B, T, d]
    # per-head group norm
    o = o.reshape(B, T, H, head_dim)
    mu = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(o - mu), axis=-1, keepdims=True)
    o = (o - mu) * jax.lax.rsqrt(var + 64e-5)
    o = (o.reshape(B, T, d) * p["ln_x_g"].astype(jnp.float32)).astype(x.dtype)
    out = (o * g.astype(x.dtype)) @ p["w_o"]
    return out, _last_valid_row(x, last, chunk_lens), ST


def rwkv_channel_mix(
    x: jax.Array,                       # [B, T, d]
    p: Dict[str, jax.Array],
    *,
    state: Optional[RWKVState] = None,
    chunk_lens: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    B, T, d = x.shape
    last = (jnp.zeros((B, d), x.dtype) if state is None else state.cm_x)
    sx = _token_shift(x, last)
    xk = x + sx * p["cm_mu_k"]
    xr = x + sx * p["cm_mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    out = jax.nn.sigmoid(xr @ p["cm_r"]) * (k @ p["cm_v"])
    return out, _last_valid_row(x, last, chunk_lens)


def rwkv_block(
    x: jax.Array,
    p: Dict[str, jax.Array],
    *,
    head_dim: int,
    norm_eps: float,
    state: Optional[RWKVState] = None,
    valid: Optional[jax.Array] = None,
    chunk_lens: Optional[jax.Array] = None,
) -> Tuple[jax.Array, RWKVState]:
    from repro.models.layers import layernorm

    h = layernorm(x, p["ln1_g"], p["ln1_b"], norm_eps)
    att, tm_x, wkv = rwkv_time_mix(h, p, head_dim=head_dim, state=state,
                                   valid=valid, chunk_lens=chunk_lens)
    if valid is not None:
        att = jnp.where(valid[..., None], att, 0)
    x = x + att
    h = layernorm(x, p["ln2_g"], p["ln2_b"], norm_eps)
    ffn, cm_x = rwkv_channel_mix(h, p, state=state, chunk_lens=chunk_lens)
    if valid is not None:
        ffn = jnp.where(valid[..., None], ffn, 0)
    x = x + ffn
    return x, RWKVState(tm_x=tm_x, cm_x=cm_x, wkv=wkv)
