"""Model definitions: unified heterogeneous transformer stack."""

from repro.models.transformer import (
    abstract_params,
    block_apply_train,
    cross_entropy,
    embed_apply,
    head_apply,
    init_params,
    model_param_defs,
    param_pspecs,
    param_shapes,
    stage_forward_train,
)
from repro.models.serve import (
    ServeDims,
    abstract_caches,
    abstract_meta,
    block_apply_serve,
    cache_pspecs,
    init_caches,
    meta_pspecs,
    stage_forward_serve,
    zero_meta,
)

__all__ = [
    "abstract_params", "block_apply_train", "cross_entropy", "embed_apply",
    "head_apply", "init_params", "model_param_defs", "param_pspecs",
    "param_shapes", "stage_forward_train",
    "ServeDims", "abstract_caches", "abstract_meta", "block_apply_serve",
    "cache_pspecs", "init_caches", "meta_pspecs", "stage_forward_serve",
    "zero_meta",
]
