"""Serve-mode model execution: one pipeline-stage forward over a micro-batch
of prefill chunks + decode rows, with paged KV / recurrent-state caches.

Layouts (per pipeline stage, per data replica — both mesh axes are manual
inside the serving tick):
  prefill payload  xp [Sp, C, d]    (whisper: [Sp, Te + C, d], enc slice first)
  decode payload   xd [Sd, 1, d]
  paged KV         [R, pages, page, 2, KH, hd]   (R = block repeat)
  MLA latent KV    [R, pages, page, klr + dr]
  mamba state      conv [R, slots, dc-1, di], ssm [R, slots, di, ds]
  rwkv state       tm_x/cm_x [R, slots, d], wkv [R, slots, H, hk, hv]
  whisper enc      enc_h [slots, Te, d]  (stage-local encoder hidden cache)

The static bucket sizes (Sp, C, Sd, pages, ...) come from `ServeDims`; Token
Throttling keeps the real token counts near the bucket so the padding — the
TPU form of a pipeline bubble — stays small (DESIGN.md §2).
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, BlockKind
from repro.models import attention as attn
from repro.models import ssm as ssm_lib
from repro.models import moe as moe_lib
from repro.models.layers import apply_mrope, apply_norm, apply_rope, mlp_apply, rmsnorm
from repro.models.transformer import _block_key, _heads

# Flash KV-block granularity (pages per gather step).  Read once at import —
# see `_pages_per_block` for why a live re-read is wrong.
PAGES_PER_BLOCK = int(os.environ.get("REPRO_PAGES_PER_BLOCK", "8"))

# KV-depth bucket divisors k -> depth step ⌈B/k⌉ (DESIGN.md §14).  "4,2,1"
# is the {⌈B/4⌉, ⌈B/2⌉, B} ladder; "1" disables depth bucketing.
DEPTH_DIVISORS: Tuple[int, ...] = tuple(
    int(x) for x in os.environ.get("REPRO_DEPTH_STEPS", "4,2,1").split(",")
    if x.strip())


@dataclass(frozen=True)
class ServeDims:
    """Static bucket sizes for one (arch, shape) serving cell, per replica."""

    Sp: int              # prefill sequences per tick (0 for decode-only cells)
    C: int               # prefill chunk bucket (tokens per prefill seq)
    Sd: int              # decode rows per tick
    pages: int           # KV pool size (pages) per replica, per layer
    page: int            # page size in tokens
    Bp: int              # max pages per prefill seq's block table
    Bd: int              # max pages per decode seq's block table
    slots: int           # recurrent-state / enc-cache sequence slots
    Te: int = 0          # whisper encoder bucket (0 for non-enc-dec)
    seq_shard: bool = False   # long-context: KV sequence sharded over `data`

    @property
    def prefill_width(self) -> int:
        return self.Te + self.C

    @property
    def rows(self) -> int:
        return self.Sp * self.prefill_width + self.Sd


def depth_steps(B: int, *, pages_per_block: Optional[int] = None,
                divisors: Optional[Sequence[int]] = None) -> Tuple[int, ...]:
    """Block-table depth buckets for a phase whose full table is `B` pages:
    {⌈B/k⌉ for k in `divisors`} rounded up to multiples of the flash gather
    granularity (`pages_per_block`), deduplicated, always including B.  A
    full width not divisible by `pages_per_block` gets no sub-buckets — the
    attention path requires the same divisibility at every width."""
    ppb = pages_per_block if pages_per_block is not None else _pages_per_block()
    if B <= 0 or ppb <= 0 or B % ppb != 0:
        return (B,)
    divisors = tuple(divisors) if divisors is not None else DEPTH_DIVISORS
    steps = {B}
    for k in divisors:
        if k > 0:
            need = -(-B // k)                       # ⌈B/k⌉ pages demanded
            steps.add(min(B, ppb * -(-need // ppb)))  # …rounded to blocks
    return tuple(sorted(steps))


def bucket_ladder(dims: ServeDims,
                  depth_divisors: Optional[Sequence[int]] = None
                  ) -> Tuple[ServeDims, ...]:
    """Fixed ladder of serve shapes for bucketed execution (DESIGN.md §12/§14).

    Three bucket dimensions, deduplicated: prefill-chunk buckets
    {0, ⌈C/4⌉, ⌈C/2⌉, C} × decode-row buckets {⌈Sd/4⌉, ⌈Sd/2⌉, Sd} × KV
    depth — the block-table widths Bp/Bd stepped per `depth_steps`.  One
    shared depth index scales both phases together (×len(steps) ladder
    growth, not the Bp×Bd cross product); a phase with no rows in an entry
    keeps its full table width, since its meta carries no live tables there.
    Every entry keeps the full `dims` cache geometry (pages/page/slots/Te
    untouched), so one KV pool, one parameter tree, and one carry buffer
    serve every program in the ladder.  The Sp=0 entries are the "0 prefill
    tokens" buckets; decode-only shapes keep C at its full value since the
    prefill payload has no rows there.  The fully-empty (Sp=0, Sd=0) shape
    is excluded — bubble ticks run in the smallest non-empty bucket.
    """
    def ceil_div(a: int, b: int) -> int:
        return -(-a // b)

    c_steps = sorted({max(1, ceil_div(dims.C, 4)),
                      max(1, ceil_div(dims.C, 2)), dims.C})
    d_steps = ([0] if dims.Sd == 0 else
               sorted({max(1, ceil_div(dims.Sd, 4)),
                       max(1, ceil_div(dims.Sd, 2)), dims.Sd}))
    bp_steps = depth_steps(dims.Bp, divisors=depth_divisors)
    bd_steps = depth_steps(dims.Bd, divisors=depth_divisors)
    n_depth = max(len(bp_steps), len(bd_steps))
    # shared depth index i = "fraction i of both phases"; the shorter
    # phase's list saturates at its full width
    depth_pairs = []
    for i in range(n_depth):
        pair = (bp_steps[min(i, len(bp_steps) - 1)],
                bd_steps[min(i, len(bd_steps) - 1)])
        if pair not in depth_pairs:
            depth_pairs.append(pair)
    ladder = []
    seen = set()
    for Sd_b in d_steps:
        variants = [(0, dims.C)]
        if dims.Sp > 0:
            variants += [(dims.Sp, c) for c in c_steps]
        for Sp_b, C_b in variants:
            for Bp_b, Bd_b in depth_pairs:
                bp = Bp_b if Sp_b > 0 else dims.Bp
                bd = Bd_b if Sd_b > 0 else dims.Bd
                key = (Sp_b, C_b, Sd_b, bp, bd)
                if key in seen or (Sp_b == 0 and Sd_b == 0):
                    continue
                seen.add(key)
                ladder.append(replace(dims, Sp=Sp_b, C=C_b, Sd=Sd_b,
                                      Bp=bp, Bd=bd))
    return tuple(ladder)


def select_bucket(ladder: Sequence[ServeDims], need_c: int, need_d: int,
                  need_bp: int = 0, need_bd: int = 0) -> ServeDims:
    """Smallest ladder entry covering a tick whose widest prefill chunk is
    `need_c` tokens, whose decode rows number `need_d`, and whose deepest
    prefill/decode block tables hold `need_bp`/`need_bd` live pages.
    Minimality is by padded row count (`rows`); ties break toward the
    narrower prefill bucket, the smaller decode bucket, then the shallower
    block tables.  Depth demands only bind for phases with rows (`need_c`
    resp. `need_d` nonzero): a phase with no rows reads no tables."""
    best: Optional[ServeDims] = None
    for b in ladder:
        covers = ((need_c == 0 or (b.Sp > 0 and b.C >= need_c
                                   and b.Bp >= need_bp))
                  and b.Sd >= need_d
                  and (need_d == 0 or b.Bd >= need_bd))
        if not covers:
            continue
        key = (b.rows, b.C, b.Sd, b.Bp, b.Bd)
        if best is None or key < (best.rows, best.C, best.Sd,
                                  best.Bp, best.Bd):
            best = b
    if best is None:
        raise ValueError(
            f"no bucket covers need_c={need_c}, need_d={need_d}, "
            f"need_bp={need_bp}, need_bd={need_bd} "
            f"(ladder max C={max(b.C for b in ladder)}, "
            f"Sd={max(b.Sd for b in ladder)}, "
            f"Bp={max(b.Bp for b in ladder)}, "
            f"Bd={max(b.Bd for b in ladder)})")
    return best


def _meta_field_defs(dims: ServeDims) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    Sp, C, Sd = dims.Sp, dims.C, dims.Sd
    return {
        "p_positions": ((Sp, C), jnp.int32),
        "p_chunk_lens": ((Sp,), jnp.int32),
        "p_context_lens": ((Sp,), jnp.int32),
        "p_block_tables": ((Sp, dims.Bp), jnp.int32),
        "p_slot_pages": ((Sp, C), jnp.int32),
        "p_slot_offsets": ((Sp, C), jnp.int32),
        "p_state_slots": ((Sp,), jnp.int32),
        "p_sample": ((Sp,), jnp.int32),        # 1 if chunk finishes prefill
        "d_positions": ((Sd,), jnp.int32),
        "d_context_lens": ((Sd,), jnp.int32),
        "d_block_tables": ((Sd, dims.Bd), jnp.int32),
        "d_slot_pages": ((Sd,), jnp.int32),
        "d_slot_offsets": ((Sd,), jnp.int32),
        "d_state_slots": ((Sd,), jnp.int32),
        "d_valid": ((Sd,), jnp.int32),
    }


def zero_meta(dims: ServeDims) -> Dict[str, jax.Array]:
    out = {}
    for k, (shape, dt) in _meta_field_defs(dims).items():
        fill = -1 if k in ("p_slot_pages", "d_slot_pages") else 0
        out[k] = jnp.full(shape, fill, dt)
    return out


def abstract_meta(dims: ServeDims, stages: int, stack: bool = True):
    return {
        k: jax.ShapeDtypeStruct(((stages,) + shape) if stack else shape, dt)
        for k, (shape, dt) in _meta_field_defs(dims).items()
    }


def meta_pspecs(dims: ServeDims):
    """stage dim manual; per-replica seq dims are sharded over `data`."""
    return {k: P("stage", "data") for k in _meta_field_defs(dims)}


# ----------------------------------------------------------------------------
# Cache construction
# ----------------------------------------------------------------------------

def block_cache_defs(cfg: ArchConfig, kind: BlockKind, dims: ServeDims,
                     repeat: int):
    """(shape, pspec) per cache array of one block group (no stage dim)."""
    R = repeat
    tp_heads = max(1, cfg.num_kv_heads)
    out: Dict[str, Tuple[Tuple[int, ...], P]] = {}
    if kind in (BlockKind.ATTN_MLP, BlockKind.ATTN_MOE, BlockKind.DEC_LAYER):
        out["kv"] = ((R, dims.pages, dims.page, 2, tp_heads, cfg.head_dim),
                     P(None, "data", None, None, "tensor", None))
    elif kind == BlockKind.MLA_MLP:
        out["kv"] = ((R, dims.pages, dims.page,
                      cfg.kv_lora_rank + cfg.qk_rope_dim),
                     P(None, "data", None, None))
    elif kind in (BlockKind.MAMBA_MLP, BlockKind.MAMBA_MOE):
        di = cfg.mamba_d_inner
        out["conv"] = ((R, dims.slots, cfg.mamba_d_conv - 1, di),
                       P(None, "data", None, "tensor"))
        # the selective-scan state carries in f32 (recurrence precision)
        out["ssm"] = ((R, dims.slots, di, cfg.mamba_d_state),
                      P(None, "data", "tensor", None))
    elif kind == BlockKind.RWKV:
        H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
        out["tm_x"] = ((R, dims.slots, cfg.d_model), P(None, "data", None))
        out["cm_x"] = ((R, dims.slots, cfg.d_model), P(None, "data", None))
        # the WKV state carries in f32 (recurrence precision)
        out["wkv"] = ((R, dims.slots, H, hd, hd),
                      P(None, "data", "tensor", None, None))
    if kind == BlockKind.ENC_LAYER:
        pass  # encoder layers are stateless
    return out


def cache_defs(cfg: ArchConfig, dims: ServeDims):
    """Full cache tree of (shape, pspec) with leading stage dim."""
    S = cfg.plan.pp
    tree: Dict[str, Any] = {}
    for i, bs in enumerate(cfg.pattern):
        defs = block_cache_defs(cfg, bs.kind, dims, bs.repeat)
        if defs:
            tree[_block_key(i, bs)] = {
                k: ((S,) + shape, P(*(("stage",) + tuple(spec))))
                for k, (shape, spec) in defs.items()
            }
    if cfg.is_encoder_decoder:
        tree["enc_h"] = {"h": ((S, dims.slots, dims.Te, cfg.d_model),
                               P("stage", "data", None, None))}
    return tree


def _isdef(x):
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[1], P)


F32_STATE_LEAVES = ("ssm", "wkv")    # recurrent states carry in f32


def cache_leaf_dtype(name: str, model_dtype) -> Any:
    return jnp.float32 if name in F32_STATE_LEAVES else model_dtype


def _map_caches_with_names(cfg, dims, fn):
    defs = cache_defs(cfg, dims)
    return {gk: {name: fn(name, leaf) for name, leaf in grp.items()}
            for gk, grp in defs.items()}


def init_caches(cfg: ArchConfig, dims: ServeDims, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    return _map_caches_with_names(
        cfg, dims,
        lambda name, leaf: jnp.zeros(leaf[0], cache_leaf_dtype(name, dtype)))


def abstract_caches(cfg: ArchConfig, dims: ServeDims, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    return _map_caches_with_names(
        cfg, dims,
        lambda name, leaf: jax.ShapeDtypeStruct(
            leaf[0], cache_leaf_dtype(name, dtype)))


def cache_pspecs(cfg: ArchConfig, dims: ServeDims):
    return jax.tree.map(lambda leaf: leaf[1], cache_defs(cfg, dims),
                        is_leaf=_isdef)


# ----------------------------------------------------------------------------
# Serve-mode attention helpers
# ----------------------------------------------------------------------------

def _qkv_rows(cfg, p, x, positions, prefix=""):
    """x [S, T, d], positions [S, T] -> q [S,T,H,hd], k/v [S,T,KH,hd]."""
    q = x @ p[f"{prefix}wq"]
    k = x @ p[f"{prefix}wk"]
    v = x @ p[f"{prefix}wv"]
    if cfg.qkv_bias and f"{prefix}bq" in p:
        q, k, v = q + p[f"{prefix}bq"], k + p[f"{prefix}bk"], v + p[f"{prefix}bv"]
    q = _heads(q, cfg.num_heads, cfg.head_dim)
    k = _heads(k, cfg.num_kv_heads, cfg.head_dim)
    v = _heads(v, cfg.num_kv_heads, cfg.head_dim)
    if cfg.mrope:
        pos3 = jnp.broadcast_to(positions, (3,) + positions.shape)
        q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _pages_per_block() -> int:
    """Flash KV-block granularity (pages per gather step) — §Perf knob.
    Read once at import (`PAGES_PER_BLOCK` below): this value participates
    in traced shape math, so a per-call env read would burn host time in
    the tick hot path and a mid-process change would silently split the
    jit cache."""
    return PAGES_PER_BLOCK


def _paged_self_attention(cfg, p, xs, cache, meta, dims: ServeDims,
                          is_prefill: bool, prefix=""):
    """Project, write pages, attend.  Returns (attn_out, new_cache)."""
    if is_prefill:
        positions = meta["p_positions"]
        valid = (jnp.arange(dims.C)[None, :] < meta["p_chunk_lens"][:, None])
        tables, ctx = meta["p_block_tables"], meta["p_context_lens"]
        pages, offs = meta["p_slot_pages"], meta["p_slot_offsets"]
    else:
        positions = meta["d_positions"][:, None]
        valid = (meta["d_valid"] > 0)[:, None]
        tables, ctx = meta["d_block_tables"], meta["d_context_lens"]
        pages, offs = meta["d_slot_pages"][:, None], meta["d_slot_offsets"][:, None]

    q, k, v = _qkv_rows(cfg, p, xs, positions, prefix)
    new_kv = jnp.stack([k, v], axis=2)                    # [S, T, 2, KH, hd]
    cache = attn.write_kv_pages(cache, new_kv, pages, offs, valid)
    merge_axis = "data" if (dims.seq_shard and not is_prefill) else None
    shard_info = None
    if merge_axis is not None:
        shard_info = (jax.lax.axis_index("data"), jax.lax.psum(1, "data"))
    o = attn.paged_attention(q, cache, tables, ctx, positions,
                             pages_per_block=_pages_per_block(),
                             merge_axis=merge_axis, shard_info=shard_info)
    o = o.reshape(o.shape[:-2] + (-1,)) @ p[f"{prefix}wo"]
    return o, cache


def _paged_mla_attention(cfg, p, xs, cache, meta, dims: ServeDims,
                         is_prefill: bool):
    S, T, _ = xs.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    klr = cfg.kv_lora_rank
    if is_prefill:
        positions = meta["p_positions"]
        valid = (jnp.arange(dims.C)[None, :] < meta["p_chunk_lens"][:, None])
        tables, ctx = meta["p_block_tables"], meta["p_context_lens"]
        pages, offs = meta["p_slot_pages"], meta["p_slot_offsets"]
    else:
        positions = meta["d_positions"][:, None]
        valid = (meta["d_valid"] > 0)[:, None]
        tables, ctx = meta["d_block_tables"], meta["d_context_lens"]
        pages, offs = meta["d_slot_pages"][:, None], meta["d_slot_offsets"][:, None]

    cq = rmsnorm(xs @ p["w_dq"], p["q_norm_g"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(S, T, H, dn + dr)
    q_rope = apply_rope(q[..., dn:], positions, cfg.rope_theta)
    q = jnp.concatenate([q[..., :dn], q_rope], axis=-1)
    ckv_full = xs @ p["w_dkv"]
    ckv = rmsnorm(ckv_full[..., :klr], p["kv_norm_g"], cfg.norm_eps)
    k_rope = apply_rope(ckv_full[..., None, klr:], positions,
                        cfg.rope_theta)[..., 0, :]
    lat = jnp.concatenate([ckv, k_rope], axis=-1)          # [S, T, klr+dr]
    cache = attn.write_kv_pages(cache, lat, pages, offs, valid)
    o = attn.paged_attention_mla(
        q, cache, p["w_ukv"], tables, ctx, positions,
        kv_lora_rank=klr, qk_nope_dim=dn, v_head_dim=dv,
        pages_per_block=_pages_per_block())
    return o.reshape(S, T, H * dv) @ p["wo"], cache


def _gathered_state_step(mixer_fn, xs, state_arrays, state_slots, chunk_lens):
    """Gather per-seq recurrent state, run the mixer, scatter back.

    state_arrays: dict name -> [slots, ...]; state_slots [S]; returns
    (out, new_state_arrays)."""
    gathered = {k: v[state_slots] for k, v in state_arrays.items()}
    out, new_state = mixer_fn(xs, gathered)
    updated = {}
    for k, v in state_arrays.items():
        upd = new_state[k]
        updated[k] = v.at[state_slots].set(upd, mode="drop")
    return out, updated

# ----------------------------------------------------------------------------
# Per-kind serve block application
# ----------------------------------------------------------------------------

def _mamba_serve(cfg, p, xs, caches, state_slots, chunk_lens):
    """xs [S, T, d]; caches {conv [slots, dc-1, di], ssm [slots, di, ds]}.
    chunk_lens masks padded rows (dt := 0 -> state frozen)."""
    S, T, _ = xs.shape
    valid = (jnp.arange(T)[None, :] < chunk_lens[:, None])

    def mixer(x, st):
        state = ssm_lib.MambaState(conv=st["conv"], ssm=st["ssm"])
        # mask padded rows by zeroing the input (dt(0)=softplus(bias) != 0, so
        # also freeze via masked dt below); simplest correct: zero input rows
        # and rebuild conv/ssm state from valid length.
        xm = jnp.where(valid[..., None], x, 0)
        out, new = ssm_lib.mamba_mixer(
            xm, p, d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv,
            state=state, valid=valid, chunk_lens=chunk_lens)
        return out, {"conv": new.conv, "ssm": new.ssm}

    out, updated = _gathered_state_step(mixer, xs, caches, state_slots,
                                        chunk_lens)
    return jnp.where(valid[..., None], out, 0), updated


def _rwkv_serve(cfg, p, xs, caches, state_slots, chunk_lens):
    S, T, _ = xs.shape
    valid = (jnp.arange(T)[None, :] < chunk_lens[:, None])

    def mixer(x, st):
        state = ssm_lib.RWKVState(tm_x=st["tm_x"], cm_x=st["cm_x"],
                                  wkv=st["wkv"])
        out, new = ssm_lib.rwkv_block(
            x, p, head_dim=cfg.rwkv_head_dim, norm_eps=cfg.norm_eps,
            state=state, valid=valid, chunk_lens=chunk_lens)
        return out, {"tm_x": new.tm_x, "cm_x": new.cm_x, "wkv": new.wkv}

    out, updated = _gathered_state_step(mixer, xs, caches, state_slots,
                                        chunk_lens)
    return jnp.where(valid[..., None], out, 0), updated


def block_apply_serve(cfg: ArchConfig, kind: BlockKind, p, xp, xd, cache,
                      meta, dims: ServeDims, enc_cache=None):
    """One block over the stage's micro-batch.

    xp [Sp, W, d] prefill payload (W = Te + C for whisper, C otherwise),
    xd [Sd, 1, d] decode rows.  Returns (xp, xd, new_cache, new_enc_cache)."""
    eps = cfg.norm_eps

    def norm(name, h):
        keys = {"g": p[f"{name}_g"]}
        if f"{name}_b" in p:
            keys["b"] = p[f"{name}_b"]
        return apply_norm(h, keys, cfg.norm, eps)

    new_cache = cache
    Sp, Sd = dims.Sp, dims.Sd
    has_p, has_d = Sp > 0, Sd > 0

    if kind == BlockKind.RWKV:
        # time-mix + channel-mix as one fused block (own norms inside)
        if has_p:
            yp, st = _rwkv_serve(cfg, p, xp,
                                 {k: cache[k] for k in ("tm_x", "cm_x", "wkv")},
                                 meta["p_state_slots"], meta["p_chunk_lens"])
            xp = yp
            new_cache = st
        if has_d:
            yd, st2 = _rwkv_serve(cfg, p, xd,
                                  {k: (new_cache if has_p else cache)[k]
                                   for k in ("tm_x", "cm_x", "wkv")},
                                  meta["d_state_slots"], meta["d_valid"])
            xd = yd
            new_cache = st2
        return xp, xd, new_cache, enc_cache

    if kind in (BlockKind.ENC_LAYER, BlockKind.DEC_LAYER):
        Te = dims.Te
        enc = xp[:, :Te] if has_p else None
        dec = xp[:, Te:] if has_p else None
        if kind == BlockKind.ENC_LAYER:
            if has_p:
                h = norm("ln1", enc)
                pos = jnp.broadcast_to(jnp.arange(Te), (Sp, Te))
                q, k, v = _qkv_rows(cfg, p, h, pos)
                o = attn.cross_attention(q, k, v)           # bidirectional
                enc = enc + o.reshape(Sp, Te, -1) @ p["wo"]
                h = norm("ln2", enc)
                enc = enc + mlp_apply(h, p, cfg.act)
                xp = jnp.concatenate([enc, dec], axis=1)
            return xp, xd, new_cache, enc_cache
        # DEC_LAYER: causal paged self-attn + cross-attn
        if has_p:
            h = norm("ln1", dec)
            o, new_cache = _paged_self_attention(
                cfg, p, h, cache["kv"], meta, dims, is_prefill=True)
            new_cache = {"kv": new_cache}
            dec = dec + o
            h = norm("ln3", dec)
            q = _heads(h @ p["x_wq"] + p.get("x_bq", 0.0), cfg.num_heads,
                       cfg.head_dim)
            k = _heads(enc @ p["x_wk"] + p.get("x_bk", 0.0),
                       cfg.num_kv_heads, cfg.head_dim)
            v = _heads(enc @ p["x_wv"] + p.get("x_bv", 0.0),
                       cfg.num_kv_heads, cfg.head_dim)
            o = attn.cross_attention(q, k, v)
            dec = dec + o.reshape(Sp, dims.C, -1) @ p["x_wo"]
            h = norm("ln2", dec)
            dec = dec + mlp_apply(h, p, cfg.act)
            xp = jnp.concatenate([enc, dec], axis=1)
        if has_d:
            kvc = new_cache["kv"] if isinstance(new_cache, dict) and "kv" in new_cache else cache["kv"]
            h = norm("ln1", xd)
            o, kvc = _paged_self_attention(cfg, p, h, kvc, meta, dims,
                                           is_prefill=False)
            new_cache = {"kv": kvc}
            xd = xd + o
            # cross-attention against the cached stage-local encoder hidden
            h = norm("ln3", xd)
            src = enc_cache[meta["d_state_slots"]]            # [Sd, Te, d]
            q = _heads(h @ p["x_wq"] + p.get("x_bq", 0.0), cfg.num_heads,
                       cfg.head_dim)
            k = _heads(src @ p["x_wk"] + p.get("x_bk", 0.0),
                       cfg.num_kv_heads, cfg.head_dim)
            v = _heads(src @ p["x_wv"] + p.get("x_bv", 0.0),
                       cfg.num_kv_heads, cfg.head_dim)
            o = attn.cross_attention(q, k, v)
            xd = xd + o.reshape(Sd, 1, -1) @ p["x_wo"]
            h = norm("ln2", xd)
            xd = xd + mlp_apply(h, p, cfg.act)
        return xp, xd, new_cache, enc_cache

    # ---- standard mixer + ffn blocks --------------------------------------
    if kind in (BlockKind.MAMBA_MLP, BlockKind.MAMBA_MOE):
        st_keys = ("conv", "ssm")
        if has_p:
            h = norm("ln1", xp)
            o, st = _mamba_serve(cfg, p, h, {k: cache[k] for k in st_keys},
                                 meta["p_state_slots"], meta["p_chunk_lens"])
            xp = xp + o
            new_cache = dict(st)
        if has_d:
            base = new_cache if has_p else cache
            h = norm("ln1", xd)
            o, st = _mamba_serve(cfg, p, h, {k: base[k] for k in st_keys},
                                 meta["d_state_slots"], meta["d_valid"])
            xd = xd + o
            new_cache = dict(st)
    elif kind == BlockKind.MLA_MLP:
        kvc = cache["kv"]
        if has_p:
            h = norm("ln1", xp)
            o, kvc = _paged_mla_attention(cfg, p, h, kvc, meta, dims, True)
            xp = xp + o
        if has_d:
            h = norm("ln1", xd)
            o, kvc = _paged_mla_attention(cfg, p, h, kvc, meta, dims, False)
            xd = xd + o
        new_cache = {"kv": kvc}
    else:  # ATTN_MLP / ATTN_MOE
        kvc = cache["kv"]
        if has_p:
            h = norm("ln1", xp)
            o, kvc = _paged_self_attention(cfg, p, h, kvc, meta, dims, True)
            xp = xp + o
        if has_d:
            h = norm("ln1", xd)
            o, kvc = _paged_self_attention(cfg, p, h, kvc, meta, dims, False)
            xd = xd + o
        new_cache = {"kv": kvc}

    # ffn over all rows (flattened); static-bucket padding rows are masked
    # out of MoE routing so they never consume expert capacity
    parts, valid_parts = [], []
    if has_p:
        parts.append(norm("ln2", xp).reshape(-1, cfg.d_model))
        pv = (jnp.arange(xp.shape[1])[None, :]
              < (dims.Te + meta["p_chunk_lens"])[:, None])
        valid_parts.append(pv.reshape(-1))
    if has_d:
        parts.append(norm("ln2", xd).reshape(-1, cfg.d_model))
        valid_parts.append((meta["d_valid"] > 0))
    flat = jnp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
    if kind in (BlockKind.ATTN_MOE, BlockKind.MAMBA_MOE):
        ep = "data" if cfg.plan.ep_over_data else None
        row_valid = (jnp.concatenate(valid_parts)
                     if len(valid_parts) > 1 else valid_parts[0])
        y, _ = moe_lib.moe_apply(flat, p, top_k=cfg.num_experts_per_tok,
                                 ep_axis=ep,
                                 capacity_factor=cfg.moe_capacity_factor,
                                 row_valid=row_valid)
    else:
        y = mlp_apply(flat, p, cfg.act)
    off = 0
    if has_p:
        n = Sp * xp.shape[1]
        xp = xp + y[off:off + n].reshape(xp.shape)
        off += n
    if has_d:
        xd = xd + y[off:].reshape(xd.shape)
    return xp, xd, new_cache, enc_cache


def stage_forward_serve(cfg: ArchConfig, stage_params, caches, xp, xd, meta,
                        dims: ServeDims, *, unroll: bool = False):
    """Apply one stage's blocks to its resident micro-batch (inside the
    manual {'stage','data'} shard_map).  Returns (xp, xd, new_caches).

    `unroll=True` replaces the per-block lax.scan with a Python loop whose
    cache updates are in-place dynamic-update-slices on the donated cache
    buffer — the scan version forces XLA to double-buffer the whole KV pool
    every tick (§Perf iteration 1)."""
    stage_idx = jax.lax.axis_index("stage")
    layer_offset = 0
    new_caches = dict(caches) if caches else {}
    enc_cache = caches.get("enc_h", {}).get("h") if caches else None
    # whisper: cache this stage's encoder hidden for decode cross-attention
    if cfg.is_encoder_decoder and dims.Sp > 0 and enc_cache is not None:
        pass  # written after the encoder blocks below

    for i, bs in enumerate(cfg.pattern):
        key = _block_key(i, bs)
        p = stage_params[key]
        cache_i = caches.get(key) if caches else None

        def apply_one(carry, pl, cl, local_i, kind=bs.kind, off=layer_offset):
            cxp, cxd, cenc = carry
            g = stage_idx * cfg.layers_per_stage + off + local_i
            active = jnp.where(g < cfg.num_layers, 1.0, 0.0)
            yp, yd, new_cl, cenc = block_apply_serve(
                cfg, kind, pl, cxp, cxd, cl, meta, dims, enc_cache=cenc)
            a = active.astype(cxp.dtype if dims.Sp else cxd.dtype)
            if dims.Sp:
                yp = cxp + a * (yp - cxp)
            if dims.Sd:
                yd = cxd + a * (yd - cxd)
            # NOTE: padded layers' cache writes land in their *own* [R, ...]
            # slice and are never read (outputs masked above) — no freeze
            # needed, and freezing would touch the full KV pool every layer.
            return (yp, yd, cenc), new_cl

        if bs.repeat == 1:
            p1 = jax.tree.map(lambda a: a[0], p)
            c1 = jax.tree.map(lambda a: a[0], cache_i) if cache_i else None
            (xp, xd, enc_cache), nc = apply_one((xp, xd, enc_cache), p1, c1, 0)
            if cache_i is not None and nc is not None:
                new_caches[key] = jax.tree.map(lambda a: a[None], nc)
        elif unroll:
            # in-place layer loop: each layer's cache slice is updated with a
            # dynamic-update-slice on the (donated) stacked buffer
            acc = cache_i
            for r in range(bs.repeat):
                pr = jax.tree.map(lambda a: a[r], p)
                cr = jax.tree.map(lambda a: a[r], acc) if acc else None
                (xp, xd, enc_cache), nc = apply_one((xp, xd, enc_cache),
                                                    pr, cr, r)
                if acc is not None and nc is not None:
                    acc = jax.tree.map(
                        lambda full, upd, rr=r:
                        jax.lax.dynamic_update_index_in_dim(full, upd, rr, 0),
                        acc, nc)
            if acc is not None:
                new_caches[key] = acc
        else:
            def scan_body(carry, inp):
                pl, cl, li = inp
                carry, nc = apply_one(carry, pl, cl, li)
                return carry, nc

            (xp, xd, enc_cache), ncs = jax.lax.scan(
                scan_body, (xp, xd, enc_cache),
                (p, cache_i, jnp.arange(bs.repeat)))
            if cache_i is not None and ncs is not None:
                new_caches[key] = ncs
        layer_offset += bs.repeat
        # whisper: after the encoder group, snapshot enc hidden into the cache
        if cfg.is_encoder_decoder and bs.kind == BlockKind.ENC_LAYER \
                and enc_cache is not None and dims.Sp > 0:
            slots = meta["p_state_slots"]
            upd = xp[:, :dims.Te]
            write = (meta["p_sample"] + jnp.zeros_like(slots)) >= 0  # prefill ticks
            tgt = jnp.where(meta["p_chunk_lens"] > 0, slots, -1)
            enc_cache = enc_cache.at[tgt].set(upd, mode="drop")

    if "enc_h" in new_caches and enc_cache is not None:
        new_caches["enc_h"] = {"h": enc_cache}
    return xp, xd, new_caches
