"""Shared neural-net layers: norms, RoPE/M-RoPE, MLPs, embeddings, sampling."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * g.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * g.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(x: jax.Array, p: dict, kind: str, eps: float) -> jax.Array:
    if kind == "layernorm":
        return layernorm(x, p["g"], p["b"], eps)
    return rmsnorm(x, p["g"], eps)


# ----------------------------------------------------------------------------
# Rotary embeddings
# ----------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [...,] -> (cos, sin) each [..., dim/2] float32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # [..., dim/2]
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., H, D]; cos/sin broadcastable [..., 1, D/2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., T, H, D], positions [..., T] -> rotary-embedded x."""
    cos, sin = _rope_angles(positions, x.shape[-1], theta)
    return _rotate(x, cos[..., None, :], sin[..., None, :])


def apply_mrope(
    x: jax.Array,
    positions3: jax.Array,           # [3, ..., T] (temporal, height, width)
    sections: Tuple[int, int, int],  # frequency-split sizes, sum == D/2
    theta: float,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the D/2 frequency bands are split into
    (t, h, w) sections, each rotated by its own position stream.  For text
    tokens the three streams coincide and M-RoPE reduces to RoPE."""
    D = x.shape[-1]
    assert sum(sections) == D // 2, (sections, D)
    inv = 1.0 / (theta ** (jnp.arange(0, D, 2, dtype=jnp.float32) / D))
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=D // 2)
    # per-frequency position stream: gather the section's positions
    pos3 = jnp.moveaxis(positions3, 0, -1).astype(jnp.float32)  # [..., T, 3]
    pos = jnp.take(pos3, sec_id, axis=-1)                        # [..., T, D/2]
    ang = pos * inv
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    return _rotate(x, cos[..., None, :], sin[..., None, :])


# ----------------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def gelu_mlp(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array, b2: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ w1 + b1, approximate=True) @ w2 + b2


def mlp_apply(x: jax.Array, p: dict, act: str) -> jax.Array:
    if act == "gelu":
        return gelu_mlp(x, p["w1"], p["b1"], p["w2"], p["b2"])
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


# ----------------------------------------------------------------------------
# Sampling
# ----------------------------------------------------------------------------

def sample_tokens(
    logits: jax.Array,               # [rows, V]
    rng: Optional[jax.Array],
    temperature: float = 0.0,
) -> jax.Array:
    if temperature <= 0.0 or rng is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature, axis=-1).astype(jnp.int32)
