"""Unified transformer stack over heterogeneous block kinds.

Parameters are *stacked*: every leaf carries leading dims ``[S, R, ...]``
(S = pipeline stages — sharded over the manual `stage` axis — and R = the
block's repeat count inside a stage, scanned).  The same stage program runs on
every stage (SPMD pipelining); published layer counts that don't tile the
grid are padded and *masked* — padded layers contribute exactly ``h + 0``
(DESIGN.md §3).

Two execution modes share the block definitions:
  * ``train``  — full sequences, dense causal attention, no caches.
  * ``serve``  — one pipeline tick: per-stage micro-batch of prefill chunks
    [Sp, C] + decode rows [Sd], paged KV / recurrent-state caches.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, BlockKind, BlockSpec
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    apply_mrope,
    apply_norm,
    apply_rope,
    gelu_mlp,
    mlp_apply,
    rmsnorm,
    swiglu,
)

Leaf = Tuple[Tuple[int, ...], P, str]   # (shape, partition-spec, init kind)


def _norm_defs(cfg: ArchConfig, name: str) -> Dict[str, Leaf]:
    d = {f"{name}_g": ((cfg.d_model,), P(), "ones")}
    if cfg.norm == "layernorm":
        d[f"{name}_b"] = ((cfg.d_model,), P(), "zeros")
    return d


def _mlp_defs(cfg: ArchConfig) -> Dict[str, Leaf]:
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.act == "gelu":
        return {
            "w1": ((d, ff), P(None, "tensor"), "normal"),
            "b1": ((ff,), P("tensor"), "zeros"),
            "w2": ((ff, d), P("tensor", None), "residual"),
            "b2": ((d,), P(), "zeros"),
        }
    return {
        "w_gate": ((d, ff), P(None, "tensor"), "normal"),
        "w_up": ((d, ff), P(None, "tensor"), "normal"),
        "w_down": ((ff, d), P("tensor", None), "residual"),
    }


def _attn_defs(cfg: ArchConfig, prefix: str = "") -> Dict[str, Leaf]:
    d = cfg.d_model
    q, kv = cfg.q_dim, cfg.kv_dim
    out: Dict[str, Leaf] = {
        f"{prefix}wq": ((d, q), P(None, "tensor"), "normal"),
        f"{prefix}wk": ((d, kv), P(None, "tensor"), "normal"),
        f"{prefix}wv": ((d, kv), P(None, "tensor"), "normal"),
        f"{prefix}wo": ((q, d), P("tensor", None), "residual"),
    }
    if cfg.qkv_bias:
        out[f"{prefix}bq"] = ((q,), P("tensor"), "zeros")
        out[f"{prefix}bk"] = ((kv,), P("tensor"), "zeros")
        out[f"{prefix}bv"] = ((kv,), P("tensor"), "zeros")
    return out


def _mla_defs(cfg: ArchConfig) -> Dict[str, Leaf]:
    d, H = cfg.d_model, cfg.num_heads
    qlr, klr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": ((d, qlr), P(), "normal"),
        "q_norm_g": ((qlr,), P(), "ones"),
        "w_uq": ((qlr, H * (dn + dr)), P(None, "tensor"), "normal"),
        "w_dkv": ((d, klr + dr), P(), "normal"),
        "kv_norm_g": ((klr,), P(), "ones"),
        "w_ukv": ((klr, H * (dn + dv)), P(None, "tensor"), "normal"),
        "wo": ((H * dv, d), P("tensor", None), "residual"),
    }


def _moe_defs(cfg: ArchConfig) -> Dict[str, Leaf]:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ep = "data" if cfg.plan.ep_over_data else None
    out: Dict[str, Leaf] = {
        "router": ((d, E), P(), "normal"),
        "w_gate": ((E, d, ff), P(ep, None, "tensor"), "normal"),
        "w_up": ((E, d, ff), P(ep, None, "tensor"), "normal"),
        "w_down": ((E, ff, d), P(ep, "tensor", None), "residual"),
    }
    if cfg.num_shared_experts:
        ffs = ff * cfg.num_shared_experts
        out["s_gate"] = ((d, ffs), P(None, "tensor"), "normal")
        out["s_up"] = ((d, ffs), P(None, "tensor"), "normal")
        out["s_down"] = ((ffs, d), P("tensor", None), "residual")
    return out


def _mamba_defs(cfg: ArchConfig) -> Dict[str, Leaf]:
    d = cfg.d_model
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    dtr = max(8, d // 16)
    return {
        "in_proj": ((d, 2 * di), P(None, "tensor"), "normal"),
        "conv_w": ((dc, di), P(None, "tensor"), "normal"),
        "conv_b": ((di,), P("tensor"), "zeros"),
        "x_proj": ((di, dtr + 2 * ds), P("tensor", None), "normal"),
        "dt_proj": ((dtr, di), P(None, "tensor"), "normal"),
        "dt_bias": ((di,), P("tensor"), "zeros"),
        "A_log": ((di, ds), P("tensor", None), "a_log"),
        "D": ((di,), P("tensor"), "ones"),
        "out_proj": ((di, d), P("tensor", None), "residual"),
    }


def _rwkv_defs(cfg: ArchConfig) -> Dict[str, Leaf]:
    d, ff = cfg.d_model, cfg.d_ff
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    lora = 64
    out: Dict[str, Leaf] = {
        "ln1_g": ((d,), P(), "ones"), "ln1_b": ((d,), P(), "zeros"),
        "ln2_g": ((d,), P(), "ones"), "ln2_b": ((d,), P(), "zeros"),
        "mu_r": ((d,), P(), "mu"), "mu_k": ((d,), P(), "mu"),
        "mu_v": ((d,), P(), "mu"), "mu_g": ((d,), P(), "mu"),
        "mu_w": ((d,), P(), "mu"),
        "w_r": ((d, d), P(None, "tensor"), "normal"),
        "w_k": ((d, d), P(None, "tensor"), "normal"),
        "w_v": ((d, d), P(None, "tensor"), "normal"),
        "w_g": ((d, d), P(None, "tensor"), "normal"),
        "w_o": ((d, d), P("tensor", None), "residual"),
        "w0": ((d,), P(), "decay"),
        "w_lora_a": ((d, lora), P(), "normal"),
        "w_lora_b": ((lora, d), P(), "zeros"),
        "u": ((d,), P(), "mu"),
        "ln_x_g": ((d,), P(), "ones"),
        "cm_mu_k": ((d,), P(), "mu"), "cm_mu_r": ((d,), P(), "mu"),
        "cm_k": ((d, ff), P(None, "tensor"), "normal"),
        "cm_v": ((ff, d), P("tensor", None), "residual"),
        "cm_r": ((d, d), P(), "normal"),
    }
    return out


def block_param_defs(cfg: ArchConfig, kind: BlockKind) -> Dict[str, Leaf]:
    defs: Dict[str, Leaf] = {}
    if kind == BlockKind.RWKV:
        return _rwkv_defs(cfg)
    defs.update(_norm_defs(cfg, "ln1"))
    defs.update(_norm_defs(cfg, "ln2"))
    if kind in (BlockKind.ATTN_MLP, BlockKind.ATTN_MOE, BlockKind.ENC_LAYER):
        defs.update(_attn_defs(cfg))
    elif kind == BlockKind.MLA_MLP:
        defs.update(_mla_defs(cfg))
    elif kind in (BlockKind.MAMBA_MLP, BlockKind.MAMBA_MOE):
        defs.update(_mamba_defs(cfg))
    elif kind == BlockKind.DEC_LAYER:
        defs.update(_attn_defs(cfg))
        defs.update(_attn_defs(cfg, prefix="x_"))
        defs.update(_norm_defs(cfg, "ln3"))
    if kind in (BlockKind.ATTN_MOE, BlockKind.MAMBA_MOE):
        defs.update(_moe_defs(cfg))
    elif kind != BlockKind.RWKV:
        defs.update(_mlp_defs(cfg))
    return defs


def _block_key(i: int, spec: BlockSpec) -> str:
    return f"b{i}_{spec.kind.value}"


def model_param_defs(cfg: ArchConfig) -> Dict[str, Any]:
    """Full parameter tree of (shape, spec, init) leaves."""
    S = cfg.plan.pp
    stages: Dict[str, Dict[str, Leaf]] = {}
    for i, bs in enumerate(cfg.pattern):
        defs = block_param_defs(cfg, bs.kind)
        stages[_block_key(i, bs)] = {
            k: ((S, bs.repeat) + shape, P(*(("stage", None) + tuple(spec))), init)
            for k, (shape, spec, init) in defs.items()
        }
    # Embedding: replicated over manual axes (gathers are FLOP-free), d over
    # `tensor`.  LM head: vocab sharded over (stage x tensor) — the sharded
    # loss in distributed.pipeline broadcasts the last stage's hidden once and
    # every stage computes its vocab slice (no S-fold redundant head FLOPs).
    V = cfg.padded_vocab
    tree: Dict[str, Any] = {
        "embed": {"tok": ((V, cfg.d_model), P(None, "tensor"), "normal")},
        "stages": stages,
        "final_norm": {k.split("final_")[-1]: v for k, v in
                       _norm_defs(cfg, "final").items()},
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = {"w": ((cfg.d_model, V),
                                 P(None, ("stage", "tensor")), "normal")}
    return tree


def param_shapes(cfg: ArchConfig):
    return jax.tree.map(lambda leaf: leaf[0], model_param_defs(cfg),
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
                        and isinstance(x[0], tuple))


def _is_leafdef(x):
    return isinstance(x, tuple) and len(x) == 3 and isinstance(x[-1], str)


def param_pspecs(cfg: ArchConfig):
    return jax.tree.map(lambda leaf: leaf[1], model_param_defs(cfg),
                        is_leaf=_is_leafdef)


def init_params(cfg: ArchConfig, rng: jax.Array, dtype=None):
    """Materialize parameters (reduced configs / examples; full configs are
    only ever abstract — dry-run)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    defs = model_param_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_leafdef)
    keys = jax.random.split(rng, len(leaves))

    def make(leaf, key):
        shape, _, init = leaf
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "mu":
            return jax.random.uniform(key, shape, dtype, 0.0, 1.0)
        if init == "decay":
            return jnp.full(shape, -1.0, dtype)
        if init == "a_log":
            ds = shape[-1]
            base = jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32))
            return jnp.broadcast_to(base, shape).astype(dtype)
        scale = 0.02
        if init == "residual":
            scale = 0.02 / math.sqrt(max(1, 2 * cfg.num_layers))
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)

    return jax.tree.unflatten(treedef, [make(l, k) for l, k in zip(leaves, keys)])


def abstract_params(cfg: ArchConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda leaf: jax.ShapeDtypeStruct(leaf[0], dtype),
        model_param_defs(cfg), is_leaf=_is_leafdef)


# ----------------------------------------------------------------------------
# Embedding / head / loss (run in auto-GSPMD land, outside the pipeline)
# ----------------------------------------------------------------------------

def embed_apply(cfg: ArchConfig, params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embed"]["tok"], tokens, axis=0)


def head_apply(cfg: ArchConfig, params, h: jax.Array) -> jax.Array:
    fn = params["final_norm"]
    if "b" in fn:
        from repro.models.layers import layernorm
        h = layernorm(h, fn["g"], fn["b"], cfg.norm_eps)
    else:
        h = rmsnorm(h, fn["g"], cfg.norm_eps)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["lm_head"]["w"]
    return h @ w


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ----------------------------------------------------------------------------
# Train-mode block application
# ----------------------------------------------------------------------------

def _heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _attn_train(cfg: ArchConfig, p, x, positions, *, causal=True, prefix=""):
    """x [B, T, d] -> self-attention output."""
    q = x @ p[f"{prefix}wq"]
    k = x @ p[f"{prefix}wk"]
    v = x @ p[f"{prefix}wv"]
    if cfg.qkv_bias and f"{prefix}bq" in p:
        q, k, v = q + p[f"{prefix}bq"], k + p[f"{prefix}bk"], v + p[f"{prefix}bv"]
    q = _heads(q, cfg.num_heads, cfg.head_dim)
    k = _heads(k, cfg.num_kv_heads, cfg.head_dim)
    v = _heads(v, cfg.num_kv_heads, cfg.head_dim)
    if cfg.mrope:
        pos3 = jnp.broadcast_to(positions, (3,) + positions.shape)
        q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = attn.causal_attention(q, k, v, causal=causal)
    return o.reshape(o.shape[:-2] + (-1,)) @ p[f"{prefix}wo"]


def _mla_train(cfg: ArchConfig, p, x, positions):
    B, T, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    klr = cfg.kv_lora_rank
    cq = rmsnorm(x @ p["w_dq"], p["q_norm_g"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(B, T, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_full = x @ p["w_dkv"]                              # [B, T, klr + dr]
    ckv = rmsnorm(ckv_full[..., :klr], p["kv_norm_g"], cfg.norm_eps)
    k_rope = apply_rope(ckv_full[..., None, klr:], positions, cfg.rope_theta)
    kv = (ckv @ p["w_ukv"]).reshape(B, T, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, T, H, dr))], axis=-1)
    o = attn.causal_attention(q, k, v)
    return o.reshape(B, T, H * dv) @ p["wo"]


def block_apply_train(cfg: ArchConfig, kind: BlockKind, p, x, aux,
                      enc_width: int = 0):
    """x [B, T, d] -> (x, aux).  Whisper blocks operate on the enc/dec halves
    of the payload (enc_width = encoder slice length)."""
    positions = jnp.arange(x.shape[1])
    eps = cfg.norm_eps

    def norm(name, h):
        keys = {"g": p[f"{name}_g"]}
        if f"{name}_b" in p:
            keys["b"] = p[f"{name}_b"]
        return apply_norm(h, keys, cfg.norm, eps)

    if kind == BlockKind.RWKV:
        x, _ = ssm_lib.rwkv_block(x, p, head_dim=cfg.rwkv_head_dim,
                                  norm_eps=eps)
        return x, aux

    if kind in (BlockKind.ENC_LAYER, BlockKind.DEC_LAYER):
        Te = enc_width
        enc, dec = x[:, :Te], x[:, Te:]
        if kind == BlockKind.ENC_LAYER:
            h = norm("ln1", enc)
            enc = enc + _attn_train(cfg, p, h, positions[:Te], causal=False)
            h = norm("ln2", enc)
            enc = enc + mlp_apply(h, p, cfg.act)
        else:
            h = norm("ln1", dec)
            dec = dec + _attn_train(cfg, p, h, positions[: dec.shape[1]])
            # cross-attention to the (stage-local) encoder stream
            h = norm("ln3", dec)
            q = _heads(h @ p["x_wq"] + (p.get("x_bq", 0.0)), cfg.num_heads,
                       cfg.head_dim)
            he = enc
            k = _heads(he @ p["x_wk"] + (p.get("x_bk", 0.0)),
                       cfg.num_kv_heads, cfg.head_dim)
            v = _heads(he @ p["x_wv"] + (p.get("x_bv", 0.0)),
                       cfg.num_kv_heads, cfg.head_dim)
            o = attn.cross_attention(q, k, v)
            dec = dec + o.reshape(o.shape[:-2] + (-1,)) @ p["x_wo"]
            h = norm("ln2", dec)
            dec = dec + mlp_apply(h, p, cfg.act)
        return jnp.concatenate([enc, dec], axis=1), aux

    # mixer
    h = norm("ln1", x)
    if kind in (BlockKind.MAMBA_MLP, BlockKind.MAMBA_MOE):
        mix, _ = ssm_lib.mamba_mixer(h, p, d_state=cfg.mamba_d_state,
                                     d_conv=cfg.mamba_d_conv)
    elif kind == BlockKind.MLA_MLP:
        mix = _mla_train(cfg, p, h, positions)
    else:
        mix = _attn_train(cfg, p, h, positions)
    x = x + mix

    # ffn
    h = norm("ln2", x)
    if kind in (BlockKind.ATTN_MOE, BlockKind.MAMBA_MOE):
        flat = h.reshape(-1, cfg.d_model)
        ep = "data" if cfg.plan.ep_over_data else None
        y, a = moe_lib.moe_apply(flat, p, top_k=cfg.num_experts_per_tok,
                                 ep_axis=ep,
                                 capacity_factor=cfg.moe_capacity_factor)
        x = x + y.reshape(x.shape)
        aux = aux + a
    else:
        x = x + mlp_apply(h, p, cfg.act)
    return x, aux


def stage_forward_train(cfg: ArchConfig, stage_params, x, *,
                        enc_width: int = 0, remat: bool = True):
    """Apply one stage's blocks to x [B, T, d] (runs inside the `stage`
    shard_map; stage_params leaves are local [R, ...])."""
    aux = jnp.zeros((), jnp.float32)
    stage_idx = jax.lax.axis_index("stage")
    layer_offset = 0

    for i, bs in enumerate(cfg.pattern):
        p = stage_params[_block_key(i, bs)]

        def apply_one(x_aux, pl, local_i, kind=bs.kind, off=layer_offset):
            xx, ax = x_aux
            g = stage_idx * cfg.layers_per_stage + off + local_i
            active = jnp.where(g < cfg.num_layers, 1.0, 0.0).astype(xx.dtype)
            fn = partial(block_apply_train, cfg, kind, enc_width=enc_width)
            if remat:
                import os
                pol = os.environ.get("REPRO_REMAT_POLICY", "full")
                if pol == "dots":
                    fn = jax.checkpoint(
                        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
                else:
                    fn = jax.checkpoint(fn)
            y, ax2 = fn(pl, xx, ax)
            xx = xx + active * (y - xx)      # masked: padded layers are identity
            return (xx, ax2 * active + ax * (1 - active))

        if bs.repeat == 1:
            p1 = jax.tree.map(lambda a: a[0], p)
            x, aux = apply_one((x, aux), p1, 0)
        else:
            def scan_body(carry, inp):
                pl, li = inp
                return apply_one(carry, pl, li), None

            (x, aux), _ = jax.lax.scan(
                scan_body, (x, aux),
                (p, jnp.arange(bs.repeat)))
        layer_offset += bs.repeat
    return x, aux
