"""Dense (non-pipelined, non-paged) reference forward + greedy generation.

The oracle the serving engine is validated against: identical parameters,
identical stage-ordered layer application (including the whisper staircase
and the padded-layer mask), but executed as one dense forward over the full
sequence — no pipeline, no paged KV, no chunking.  Used by the equivalence
tests and the Table-1-style output-quality benchmark.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as tfm
from repro.models.transformer import _block_key


def dense_forward(cfg: ArchConfig, params, tokens: jax.Array,
                  enc_embeds: Optional[jax.Array] = None,
                  enc_width: int = 0) -> jax.Array:
    """tokens [B, T] -> logits [B, T(+Te), V].  For enc-dec, `enc_embeds`
    [B, Te, d] is prepended as the encoder stream (tokens are the decoder
    side); returned logits cover the concatenated payload — slice the
    decoder half for next-token prediction."""
    h = jnp.take(params["embed"]["tok"], tokens, axis=0)
    if enc_embeds is not None:
        h = jnp.concatenate([enc_embeds.astype(h.dtype), h], axis=1)
        enc_width = enc_embeds.shape[1]
    aux = jnp.zeros((), jnp.float32)
    Lps = cfg.layers_per_stage
    for s in range(cfg.plan.pp):
        off = 0
        for i, bs in enumerate(cfg.pattern):
            p = params["stages"][_block_key(i, bs)]
            for r in range(bs.repeat):
                g = s * Lps + off + r
                if g < cfg.num_layers:
                    pl = jax.tree.map(lambda a: a[s, r], p)
                    h, aux = tfm.block_apply_train(
                        cfg, bs.kind, pl, h, aux, enc_width=enc_width)
            off += bs.repeat
    return tfm.head_apply(cfg, params, h)


def greedy_generate(
    cfg: ArchConfig,
    params,
    prompt: Sequence[int],
    max_new_tokens: int,
    enc_embeds: Optional[np.ndarray] = None,
) -> List[int]:
    """Greedy decoding by full recompute each step (slow, exact)."""
    toks = list(prompt)
    out: List[int] = []
    enc = None if enc_embeds is None else jnp.asarray(enc_embeds)[None]
    for _ in range(max_new_tokens):
        logits = dense_forward(cfg, params, jnp.asarray([toks], jnp.int32),
                               enc_embeds=enc)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out
