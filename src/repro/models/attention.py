"""Attention paths: a shared flash (online-softmax) core consumed by
training (dense causal), chunked-prefill-over-pages, paged decode, and
sequence-sharded long-context decode (flash-decode merge over `data`).

The core iterates KV *blocks* through a provider callback so that paged
gathers and MLA latent expansion happen per-block inside the scan — the
[Tq, ctx] score matrix and the expanded MLA K/V never materialize in full.
The Pallas kernels in ``repro.kernels`` implement the same math with explicit
VMEM BlockSpecs; on this CPU container the jnp path is the execution path and
the kernels are validated in interpret mode (DESIGN.md §6).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _online_softmax_step(carry, blk, q, scale):
    """One flash block: q [*, Tq, H, D]; blk = (k, v, mask).

    k/v: [*, Bk, KH, D]; mask: [*, Tq, Bk] bool (True = attend), already
    broadcastable over heads.  Grouped heads (GQA): H = KH * G.
    """
    o, m, l = carry                      # o [*, Tq, H, Dv]; m,l [*, Tq, H]
    k, v, mask = blk
    H = q.shape[-2]
    KH = k.shape[-2]
    G = H // KH
    qg = q.reshape(q.shape[:-2] + (KH, G, q.shape[-1]))
    # operands stay in their storage dtype; the MXU accumulates in f32
    # (an explicit .astype(f32) on k/v lets XLA hoist a *whole-KV-pool*
    # f32 conversion out of the flash loop — §Perf iteration 1b)
    s = jnp.einsum("...qhgd,...khd->...qhgk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[..., :, None, None, :], s, NEG_INF)
    s = s.reshape(s.shape[:-4] + (s.shape[-4], H, s.shape[-1]))  # [*, Tq, H, Bk]
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    pg = p.reshape(p.shape[:-2] + (KH, G, p.shape[-1]))
    pv = jnp.einsum("...qhgk,...khd->...qhgd", pg.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    pv = pv.reshape(pv.shape[:-3] + (H, pv.shape[-1]))
    o_new = o * alpha[..., None] + pv
    return (o_new, m_new, l_new), None


def flash_attention_blocks(
    q: jax.Array,                                   # [*, Tq, H, D]
    kv_block_fn: Callable[[jax.Array], Tuple[jax.Array, jax.Array, jax.Array]],
    num_blocks: int,
    *,
    v_dim: Optional[int] = None,
    scale: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Online-softmax over `num_blocks` KV blocks from `kv_block_fn(i)`.

    Returns (out [*, Tq, H, Dv], m, l) — the un-normalized partials so callers
    can merge across shards (flash-decode); use `finalize_flash` for the
    normalized output.
    """
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    Dv = v_dim if v_dim is not None else q.shape[-1]
    shape = q.shape[:-1]
    o0 = jnp.zeros(shape + (Dv,), jnp.float32)
    m0 = jnp.full(shape, NEG_INF, jnp.float32)
    l0 = jnp.zeros(shape, jnp.float32)

    def body(carry, i):
        blk = kv_block_fn(i)
        return _online_softmax_step(carry, blk, q, scale)

    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), jnp.arange(num_blocks))
    return o, m, l


def finalize_flash(o: jax.Array, l: jax.Array, dtype) -> jax.Array:
    return (o / jnp.maximum(l, 1e-20)[..., None]).astype(dtype)


def merge_flash_partials(o, m, l, axis_name: str):
    """Flash-decode: combine per-shard (o, m, l) across `axis_name` — used for
    sequence-sharded KV in long-context decode (DESIGN.md §3)."""
    m_glob = jax.lax.pmax(m, axis_name)
    alpha = jnp.exp(m - m_glob)
    o = jax.lax.psum(o * alpha[..., None], axis_name)
    l = jax.lax.psum(l * alpha, axis_name)
    return o, m_glob, l


# ----------------------------------------------------------------------------
# Dense causal attention (training / smoke)
# ----------------------------------------------------------------------------

def causal_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,      # [B, T, H|KH, D]
    *, block_k: int = 512, causal: bool = True,
) -> jax.Array:
    B, T = q.shape[0], q.shape[1]
    Bk = min(block_k, T)
    assert T % Bk == 0, (T, Bk)
    qpos = jnp.arange(T)

    def kv_blk(i):
        kb = jax.lax.dynamic_slice_in_dim(k, i * Bk, Bk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, i * Bk, Bk, axis=1)
        kpos = i * Bk + jnp.arange(Bk)
        if causal:
            mask = qpos[:, None] >= kpos[None, :]
        else:
            mask = jnp.ones((T, Bk), bool)
        return kb, vb, jnp.broadcast_to(mask, (B, T, Bk))

    o, m, l = flash_attention_blocks(q, kv_blk, T // Bk, v_dim=v.shape[-1])
    return finalize_flash(o, l, q.dtype)


def cross_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,      # q [B,Tq,H,D], kv [B,Tk,KH,D]
    k_valid: Optional[jax.Array] = None,           # [B, Tk] bool
    *, block_k: int = 512,
) -> jax.Array:
    B, Tq = q.shape[0], q.shape[1]
    Tk = k.shape[1]
    Bk = min(block_k, Tk)
    assert Tk % Bk == 0, (Tk, Bk)

    def kv_blk(i):
        kb = jax.lax.dynamic_slice_in_dim(k, i * Bk, Bk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, i * Bk, Bk, axis=1)
        if k_valid is None:
            mask = jnp.ones((B, Tq, Bk), bool)
        else:
            mb = jax.lax.dynamic_slice_in_dim(k_valid, i * Bk, Bk, axis=1)
            mask = jnp.broadcast_to(mb[:, None, :], (B, Tq, Bk))
        return kb, vb, mask

    o, m, l = flash_attention_blocks(q, kv_blk, Tk // Bk, v_dim=v.shape[-1])
    return finalize_flash(o, l, q.dtype)


# ----------------------------------------------------------------------------
# Paged attention (serving): query rows attend to block-table pages
# ----------------------------------------------------------------------------

def write_kv_pages(
    cache: jax.Array,                 # [Pages, page, 2, KH, D] (or [..., C] MLA)
    new_kv: jax.Array,                # [S, C, 2, KH, D] / [S, C, Cdim]
    slot_pages: jax.Array,            # [S, C] int32 destination page per token
    slot_offsets: jax.Array,          # [S, C] int32 offset within page
    valid: jax.Array,                 # [S, C] bool (padding rows don't write)
) -> jax.Array:
    flat_kv = new_kv.reshape((-1,) + new_kv.shape[2:])
    pages = jnp.where(valid, slot_pages, -1).reshape(-1)   # OOB => dropped
    offs = slot_offsets.reshape(-1)
    return cache.at[pages, offs].set(flat_kv, mode="drop")


def _check_table_alignment(Bmax: int, pages_per_block: int) -> None:
    if pages_per_block <= 0 or Bmax % pages_per_block != 0:
        raise ValueError(
            f"block-table width {Bmax} is not a positive multiple of "
            f"pages_per_block={pages_per_block} (the flash gather "
            f"granularity, env knob REPRO_PAGES_PER_BLOCK): pick table "
            f"widths (ServeDims.Bp/Bd and any depth-bucket steps, "
            f"REPRO_DEPTH_STEPS) divisible by it, or change the knob")


def paged_attention(
    q: jax.Array,                     # [S, C, H, D] (C==1 for decode)
    cache: jax.Array,                 # [Pages, page, 2, KH, D]
    block_tables: jax.Array,          # [S, Bmax] int32
    context_lens: jax.Array,          # [S] int32 (incl. this step's tokens)
    q_positions: jax.Array,           # [S, C] int32 global positions
    *,
    pages_per_block: int = 8,
    merge_axis: Optional[str] = None, # flash-decode merge over this mesh axis
    shard_info: Optional[Tuple[jax.Array, int]] = None,  # (shard_idx, n_shards)
) -> jax.Array:
    """Chunked-prefill & decode attention over the paged KV pool.

    With `merge_axis`, block tables index a *local* pool shard holding an
    interleaved slice of the sequence (page p on shard r covers positions
    [(p*n_shards+r)*page, ...)) and partial softmax stats are merged across
    the axis (flash-decode).
    """
    S, Bmax = block_tables.shape
    page = cache.shape[1]
    KH, D = cache.shape[-2], cache.shape[-1]
    _check_table_alignment(Bmax, pages_per_block)
    n_blocks = Bmax // pages_per_block
    Bk = pages_per_block * page

    # On real TPU, dispatch to the Pallas kernel (identical math, explicit
    # VMEM tiling); the jnp path below is the CPU/dry-run implementation.
    if merge_axis is None:
        from repro.kernels import ops as kops
        if kops.on_tpu() and kops.use_kernels():
            return kops.paged_attention(q, cache, block_tables, context_lens,
                                        q_positions)

    def kv_blk(i):
        tabs = jax.lax.dynamic_slice_in_dim(block_tables, i * pages_per_block,
                                            pages_per_block, axis=1)  # [S, pb]
        gathered = cache[tabs]                 # [S, pb, page, 2, KH, D]
        kv = gathered.reshape(S, Bk, 2, KH, D)
        kb, vb = kv[:, :, 0], kv[:, :, 1]
        base = (i * pages_per_block + jnp.arange(pages_per_block)) * page
        kpos = (base[:, None] + jnp.arange(page)[None, :]).reshape(Bk)  # [Bk]
        if shard_info is not None:
            shard_idx, n_shards = shard_info
            # interleaved sequence sharding: local page b = global page b*n+r
            gbase = ((i * pages_per_block + jnp.arange(pages_per_block))
                     * n_shards + shard_idx) * page
            kpos = (gbase[:, None] + jnp.arange(page)[None, :]).reshape(Bk)
        mask = (kpos[None, None, :] < context_lens[:, None, None]) & \
               (kpos[None, None, :] <= q_positions[:, :, None])
        return kb, vb, mask

    o, m, l = flash_attention_blocks(q, kv_blk, n_blocks, v_dim=D)
    if merge_axis is not None:
        o, m, l = merge_flash_partials(o, m, l, merge_axis)
    return finalize_flash(o, l, q.dtype)


def paged_attention_mla(
    q: jax.Array,                     # [S, C, H, dn + dr]
    cache: jax.Array,                 # [Pages, page, klr + dr]  (latent + rope)
    w_ukv: jax.Array,                 # [klr, H * (dn + dv)]
    block_tables: jax.Array,
    context_lens: jax.Array,
    q_positions: jax.Array,
    *,
    kv_lora_rank: int,
    qk_nope_dim: int,
    v_head_dim: int,
    pages_per_block: int = 8,
) -> jax.Array:
    """MLA: latent KV pages are expanded to per-head K/V *per block inside the
    flash scan* — the full expanded K/V never hits HBM (DeepSeek-V2 style,
    memory-bound decode becomes latent-read-bound)."""
    S, Bmax = block_tables.shape
    page = cache.shape[1]
    klr = kv_lora_rank
    dn, dv = qk_nope_dim, v_head_dim
    H = q.shape[-2]
    dr = q.shape[-1] - dn
    _check_table_alignment(Bmax, pages_per_block)
    n_blocks = Bmax // pages_per_block
    Bk = pages_per_block * page

    def kv_blk(i):
        tabs = jax.lax.dynamic_slice_in_dim(block_tables, i * pages_per_block,
                                            pages_per_block, axis=1)
        lat = cache[tabs].reshape(S, Bk, klr + dr)
        c_kv, k_rope = lat[..., :klr], lat[..., klr:]
        kv = (c_kv @ w_ukv).reshape(S, Bk, H, dn + dv)
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (S, Bk, H, dr))],
            axis=-1)
        base = (i * pages_per_block + jnp.arange(pages_per_block)) * page
        kpos = (base[:, None] + jnp.arange(page)[None, :]).reshape(Bk)
        mask = (kpos[None, None, :] < context_lens[:, None, None]) & \
               (kpos[None, None, :] <= q_positions[:, :, None])
        return k, v, mask

    o, m, l = flash_attention_blocks(q, kv_blk, n_blocks, v_dim=dv)
    return finalize_flash(o, l, q.dtype)
