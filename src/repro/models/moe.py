"""Mixture-of-Experts with explicit expert parallelism.

Dispatch is sort-based with static capacity buffers (GShard-style dropping,
but without the O(T*E*C) one-hot dispatch tensors — at kimi-k2 scale those
would be ~10^11 elements).  With ``ep_axis`` set (kimi, jamba) the experts are
sharded over the `data` mesh axis and tokens move via two `all_to_all`s; each
expert's FFN dims are additionally sharded over `tensor` by GSPMD.  The same
code path (NS=1) serves replicated-expert archs (olmoe) and CPU smoke tests.

Everything is differentiable (sorts only compute indices; gathers/scatters
carry gradients), so the training path reuses it unchanged.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def _round8(x: int) -> int:
    return max(8, (x + 7) // 8 * 8)


def _axis_size(axis: Optional[str]) -> int:
    if axis is None:
        return 1
    return jax.lax.psum(1, axis)


def route(x: jax.Array, router_w: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Softmax-then-top-k routing with weight renormalization.

    Returns (weights [T,K] f32, expert_ids [T,K] i32, aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # GShard load-balancing auxiliary loss: E * mean_e(frac_tokens_e * mean_prob_e)
    E = router_w.shape[-1]
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    fe = jnp.mean(one_hot_top1, axis=0)
    aux = E * jnp.sum(fe * me)
    return w, idx.astype(jnp.int32), aux


def _group_rows(values: jax.Array, group_ids: jax.Array, num_groups: int,
                capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sort rows by group and scatter into [num_groups, capacity, ...] buffers.

    Returns (buffers, order, slot_group, slot_pos); rows beyond capacity drop.
    `group_ids` >= num_groups mark invalid rows (never stored).
    """
    n = group_ids.shape[0]
    order = jnp.argsort(group_ids, stable=True)
    sg = group_ids[order]
    starts = jnp.searchsorted(sg, jnp.arange(num_groups))
    pos = jnp.arange(n) - starts[jnp.minimum(sg, num_groups - 1)]
    pos = jnp.where(sg < num_groups, pos, capacity)      # invalid -> dropped
    buf = jnp.zeros((num_groups, capacity) + values.shape[1:], values.dtype)
    buf = buf.at[sg, pos].set(values[order], mode="drop")
    return buf, order, sg, pos


def _ungroup_rows(buffers: jax.Array, order: jax.Array, slot_group: jax.Array,
                  slot_pos: jax.Array) -> jax.Array:
    """Inverse of `_group_rows`: read each row's result back (dropped -> 0)."""
    n = order.shape[0]
    capacity = buffers.shape[1]
    ok = slot_pos < capacity
    vals = buffers[jnp.minimum(slot_group, buffers.shape[0] - 1),
                   jnp.minimum(slot_pos, capacity - 1)]
    vals = jnp.where(ok[(...,) + (None,) * (vals.ndim - 1)], vals, 0)
    out = jnp.zeros((n,) + buffers.shape[2:], buffers.dtype)
    return out.at[order].set(vals)


def expert_ffn(xb: jax.Array, w_gate: jax.Array, w_up: jax.Array,
               w_down: jax.Array) -> jax.Array:
    """Grouped SwiGLU over padded per-expert buffers.

    xb [E_loc, C, d]; weights [E_loc, d, ff] / [E_loc, ff, d].  On TPU,
    dispatches to the fused Pallas kernel (expert hidden never leaves VMEM);
    elsewhere the batched einsum is the XLA-fused grouped GEMM (GSPMD shards
    `ff` over `tensor`)."""
    from repro.kernels import ops as kops
    if kops.on_tpu() and kops.use_kernels() and xb.shape[1] % 8 == 0 \
            and w_gate.shape[-1] % 128 == 0:
        from repro.kernels.moe_gemm import fused_moe_ffn
        return fused_moe_ffn(xb, w_gate, w_up, w_down)
    h = jnp.einsum("ecd,edf->ecf", xb, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xb, w_up)
    h = jax.nn.silu(h) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_apply(
    x: jax.Array,                     # [T, d] flattened tokens
    params: Dict[str, jax.Array],
    *,
    top_k: int,
    ep_axis: Optional[str] = None,
    capacity_factor: float = 1.25,
    row_valid: Optional[jax.Array] = None,   # [T] bool: padding rows opt out
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output [T, d], aux_loss).  `params` holds:
    router [d, E]; w_gate/w_up [E_loc, d, ff]; w_down [E_loc, ff, d];
    optional shared-expert s_gate/s_up [d, ffs], s_down [ffs, d].
    E_loc == E / axis_size(ep_axis).  Rows with row_valid=False (static-tick
    bucket padding) are routed nowhere and consume no expert capacity."""
    import os
    capacity_factor = float(os.environ.get("REPRO_MOE_CF", capacity_factor))
    T, d = x.shape
    E_loc = params["w_gate"].shape[0]
    NS = _axis_size(ep_axis)
    E = E_loc * NS

    w, idx, aux = route(x, params["router"], top_k)
    N = T * top_k
    flat_e = idx.reshape(N)
    flat_w = w.reshape(N)
    src = jnp.repeat(jnp.arange(T), top_k)
    if row_valid is not None:
        flat_e = jnp.where(row_valid[src], flat_e, E)     # invalid sentinel
    xs = x[src]                                           # [N, d]

    if NS > 1:
        # ---- EP: bucket by destination shard, all_to_all, compute, return
        cap_send = _round8(int(N / NS * capacity_factor) + 1)
        dest = flat_e // E_loc
        payload = jnp.concatenate(
            [xs, (flat_e % E_loc).astype(x.dtype)[:, None]], axis=-1)
        buf, order, sg, pos = _group_rows(payload, dest, NS, cap_send)
        valid = jnp.zeros((NS, cap_send, 1), x.dtype).at[sg, pos].set(
            jnp.ones((N, 1), x.dtype), mode="drop")
        buf = jnp.concatenate([buf, valid], axis=-1)
        rbuf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                                  tiled=True)
        rx = rbuf[..., :d].reshape(NS * cap_send, d)
        re = rbuf[..., d].reshape(NS * cap_send).astype(jnp.int32)
        rvalid = rbuf[..., d + 1].reshape(NS * cap_send) > 0.5
        re = jnp.where(rvalid, re, E_loc)                 # invalid -> dropped
        cap_e = _round8(int(NS * cap_send / E_loc * capacity_factor) + 1)
        ebuf, order2, sg2, pos2 = _group_rows(rx, re, E_loc, cap_e)
        y = expert_ffn(ebuf, params["w_gate"], params["w_up"], params["w_down"])
        ry = _ungroup_rows(y, order2, sg2, pos2)          # [NS*cap_send, d]
        ry = ry.reshape(NS, cap_send, d)
        yback = jax.lax.all_to_all(ry, ep_axis, split_axis=0, concat_axis=0,
                                   tiled=True)
        ys = _ungroup_rows(yback, order, sg, pos)         # [N, d]
    else:
        cap_e = _round8(int(N / E * capacity_factor) + 1)
        ebuf, order2, sg2, pos2 = _group_rows(xs, flat_e, E, cap_e)
        y = expert_ffn(ebuf, params["w_gate"], params["w_up"], params["w_down"])
        ys = _ungroup_rows(y, order2, sg2, pos2)          # [N, d]

    out = jnp.zeros((T, d), jnp.float32)
    out = out.at[src].add(flat_w[:, None] * ys.astype(jnp.float32))

    if "s_gate" in params:
        shared = (jax.nn.silu(x @ params["s_gate"]) * (x @ params["s_up"])) \
            @ params["s_down"]
        out = out + shared.astype(jnp.float32)
    return out.astype(x.dtype), aux


def moe_ref(x: jax.Array, params: Dict[str, jax.Array], *, top_k: int) -> jax.Array:
    """Dense per-expert oracle (no capacity drops) for correctness tests."""
    w, idx, _ = route(x, params["router"], top_k)
    E = params["router"].shape[-1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for e in range(E):
        ye = (jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_up"][e])) \
            @ params["w_down"][e]
        gate = jnp.sum(jnp.where(idx == e, w, 0.0), axis=-1)
        out = out + gate[:, None] * ye.astype(jnp.float32)
    if "s_gate" in params:
        out = out + ((jax.nn.silu(x @ params["s_gate"]) * (x @ params["s_up"]))
                     @ params["s_down"]).astype(jnp.float32)
    return out.astype(x.dtype)
