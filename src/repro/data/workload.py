"""Synthetic serving workloads matching the paper's evaluation datasets.

The paper samples ShareGPT (user/ChatGPT conversations) and Azure LLM
production traces; Fig. 11 reports Azure's inputs are 5.21x longer and
outputs 1.66x longer on average than ShareGPT's.  We synthesize log-normal
length distributions with those ratios and Poisson arrivals ("We mimic the
cloud service scenario and generate request arrival times using Poisson
distribution", §4.1).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    mean_input: float
    mean_output: float
    sigma: float = 0.9
    max_input: int = 32768
    max_output: int = 4096


SHAREGPT = WorkloadSpec("sharegpt", mean_input=330.0, mean_output=240.0)
AZURE = WorkloadSpec("azure", mean_input=330.0 * 5.21,
                     mean_output=240.0 * 1.66)

_SPECS = {"sharegpt": SHAREGPT, "azure": AZURE}


def get_workload(name: str) -> WorkloadSpec:
    return _SPECS[name]


def _lognormal(rng: np.random.Generator, mean: float, sigma: float,
               size: int) -> np.ndarray:
    mu = np.log(mean) - sigma**2 / 2.0
    return rng.lognormal(mu, sigma, size)


def sample_requests(
    spec: WorkloadSpec,
    num_requests: int,
    request_rate: float,
    *,
    seed: int = 0,
    vocab: int = 32000,
) -> List[Tuple[float, List[int], int]]:
    """Returns [(arrival_time, prompt_token_ids, output_len)] with Poisson
    arrivals at `request_rate` req/s."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(request_rate, 1e-9), num_requests)
    arrivals = np.cumsum(gaps)
    in_lens = np.clip(_lognormal(rng, spec.mean_input, spec.sigma,
                                 num_requests), 4, spec.max_input).astype(int)
    out_lens = np.clip(_lognormal(rng, spec.mean_output, spec.sigma,
                                  num_requests), 1, spec.max_output).astype(int)
    out = []
    for t, li, lo in zip(arrivals, in_lens, out_lens):
        prompt = rng.integers(0, vocab, int(li)).tolist()
        out.append((float(t), prompt, int(lo)))
    return out


# ---------------------------------------------------------------------------
# Prefix-heavy workloads (cross-request KV reuse)
#
# At production scale most traffic shares prefixes: every request from an
# application carries the same system prompt / few-shot template, and every
# turn of a conversation re-sends the whole history.  These generators model
# the two shapes so prefix caching and cache-aware routing have a measurable
# workload (benchmarks/fig_prefix_cache.py).
# ---------------------------------------------------------------------------

def shared_prefix_requests(
    num_requests: int,
    request_rate: float,
    *,
    num_pools: int = 4,
    prefix_len: int = 256,
    mean_suffix: float = 64.0,
    mean_output: float = 48.0,
    sigma: float = 0.6,
    max_suffix: int = 2048,
    max_output: int = 512,
    seed: int = 0,
    vocab: int = 32000,
) -> List[Tuple[float, List[int], int]]:
    """Shared-system-prompt pools: each request draws one of `num_pools`
    fixed `prefix_len`-token prefixes (an application's system prompt +
    few-shot template) followed by a fresh log-normal suffix (the user
    turn).  Poisson arrivals at `request_rate` req/s.

    Every request after the first in a pool can reuse `prefix_len` tokens
    of prefill if it lands on a replica that already served that pool —
    exactly the affinity signal cache-aware routing exploits."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, prefix_len).tolist()
                for _ in range(num_pools)]
    gaps = rng.exponential(1.0 / max(request_rate, 1e-9), num_requests)
    arrivals = np.cumsum(gaps)
    pools = rng.integers(0, num_pools, num_requests)
    suf_lens = np.clip(_lognormal(rng, mean_suffix, sigma, num_requests),
                       1, max_suffix).astype(int)
    out_lens = np.clip(_lognormal(rng, mean_output, sigma, num_requests),
                       1, max_output).astype(int)
    out = []
    for t, p, ls, lo in zip(arrivals, pools, suf_lens, out_lens):
        suffix = rng.integers(0, vocab, int(ls)).tolist()
        out.append((float(t), prefixes[int(p)] + suffix, int(lo)))
    return out


def _thinned_arrivals(rng: np.random.Generator, rate_fn, peak_rate: float,
                      duration: float) -> List[float]:
    """Non-homogeneous Poisson arrivals over [0, duration) by thinning: draw
    candidates at the constant `peak_rate` envelope, keep each candidate at
    time t with probability rate_fn(t)/peak_rate."""
    out: List[float] = []
    t = 0.0
    inv = 1.0 / max(peak_rate, 1e-9)
    while True:
        t += float(rng.exponential(inv))
        if t >= duration:
            return out
        if rng.random() * peak_rate < rate_fn(t):
            out.append(t)


def diurnal_requests(
    duration: float,
    *,
    base_rate: float,
    peak_rate: float,
    period: Optional[float] = None,
    mean_input: float = 64.0,
    mean_output: float = 32.0,
    sigma: float = 0.6,
    max_input: int = 2048,
    max_output: int = 512,
    seed: int = 0,
    vocab: int = 32000,
) -> List[Tuple[float, List[int], int]]:
    """Diurnal load: the arrival rate follows one (by default) full sinusoid
    cycle between `base_rate` (trough) and `peak_rate` over `duration`
    seconds, starting at the trough.  This is the canonical elastic-serving
    shape — a peak-provisioned static fleet idles through the trough while
    an autoscaled fleet tracks the curve (benchmarks/fig_autoscale.py)."""
    if peak_rate < base_rate:
        raise ValueError("peak_rate must be >= base_rate")
    period = duration if period is None else period
    rng = np.random.default_rng(seed)
    mid = (base_rate + peak_rate) / 2.0
    amp = (peak_rate - base_rate) / 2.0

    def rate(t: float) -> float:
        # trough at t=0, peak at t=period/2
        return mid - amp * np.cos(2.0 * np.pi * t / period)

    arrivals = _thinned_arrivals(rng, rate, peak_rate, duration)
    return _fill_lengths(rng, arrivals, mean_input, mean_output, sigma,
                         max_input, max_output, vocab)


def flash_crowd_requests(
    duration: float,
    *,
    base_rate: float,
    spike_rate: float,
    spike_start: float,
    spike_len: float,
    mean_input: float = 64.0,
    mean_output: float = 32.0,
    sigma: float = 0.6,
    max_input: int = 2048,
    max_output: int = 512,
    seed: int = 0,
    vocab: int = 32000,
) -> List[Tuple[float, List[int], int]]:
    """Flash crowd: steady `base_rate` with a step to `spike_rate` on
    [spike_start, spike_start + spike_len) — the worst case for reactive
    scaling (no leading edge to anticipate) and the soak tests' stressor."""
    if spike_rate < base_rate:
        raise ValueError("spike_rate must be >= base_rate")
    rng = np.random.default_rng(seed)

    def rate(t: float) -> float:
        in_spike = spike_start <= t < spike_start + spike_len
        return spike_rate if in_spike else base_rate

    arrivals = _thinned_arrivals(rng, rate, spike_rate, duration)
    return _fill_lengths(rng, arrivals, mean_input, mean_output, sigma,
                         max_input, max_output, vocab)


def _fill_lengths(rng: np.random.Generator, arrivals: List[float],
                  mean_input: float, mean_output: float, sigma: float,
                  max_input: int, max_output: int,
                  vocab: int) -> List[Tuple[float, List[int], int]]:
    n = len(arrivals)
    if n == 0:
        return []
    in_lens = np.clip(_lognormal(rng, mean_input, sigma, n),
                      4, max_input).astype(int)
    out_lens = np.clip(_lognormal(rng, mean_output, sigma, n),
                       1, max_output).astype(int)
    return [(float(t), rng.integers(0, vocab, int(li)).tolist(), int(lo))
            for t, li, lo in zip(arrivals, in_lens, out_lens)]


def multi_turn_requests(
    num_conversations: int,
    request_rate: float,
    *,
    mean_turns: float = 4.0,
    max_turns: int = 12,
    mean_user: float = 48.0,
    mean_output: float = 64.0,
    sigma: float = 0.6,
    max_user: int = 1024,
    max_output: int = 512,
    think_time: float = 2.0,
    seed: int = 0,
    vocab: int = 32000,
) -> List[Tuple[float, List[int], int]]:
    """Multi-turn chat: each conversation is a sequence of turns where turn
    k's prompt is the *entire* history so far (all previous user turns and
    synthetic assistant replies) plus a fresh user message — so all but the
    final user message is prefill a cache-holding replica skips.

    Conversations open with Poisson arrivals at `request_rate`; follow-up
    turns arrive an exponential `think_time` after the previous turn's
    deadline (history length / reading speed is not modeled — think time
    dominates).  The synthetic assistant reply appended to the history is
    `output_len` tokens drawn from the same rng, standing in for whatever
    the engine actually sampled (sim and engine runs stay workload-
    identical: arrivals depend only on the seed, not on served outputs)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(request_rate, 1e-9), num_conversations)
    starts = np.cumsum(gaps)
    out = []
    for c in range(num_conversations):
        turns = int(np.clip(rng.geometric(1.0 / max(mean_turns, 1.0)),
                            1, max_turns))
        history: List[int] = []
        t = float(starts[c])
        for _ in range(turns):
            user_len = int(np.clip(_lognormal(rng, mean_user, sigma, 1)[0],
                                   1, max_user))
            out_len = int(np.clip(_lognormal(rng, mean_output, sigma, 1)[0],
                                  1, max_output))
            user = rng.integers(0, vocab, user_len).tolist()
            prompt = history + user
            out.append((t, prompt, out_len))
            # synthetic assistant reply extends the next turn's history
            reply = rng.integers(0, vocab, out_len).tolist()
            history = prompt + reply
            t += float(rng.exponential(think_time))
    out.sort(key=lambda a: a[0])
    return out
