"""Synthetic serving workloads matching the paper's evaluation datasets.

The paper samples ShareGPT (user/ChatGPT conversations) and Azure LLM
production traces; Fig. 11 reports Azure's inputs are 5.21x longer and
outputs 1.66x longer on average than ShareGPT's.  We synthesize log-normal
length distributions with those ratios and Poisson arrivals ("We mimic the
cloud service scenario and generate request arrival times using Poisson
distribution", §4.1).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    mean_input: float
    mean_output: float
    sigma: float = 0.9
    max_input: int = 32768
    max_output: int = 4096


SHAREGPT = WorkloadSpec("sharegpt", mean_input=330.0, mean_output=240.0)
AZURE = WorkloadSpec("azure", mean_input=330.0 * 5.21,
                     mean_output=240.0 * 1.66)

_SPECS = {"sharegpt": SHAREGPT, "azure": AZURE}


def get_workload(name: str) -> WorkloadSpec:
    return _SPECS[name]


def _lognormal(rng: np.random.Generator, mean: float, sigma: float,
               size: int) -> np.ndarray:
    mu = np.log(mean) - sigma**2 / 2.0
    return rng.lognormal(mu, sigma, size)


def sample_requests(
    spec: WorkloadSpec,
    num_requests: int,
    request_rate: float,
    *,
    seed: int = 0,
    vocab: int = 32000,
) -> List[Tuple[float, List[int], int]]:
    """Returns [(arrival_time, prompt_token_ids, output_len)] with Poisson
    arrivals at `request_rate` req/s."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(request_rate, 1e-9), num_requests)
    arrivals = np.cumsum(gaps)
    in_lens = np.clip(_lognormal(rng, spec.mean_input, spec.sigma,
                                 num_requests), 4, spec.max_input).astype(int)
    out_lens = np.clip(_lognormal(rng, spec.mean_output, spec.sigma,
                                  num_requests), 1, spec.max_output).astype(int)
    out = []
    for t, li, lo in zip(arrivals, in_lens, out_lens):
        prompt = rng.integers(0, vocab, int(li)).tolist()
        out.append((float(t), prompt, int(lo)))
    return out
