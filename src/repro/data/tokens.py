"""Synthetic LM training data: a deterministic Markov-ish token stream with
learnable structure (so tiny-model training loss visibly drops), packed into
the micro-batched [M, mbg, T] layout the train step consumes."""

from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class SyntheticLM:
    """Order-1 Markov chain over the vocab with a few strong transitions —
    enough signal for loss to fall fast, fully reproducible."""

    def __init__(self, vocab: int, seed: int = 0, concentration: float = 20.0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        # sparse-ish rows: each token strongly prefers ~4 successors
        self.next_tokens = rng.integers(0, vocab, size=(vocab, 4))
        self.rng = rng

    def sample(self, n: int) -> np.ndarray:
        out = np.empty(n, np.int32)
        t = int(self.rng.integers(0, self.vocab))
        for i in range(n):
            out[i] = t
            if self.rng.random() < 0.85:
                t = int(self.next_tokens[t, self.rng.integers(0, 4)])
            else:
                t = int(self.rng.integers(0, self.vocab))
        return out


def batches(vocab: int, M: int, mbg: int, T: int, *, seed: int = 0
            ) -> Iterator[Dict[str, np.ndarray]]:
    gen = SyntheticLM(vocab, seed)
    while True:
        flat = gen.sample(M * mbg * (T + 1)).reshape(M, mbg, T + 1)
        yield {"tokens": flat[..., :-1].astype(np.int32),
               "labels": flat[..., 1:].astype(np.int32)}
