"""Trip-count-aware HLO cost analysis.

XLA's built-in `compiled.cost_analysis()` counts a while-loop body ONCE —
with the pipeline schedule, layer stacks, flash KV blocks and the loss all
expressed as `lax.scan`, that undercounts FLOPs/bytes by the product of trip
counts (we measured 14-30x).  Fortunately the optimized HLO annotates every
loop with ``backend_config={"known_trip_count":{"n": ...}}``.

This module parses the post-optimization HLO text into a computation call
graph and folds costs bottom-up, scaling loop bodies by their known trip
count.  Costs:
  * flops — `dot` ops: 2 x |result| x (contracted extent); elementwise ops
    in fusions are amortized (FLOP-irrelevant next to the dots).
  * bytes — per *unfused* op and per fusion boundary: operands + result
    (XLA's own convention); gathers count touched bytes (2x result +
    indices), scatters 2x updates + indices (pages written, not the pool).
  * collective_bytes — per collective op: operand bytes, scaled by the
    enclosing loops' trip counts.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> Tuple[int, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    n = 1
    for d in dims:
        n *= d
    return n, dims


@dataclass
class Op:
    name: str
    type_str: str
    kind: str
    operands: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # %name -> type


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {
        op: 0.0 for op in _COLL_OPS})

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in self.coll:
            self.coll[k] += other.coll[k]
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n,
                    {k: v * n for k, v in self.coll.items()})


_KIND_RE = re.compile(r"\s*([a-zA-Z0-9\-_]+)\(")


def _balanced(s: str, start: int) -> int:
    """Index just past the ')' matching the '(' at `start`."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        s = line.rstrip()
        if not s:
            continue
        if not s.startswith(" ") and ("{" in s) and ("%" in s or
                                                     s.startswith("ENTRY")):
            m = re.match(r"^(?:ENTRY\s+)?%?([^\s(]+)\s*\(", s)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
            continue
        if s.strip() == "}" or cur is None:
            continue
        t = s.strip()
        if t.startswith("ROOT "):
            t = t[5:]
        if not t.startswith("%") or " = " not in t:
            continue
        name, rest = t[1:].split(" = ", 1)
        # type: balanced tuple "(...)" (may contain /*index=N*/ comments)
        # or "dtype[dims]{layout}"
        if rest.startswith("("):
            tend = _balanced(rest, 0)
        else:
            m = re.match(r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?", rest)
            if not m:
                continue
            tend = m.end()
        type_str = rest[:tend]
        m = _KIND_RE.match(rest[tend:])
        if not m:
            continue
        kind = m.group(1)
        args_start = tend + m.end()
        args_end = _balanced(rest, args_start - 1)
        args = rest[args_start : args_end - 1]
        attrs = rest[args_end:]
        operands = re.findall(r"%([^\s,()]+)", args)
        cur.symbols[name] = type_str
        cur.ops.append(Op(name, type_str, kind, operands, attrs))
    return comps


def _dot_flops(op: Op, comp: Computation) -> float:
    n_out, _ = _shape_elems(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    if not m or not op.operands:
        return 2.0 * n_out  # fallback
    lhs_type = comp.symbols.get(op.operands[0], "")
    _, lhs_dims = _shape_elems(lhs_type)
    contract = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2.0 * n_out * contract


def _trip_count(op: Op) -> float:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.attrs)
    return float(m.group(1)) if m else 1.0


def _called(op: Op, key: str) -> Optional[str]:
    m = re.search(rf"{key}=%([^\s,)]+)", op.attrs)
    return m.group(1) if m else None


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[str, Cost] = {}

    _PASSTHROUGH = {"convert", "bitcast", "copy", "parameter", "tuple",
                    "get-tuple-element", "reshape", "transpose", "constant",
                    "broadcast", "iota", "slice", "concatenate", "pad"}

    def _fusion_kind(self, callee: str) -> str:
        """Classify a fused computation for TPU-faithful byte accounting.

        'cast'    — only converts/bitcasts/copies & co.: XLA:CPU upcasts bf16
                    math to f32 and hoists *pool-wide* converts out of loops;
                    a TPU compile consumes bf16 natively — free there.
        'dus'     — real work is dynamic-update-slice(s): in-place on TPU,
                    traffic = 2x the update regions, not the whole buffer.
        'gather'  — real work is gathers/dynamic-slices: traffic = 2x the
                    fusion result (touched pages), not the whole pool operand.
        'plain'   — anything else: operands + result at the fusion boundary.
        """
        comp = self.comps.get(callee)
        if comp is None:
            return "plain"
        real = {o.kind for o in comp.ops} - self._PASSTHROUGH
        if not real:
            return "cast"
        idx_arith = {"select", "add", "subtract", "multiply", "compare",
                     "and", "or", "clamp", "minimum", "maximum"}
        if real <= {"dynamic-update-slice"} | idx_arith and \
                "dynamic-update-slice" in real:
            return "dus"
        if real <= {"gather", "dynamic-slice"} | idx_arith and \
                (real & {"gather", "dynamic-slice"}):
            return "gather"
        return "plain"

    def _dus_update_bytes(self, callee: str) -> float:
        comp = self.comps.get(callee)
        if comp is None:
            return 0.0
        total = 0.0
        for op in comp.ops:
            if op.kind != "dynamic-update-slice":
                continue
            # dynamic-update-slice(operand, update, idx...) — update = opnd 1
            if len(op.operands) >= 2:
                total += 2.0 * _shape_bytes(
                    comp.symbols.get(op.operands[1], ""))
        return total if total else _shape_bytes(comp.ops[-1].type_str) * 0.1

    def _op_bytes(self, op: Op, comp: Computation) -> float:
        if op.kind in _SKIP_BYTES_OPS:
            return 0.0
        res = _shape_bytes(op.type_str)
        if op.kind == "gather":
            idx = (_shape_bytes(comp.symbols.get(op.operands[1], ""))
                   if len(op.operands) > 1 else 0)
            return 2.0 * res + idx
        if op.kind in ("scatter", "dynamic-update-slice"):
            upd = (_shape_bytes(comp.symbols.get(op.operands[-2], ""))
                   if len(op.operands) >= 2 else res)
            if op.kind == "scatter" and len(op.operands) >= 3:
                upd = _shape_bytes(comp.symbols.get(op.operands[2], ""))
                idx = _shape_bytes(comp.symbols.get(op.operands[1], ""))
                return 2.0 * upd + idx
            return 2.0 * upd
        if op.kind == "fusion":
            callee = _called(op, "calls")
            fk = self._fusion_kind(callee) if callee else "plain"
            if fk == "cast":
                return 0.0
            if fk == "dus":
                return self._dus_update_bytes(callee)
            if fk == "gather":
                return 2.0 * res
        opnd = sum(_shape_bytes(comp.symbols.get(o, ""))
                   for o in op.operands)
        return res + opnd

    def computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        self._memo[name] = total          # break cycles defensively
        if comp is None:
            return total
        for op in comp.ops:
            if op.kind == "while":
                body = _called(op, "body")
                cond = _called(op, "condition")
                n = _trip_count(op)
                inner = Cost()
                if body:
                    inner += self.computation_cost(body)
                if cond:
                    inner += self.computation_cost(cond)
                total += inner.scaled(n)
                continue
            if op.kind == "conditional":
                branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"true_computation=%([^\s,)]+)|"
                                      r"false_computation=%([^\s,)]+))",
                                      op.attrs)
                names: List[str] = []
                for grp in branches:
                    for g in grp:
                        if g:
                            names.extend(re.findall(r"%?([^\s,%]+)", g))
                if names:
                    costs = [self.computation_cost(n) for n in names]
                    best = max(costs, key=lambda c: c.flops + c.bytes)
                    total += best
                continue
            if op.kind == "fusion" or op.kind == "call":
                callee = _called(op, "calls") or _called(op, "to_apply")
                if callee:
                    inner = self.computation_cost(callee)
                    # fusion boundary traffic = operands + result; internal
                    # elementwise bytes stay in registers
                    total += Cost(inner.flops, 0.0, inner.coll)
                total.bytes += self._op_bytes(op, comp)
                continue
            base = op.kind.replace("-start", "").replace("-done", "")
            if base in _COLL_OPS:
                if op.kind.endswith("-done"):
                    continue
                opnd = sum(_shape_bytes(comp.symbols.get(o, ""))
                           for o in op.operands)
                if opnd == 0:
                    opnd = _shape_bytes(op.type_str)
                total.coll[base] += opnd
                total.bytes += self._op_bytes(op, comp)
                continue
            if op.kind == "dot" or op.kind == "convolution":
                total.flops += _dot_flops(op, comp)
            total.bytes += self._op_bytes(op, comp)
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        # the entry computation is the one never referenced by others
        referenced = set()
        for comp in self.comps.values():
            for op in comp.ops:
                for key in ("calls", "to_apply", "body", "condition"):
                    c = _called(op, key)
                    if c:
                        referenced.add(c)
        entries = [n for n in self.comps if n not in referenced]
        total = Cost()
        # heuristics: prefer a computation containing 'main'/'entry'
        pick = None
        for n in entries:
            if "main" in n or "entry" in n.lower():
                pick = n
                break
        if pick is None and entries:
            pick = max(entries,
                       key=lambda n: len(self.comps[n].ops))
        if pick:
            total += self.computation_cost(pick)
        return total


def analyse_hlo_text(text: str) -> dict:
    cost = HloCostModel(text).entry_cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": sum(cost.coll.values()),
        "collectives": {k: v for k, v in cost.coll.items()},
    }
