"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), all *per chip* (the SPMD module XLA
compiles and reports on is the per-device program):

    compute    = HLO_FLOPs / peak_FLOP/s
    memory     = HLO_bytes / HBM_bw
    collective = collective_operand_bytes / ICI_link_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (from the assignment).
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in (post-optimization) HLO.

    Operand types are printed inline in the op's argument list; we take all
    shapes appearing *inside the parens* of the collective call.  `-start`
    variants are counted once (`-done` carries no new payload).
    """
    out: Dict[str, int] = {op: 0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.search(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start)?\(", s)
        if not m:
            continue
        op = m.group(1)
        args = s[m.end():]
        # strip trailing attributes (channel_id etc.) — operands end at ')'
        depth, end = 1, len(args)
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        arg_str = args[:end]
        total = 0
        for dt, dims in _SHAPE_RE.findall(arg_str):
            if dt in _DTYPE_BYTES:
                total += _shape_bytes(dt, dims)
        if total == 0:
            # fallback: result type at line start
            mres = _SHAPE_RE.search(s.split("=")[0] + "=" + s.split("=", 1)[1][:80])
            if mres:
                total = _shape_bytes(mres.group(1), mres.group(2))
        out[op] += total
    return out


@dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # per chip
    hlo_bytes: float             # per chip
    collective_bytes: float      # per chip
    collective_breakdown: Dict[str, int]
    model_flops_per_chip: float  # analytic "useful" flops
    per_device_memory_bytes: float
    notes: str = ""

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops_per_chip / self.hlo_flops

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of compute roofline: time the chip *must*
        spend on useful math over the time the program takes at the
        bound (dominant term), assuming perfect overlap of the rest."""
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        if t_bound <= 0:
            return 0.0
        return (self.model_flops_per_chip / PEAK_FLOPS) / t_bound

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


# ----------------------------------------------------------------------------
# Analytic model FLOPs (the "useful work" numerator)
# ----------------------------------------------------------------------------

def param_count(cfg, active_only: bool = False) -> float:
    """Analytic parameter count from the config (MoE: active experts only
    when `active_only`)."""
    from repro.configs.base import BlockKind
    per = cfg.params_per_layer_estimate()
    total = 0.0
    for bs in cfg.pattern:
        for _ in range(bs.repeat):
            k = bs.kind
            if k in (BlockKind.ATTN_MLP, BlockKind.ENC_LAYER):
                total += per["attn"] + per["mlp"]
            elif k == BlockKind.DEC_LAYER:
                total += 2 * per["attn"] + per["mlp"]
            elif k == BlockKind.MLA_MLP:
                total += per["attn"] + per["mlp"]
            elif k == BlockKind.ATTN_MOE:
                total += per["attn"] + (per["moe_active"] if active_only
                                        else per["moe"])
            elif k == BlockKind.MAMBA_MLP:
                total += per["mamba"] + per["mlp"]
            elif k == BlockKind.MAMBA_MOE:
                total += per["mamba"] + (per["moe_active"] if active_only
                                         else per["moe"])
            elif k == BlockKind.RWKV:
                total += per["rwkv"]
    total *= cfg.plan.pp
    total += 2 * cfg.vocab_size * cfg.d_model
    return total


def model_flops(cfg, shape, chips: int, kind: str) -> float:
    """6·N_active·tokens (train) or 2·N_active·tokens (serve fwd), per chip.

    Decode cells process one token per resident sequence per *pipeline
    traversal*; a single tick advances 1/pp of the sequences, so per-tick
    useful flops = 2·N·(batch/pp) — which is what one lowered tick does."""
    n_active = param_count(cfg, active_only=True)
    if kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens / chips
    if kind == "prefill":
        # one tick prefills `C` tokens per replica on each stage's resident
        # micro-batch: per-chip useful = 2·(N/pp)·C·... == 2·N·C·D / chips
        data = 16
        tokens_per_tick = 2048 * data          # C per replica x replicas
        return 2.0 * n_active * tokens_per_tick / chips
    # decode: one tick decodes Sd rows per (stage, replica)
    data = 16
    per_replica = max(1, -(-shape.global_batch // data))
    sd = max(1, -(-per_replica // cfg.plan.pp))
    tokens_per_tick = sd * cfg.plan.pp * data   # all stages advance their mb
    return 2.0 * n_active * tokens_per_tick / chips


def render_table(cells) -> str:
    hdr = (f"| arch | shape | mesh | t_comp(ms) | t_mem(ms) | t_coll(ms) | "
           f"bound | useful | roofline |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for c in cells:
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | {c.t_compute*1e3:.3f} | "
            f"{c.t_memory*1e3:.3f} | {c.t_collective*1e3:.3f} | "
            f"{c.bottleneck} | {c.useful_ratio:.2f} | "
            f"{c.roofline_fraction:.2%} |")
    return "\n".join(rows)
