"""Cluster-scale elasticity: the autoscaler policy on the router control
plane (DESIGN.md §16).

gLLM balances work *within* a fleet; production traffic also requires the
fleet itself to track load — diurnal swings and flash crowds change the
request rate by integer factors, and a peak-sized static fleet burns
replica-hours all night to stay ready for noon.  `AutoscalePolicy` closes
that loop one level above `RebalancePolicy`: the router's periodic control
tick measures fleet *pressure* (waiting-queue depth and projected-KV
occupancy — the same signals Token Throttling and `balance_score` already
read), smooths it with an EWMA, and

* **scales up** when sustained pressure exceeds `up_threshold` — new
  replicas come from a `replica_factory` the builder supplies (sim
  backend: a fresh `PipelineSimulator` from the spec's base geometry);
* **scales down by draining**: the victim is masked from admission, its
  waiting requests are stolen and its resident prefill/decode state
  live-migrated through the §9/§15 migration plane, and only a fully
  empty replica is retired.  Role-aware: the last prefill- or
  decode-capable replica of a disaggregated fleet is never drained.

Hysteresis comes from the distinct up/down thresholds plus per-direction
cooldowns; both transitions are recorded in the trace streams (`scale_up` /
`drain` / `retire` record kinds, trace schema 1.6) so elastic runs replay
byte-identically.

This module stays import-light (policy data + pure pressure/attainment
math) so the spec layer can depend on it; the passes themselves live in
`ReplicaRouter` next to the rebalance/handoff planes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import SLO_BATCH, SLO_INTERACTIVE


@dataclass(frozen=True)
class AutoscalePolicy:
    """When the fleet grows and shrinks.

    Pressure is normalized so 1.0 means "each replica is carrying exactly
    its target load": a replica at `target_queue` waiting requests — or
    with its projected KV headroom at the stall activation point —
    contributes 1.0.  The EWMA over control passes (`ewma_alpha`) plus the
    threshold gap (`up_threshold` > `down_threshold`) and per-direction
    cooldowns give the loop hysteresis: a single bursty pass neither grows
    the fleet nor starts a drain, and a freshly-grown fleet is given
    `up_cooldown` seconds to absorb the backlog before growing again.

    Scale-up is proportional (up to `max_step_up` replicas per pass: a
    flash crowd doubling the load should not be answered one replica per
    interval); scale-down always drains exactly one replica per decision —
    shrinking is cheap to do again next pass and expensive to get wrong.
    `drain_batch` caps how many requests a single pass moves off a
    draining victim (steals + migrations), bounding per-tick control work.
    """

    interval: float = 0.5
    min_replicas: int = 1
    max_replicas: int = 8
    target_queue: float = 4.0
    up_threshold: float = 1.0
    down_threshold: float = 0.25
    ewma_alpha: float = 0.4
    up_cooldown: float = 1.0
    down_cooldown: float = 4.0
    max_step_up: int = 8
    drain_batch: int = 16

    def __post_init__(self) -> None:
        if self.min_replicas < 1:
            raise ValueError("AutoscalePolicy.min_replicas must be >= 1")
        if self.max_replicas < self.min_replicas:
            raise ValueError("AutoscalePolicy.max_replicas must be >= "
                             "min_replicas")
        if not self.down_threshold < self.up_threshold:
            raise ValueError("hysteresis requires down_threshold < "
                             "up_threshold")
        if self.interval <= 0.0:
            raise ValueError("AutoscalePolicy.interval must be positive")


@dataclass
class AutoscaleStats:
    """Counters + the scaling event log (surfaced through
    `LLMServer.stats()` / `GET /v1/stats`; `replica_seconds` integrates
    fleet size over the event log — the cost axis fig_autoscale trades
    against attainment)."""

    passes: int = 0
    scale_ups: int = 0          # scale-up decisions
    replicas_added: int = 0
    drains_started: int = 0
    retired: int = 0
    drain_moves: int = 0        # steals + migrations forced by drains
    rehomed: int = 0            # in-transit deliveries re-pointed at flush
    # (time, "scale_up" | "drain" | "retire", fleet size after the event)
    events: List[Tuple[float, str, int]] = field(default_factory=list)

    def note(self, now: float, kind: str, fleet_size: int) -> None:
        self.events.append((now, kind, fleet_size))

    def replica_seconds(self, start_size: int, start: float,
                        end: float) -> float:
        """Integral of serving fleet size over [start, end] given the event
        log (draining replicas still count — they hold state and burn the
        replica until retired)."""
        total = 0.0
        t, n = start, start_size
        for at, kind, size in self.events:
            if kind == "drain":
                continue        # fleet size changes at retire, not drain
            at = min(max(at, start), end)
            total += n * (at - t)
            t, n = at, size
        total += n * (max(end, t) - t)
        return total


# ---------------------------------------------------------------------------
# Pressure: the signal the scale decisions run on
# ---------------------------------------------------------------------------

def _remaining_decode_growth(sched) -> int:
    # forward-looking KV growth of the resident decode population (kept
    # local: router.py imports this module, not the other way round)
    return sum(r.sampling.max_new_tokens - r.num_output_tokens
               for r in sched.running_decode)


def replica_pressure(replica, policy: AutoscalePolicy) -> float:
    """One replica's load, normalized to its own capacity: the max of

    * waiting-queue depth over `target_queue` (admission backlog — the
      signal a TTFT SLO dies by), and
    * projected-KV shortfall relative to the UT stall activation band
      (decode residents keep appending; a pool *heading* for its stall
      is pressure even while the queue is short).

    0 is idle, 1 is "exactly at target", >1 is sustained overload.
    """
    sched = replica.scheduler
    queue = len(sched.waiting) / max(policy.target_queue, 1e-9)
    pool = sched.kv.num_pages * sched.kv.page_size
    projected = sched.kv.kv_free_rate - _remaining_decode_growth(sched) / pool
    activation = min(1.0, 4.0 * sched.cfg.kv_threshold)
    shortfall = max(0.0, activation - projected) / max(activation, 1e-9)
    return max(queue, shortfall)


def fleet_pressure(replicas: Sequence[Any], policy: AutoscalePolicy) -> float:
    """Mean per-replica pressure — the quantity the EWMA smooths.  The mean
    (not the max) on purpose: one hot replica is the *rebalance* plane's
    problem; the fleet only needs to grow when the whole fleet is loaded."""
    if not replicas:
        return 0.0
    return float(np.mean([replica_pressure(r, policy) for r in replicas]))


def scale_up_step(n: int, ewma: float, policy: AutoscalePolicy) -> int:
    """How many replicas a scale-up decision adds: proportional to the
    overload factor (pressure 2.0 at threshold 1.0 wants ~n more replicas),
    clamped to [1, max_step_up] and the max_replicas ceiling."""
    want = int(np.ceil(n * (ewma / max(policy.up_threshold, 1e-9) - 1.0)))
    return max(0, min(max(want, 1), policy.max_step_up,
                      policy.max_replicas - n))


# ---------------------------------------------------------------------------
# Per-class SLO attainment — the shared report (GET /v1/stats,
# fig_autoscale, fig_disagg all call this one definition)
# ---------------------------------------------------------------------------

# Default per-class targets (sim seconds): interactive requests are TTFT-
# and TBT-bound; batch requests only need a sane token cadence.  Benchmarks
# may pass their own table; the stats surface reports against these.
DEFAULT_SLOS: Dict[str, Dict[str, float]] = {
    SLO_INTERACTIVE: {"ttft": 2.0, "tbt": 0.02},
    SLO_BATCH: {"ttft": 20.0, "tbt": 0.30},
}


def request_attains(req, slo: Dict[str, float]) -> bool:
    """One request against one SLO row: TTFT within `slo["ttft"]` and mean
    time-between-tokens (TPOT) within `slo["tbt"]`.  A request that never
    produced a first token does not attain."""
    ttft = req.metrics.ttft()
    if ttft is None or ttft > slo["ttft"]:
        return False
    tbt = req.metrics.tpot(req.num_output_tokens)
    return (tbt or 0.0) <= slo["tbt"]


def attainment_by_class(finished: Sequence[Any],
                        slos: Optional[Dict[str, Dict[str, float]]] = None,
                        *, elapsed: Optional[float] = None
                        ) -> Dict[str, Dict[str, float]]:
    """{slo_class: {n, attained, attainment, ttft_p95, tbt_p95[, goodput]}}
    over finished requests.  `attainment` is the fraction of the class's
    requests meeting both their TTFT and TBT targets (1.0 for an empty
    class — nothing violated); `goodput` (attaining requests per second)
    is included iff `elapsed` is given."""
    slos = slos if slos is not None else DEFAULT_SLOS
    out: Dict[str, Dict[str, float]] = {}
    for cls, slo in slos.items():
        reqs = [r for r in finished if r.sampling.slo_class == cls]
        ttfts = [r.metrics.ttft() for r in reqs
                 if r.metrics.ttft() is not None]
        tbts = [r.metrics.tpot(r.num_output_tokens) for r in reqs
                if r.metrics.tpot(r.num_output_tokens) is not None]
        ok = sum(1 for r in reqs if request_attains(r, slo))
        row: Dict[str, float] = {
            "n": len(reqs),
            "attained": ok,
            "attainment": ok / len(reqs) if reqs else 1.0,
            "ttft_p95": float(np.quantile(ttfts, 0.95)) if ttfts else 0.0,
            "tbt_p95": float(np.quantile(tbts, 0.95)) if tbts else 0.0,
        }
        if elapsed is not None:
            row["goodput"] = ok / max(elapsed, 1e-9)
        out[cls] = row
    return out
