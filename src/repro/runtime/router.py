"""Globally-balanced multi-replica routing (DESIGN.md §1.3).

gLLM's thesis is that *global* state — pending prefill tokens (#WP), decode
population (#RD), KV idle rate — should drive scheduling.  Token Throttling
applies that inside one replica; `ReplicaRouter` applies the same principle
one level up: it fronts N independent `TickLoop` replicas (real engines or
simulators, possibly heterogeneous in speed or pipeline depth) and routes
each arriving request to the replica whose global balance score is lowest.

The score is computed from exactly the scheduler signals Token Throttling
uses, so imbalance is *discovered* — a slow or KV-saturated replica
accumulates #WP/#RD backlog and sheds load without any static capacity
configuration (weights can still be supplied when capacities are known).

`SimCluster` drives N `PipelineSimulator` replicas in causally-consistent
virtual time: before each routing decision every replica is advanced to the
arrival instant, so the router sees the state a real frontend would.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import Request, SamplingParams


class RoutingPolicy(enum.Enum):
    ROUND_ROBIN = "rr"
    BALANCED = "balanced"


@dataclass(frozen=True)
class BalanceWeights:
    """Converts the scheduler's global signals into one load scalar.

    A decode-resident request represents future work (its remaining output
    tokens) — `decode_tokens` is the prefill-token-equivalent charged per
    resident decode; calibrate it to ~E[remaining output length] of the
    workload (the default suits chat-style ~240-token outputs).
    `kv_pressure` inflates the score of replicas close to the UT stall
    point, where admission would trigger the throttle guard or
    preemption-recompute churn (paper Fig. 15's no-UT pathology, avoided
    cluster-wide).  The pressure is *threshold-relative* — it engages below
    `kv_activation_margin` times the replica's own KV threshold — so a
    structurally smaller pool is not penalized while it still has headroom
    (the asymmetric-KV heterogeneity case of fig_router_balance.py).
    """

    decode_tokens: float = 128.0
    kv_pressure: float = 4.0
    kv_activation_margin: float = 4.0


@dataclass(frozen=True)
class ReplicaSnapshot:
    """The router's view of one replica at a routing instant."""

    waiting_prefill_tokens: int
    running_decode: int
    kv_free_rate: float
    kv_threshold: float = 0.05      # the replica scheduler's UT stall point

    @staticmethod
    def of(replica) -> "ReplicaSnapshot":
        sched = replica.scheduler
        return ReplicaSnapshot(
            waiting_prefill_tokens=sched.num_waiting_prefill_tokens,
            running_decode=sched.num_running_decode,
            kv_free_rate=sched.kv.kv_free_rate,
            kv_threshold=sched.cfg.kv_threshold,
        )


def balance_score(snap: ReplicaSnapshot, prompt_tokens: int,
                  weights: BalanceWeights, capacity: float = 1.0) -> float:
    """Estimated completion burden of placing `prompt_tokens` on a replica:
    pending work (incl. the candidate request) per unit capacity, inflated
    by proximity to the KV stall point.  Lower is better."""
    load = (snap.waiting_prefill_tokens + prompt_tokens
            + weights.decode_tokens * snap.running_decode)
    activation = min(1.0, weights.kv_activation_margin * snap.kv_threshold)
    shortfall = max(0.0, activation - snap.kv_free_rate) / max(activation,
                                                               1e-9)
    pressure = 1.0 + weights.kv_pressure * shortfall
    return load * pressure / max(capacity, 1e-9)


class ReplicaRouter:
    """Fronts N serving replicas; routes by global balance score.

    A replica is anything exposing `scheduler` (a `PipelineScheduler`);
    engine replicas additionally expose `add_request`/`step`/`has_work`/
    `busy` so the router can serve as a drop-in engine for `AsyncFrontend`
    and the launchers.
    """

    def __init__(
        self,
        replicas: Sequence[Any],
        policy: str | RoutingPolicy = RoutingPolicy.BALANCED,
        *,
        weights: Optional[BalanceWeights] = None,
        capacities: Optional[Sequence[float]] = None,
        trace_path: Optional[str] = None,
    ) -> None:
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        self.replicas = list(replicas)
        self.policy = RoutingPolicy(policy)
        self.weights = weights or BalanceWeights()
        n = len(self.replicas)
        self.capacities = list(capacities) if capacities is not None \
            else [1.0] * n
        if len(self.capacities) != n:
            raise ValueError("one capacity per replica")
        self._rr_next = 0
        self.routed_counts = [0] * n
        self._trace = None
        if trace_path is not None:
            self.open_trace(trace_path)

    # ---------------------------------------------------------------- tracing
    def open_trace(self, sink) -> None:
        """Log every placement decision (per-replica scores + chosen index)
        to a `gllm-route` JSONL stream — the routing counterpart of the
        per-replica tick traces (runtime/trace.py)."""
        from repro.runtime.trace import (ROUTE_SCHEMA, SCHEMA_MAJOR,
                                         SCHEMA_MINOR, TraceWriter)
        assert self._trace is None, "router trace already open"
        self._trace = TraceWriter(sink)
        self._trace.write({
            "kind": "header",
            "schema": ROUTE_SCHEMA,
            "version": [SCHEMA_MAJOR, SCHEMA_MINOR],
            "replicas": len(self.replicas),
            "policy": self.policy.value,
            "capacities": list(self.capacities),
        })

    def close_trace(self) -> None:
        if self._trace is not None:
            self._trace.close()

    # ---------------------------------------------------------------- routing
    def scores(self, prompt_tokens: int = 0) -> List[float]:
        return [balance_score(ReplicaSnapshot.of(r), prompt_tokens,
                              self.weights, c)
                for r, c in zip(self.replicas, self.capacities)]

    def select(self, prompt_tokens: int = 0) -> int:
        """Index of the replica the next request should land on."""
        scores: Optional[List[float]] = None
        if self.policy is RoutingPolicy.ROUND_ROBIN:
            i = self._rr_next
            self._rr_next = (self._rr_next + 1) % len(self.replicas)
        else:
            scores = self.scores(prompt_tokens)
            i = int(np.argmin(scores))
        self.routed_counts[i] += 1
        if self._trace is not None:
            self._trace.write({"kind": "route", "n": prompt_tokens,
                               "scores": scores, "replica": i})
        return i

    # ------------------------------------------------- engine-cluster surface
    def add_request(self, prompt: Sequence[int],
                    sampling: Optional[SamplingParams] = None,
                    request_id: Optional[str] = None, **kw) -> Request:
        i = self.select(len(prompt))
        return self.replicas[i].add_request(prompt, sampling, request_id,
                                            **kw)

    @property
    def scheduler(self):
        """Single-replica compatibility: the scheduler when fronting one
        replica (ambiguous otherwise)."""
        if len(self.replicas) != 1:
            raise AttributeError(
                "ReplicaRouter fronts multiple replicas; inspect "
                ".replicas[i].scheduler")
        return self.replicas[0].scheduler

    @property
    def has_work(self) -> bool:
        return any(r.has_work for r in self.replicas)

    @property
    def busy(self) -> bool:
        return any(r.busy for r in self.replicas)

    def step(self) -> List[Request]:
        """One tick on every replica that has work (the single-process
        analogue of N independent driver loops)."""
        out: List[Request] = []
        for r in self.replicas:
            if r.has_work or r.busy:
                out.extend(r.step())
        return out

    def drain(self, max_ticks: int = 100000) -> List[Request]:
        out: List[Request] = []
        t = 0
        while (self.has_work or self.busy) and t < max_ticks:
            out.extend(self.step())
            t += 1
        return out

    @property
    def finished(self) -> List[Request]:
        out: List[Request] = []
        for r in self.replicas:
            out.extend(r.finished)
        return out


class SimCluster:
    """N `PipelineSimulator` replicas behind a `ReplicaRouter`, driven in
    causally-consistent virtual time: each arrival first advances every
    replica to the arrival instant, then routes on the resulting state."""

    def __init__(self, sims: Sequence[Any], router: ReplicaRouter,
                 *, trace_dir: Optional[str] = None) -> None:
        self.sims = list(sims)
        self.router = router
        if trace_dir is not None:
            # one tick trace per replica + the router's placement stream —
            # together they capture the whole cluster run for offline replay
            import os
            os.makedirs(trace_dir, exist_ok=True)
            for i, sim in enumerate(self.sims):
                sim.attach_trace(
                    os.path.join(trace_dir, f"replica{i}.trace.jsonl"))
            if router._trace is None:
                router.open_trace(
                    os.path.join(trace_dir, "router.trace.jsonl"))

    def run(self, arrivals: Iterable[Tuple[float, List[int], int]],
            until: float = float("inf")) -> List[Request]:
        """arrivals: (time, prompt_tokens, output_len), any order.
        Returns all finished requests across replicas."""
        for t, prompt, out_len in sorted(arrivals, key=lambda a: a[0]):
            if t > until:
                break
            for sim in self.sims:
                sim.run_until(t)
            i = self.router.select(len(prompt))
            self.sims[i].inject_request(t, prompt, out_len)
        for sim in self.sims:
            sim.run(until)
        return self.finished

    @property
    def finished(self) -> List[Request]:
        out: List[Request] = []
        for sim in self.sims:
            out.extend(sim.metrics.finished)
        return out

    # ------------------------------------------------------------- aggregates
    def ttft_quantile(self, q: float) -> float:
        vals = [r.metrics.ttft() for r in self.finished
                if r.metrics.ttft() is not None]
        return float(np.quantile(vals, q)) if vals else 0.0

    def mean_ttft(self) -> float:
        vals = [r.metrics.ttft() for r in self.finished
                if r.metrics.ttft() is not None]
        return float(np.mean(vals)) if vals else 0.0

    def throughput(self) -> float:
        return float(sum(s.metrics.throughput() for s in self.sims))
