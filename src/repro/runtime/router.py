"""Globally-balanced multi-replica routing + control plane (DESIGN.md §1.3, §9).

gLLM's thesis is that *global* state — pending prefill tokens (#WP), decode
population (#RD), KV idle rate — should drive scheduling.  Token Throttling
applies that inside one replica; `ReplicaRouter` applies the same principle
one level up: it fronts N independent `TickLoop` replicas (real engines or
simulators, possibly heterogeneous in speed or pipeline depth) and routes
each arriving request to the replica whose global balance score is lowest.

The score is computed from exactly the scheduler signals Token Throttling
uses, so imbalance is *discovered* — a slow or KV-saturated replica
accumulates #WP/#RD backlog and sheds load without any static capacity
configuration (`ReplicaCapacity` hints can still be supplied when
capacities are known).

Admission-time placement alone reacts a queue-buildup too late: a replica
that saturates *after* placement keeps its backlog while neighbors idle.
With a `RebalancePolicy` the router becomes a periodic **control plane**
(§9): each interval it re-polls every replica's balance score and, when the
spread exceeds the trigger, first *steals* waiting requests from the
saturated queue (cheap — no state moves) and, if imbalance persists,
**live-migrates** running decode requests — draining them from the source
scheduler, shipping their KV pages (and recurrent state) through the
backend migration hooks, and re-admitting them at their current position
with no recompute.

`SimCluster` drives N `PipelineSimulator` replicas in causally-consistent
virtual time: before each routing decision (and each control-plane tick)
every replica is advanced to that instant, so the router sees the state a
real frontend would; migration pays the modeled KV-transfer latency.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from dataclasses import dataclass
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core import (SLO_BATCH, KVExport, Request, RequestState,
                        SamplingParams)
from repro.runtime.autoscale import (AutoscalePolicy, AutoscaleStats,
                                     fleet_pressure, replica_pressure,
                                     scale_up_step)
from repro.runtime.disagg import (ROLE_MIXED, ROLE_PREFILL, DisaggStats,
                                  HandoffPolicy, decode_capable,
                                  handoff_candidates, prefill_capable,
                                  retirable, validate_roles)


class RoutingPolicy(enum.Enum):
    ROUND_ROBIN = "rr"
    BALANCED = "balanced"


@dataclass(frozen=True)
class BalanceWeights:
    """Converts the scheduler's global signals into one load scalar.

    A decode-resident request represents future work (its remaining output
    tokens) — `decode_tokens` is the prefill-token-equivalent charged per
    resident decode; calibrate it to ~E[remaining output length] of the
    workload (the default suits chat-style ~240-token outputs; with a
    `RebalancePolicy` the router calibrates it online from an EWMA of
    observed output lengths).  `kv_pressure` inflates the score of replicas
    close to the UT stall point, where admission would trigger the throttle
    guard or preemption-recompute churn (paper Fig. 15's no-UT pathology,
    avoided cluster-wide).  The pressure is *threshold-relative* — it
    engages below `kv_activation_margin` times the replica's own KV
    threshold — so a structurally smaller pool is not penalized while it
    still has headroom (the asymmetric-KV heterogeneity case of
    fig_router_balance.py).
    """

    decode_tokens: float = 128.0
    kv_pressure: float = 4.0
    kv_activation_margin: float = 4.0
    # Prefill-token-equivalent credit per token of the candidate's prompt
    # already cached on a replica (`ReplicaSnapshot.cached_prefix_tokens`,
    # probed via the non-mutating `PagedKVManager.peek_prefix`).  At 1.0 a
    # replica is charged only the *uncached* remainder of the prompt — the
    # work it would actually do — so cache affinity and load balance trade
    # in the same currency.  Zero disables cache-aware routing; the term is
    # inert whenever prefix caching is off (probes return 0).
    cache_affinity: float = 1.0
    # Waiting-queue composition surcharge, per waiting request by SLO
    # class: `waiting_prefill_tokens` already counts the queue's tokens,
    # but a queue of interactive requests is *latency debt* (each one has
    # a TTFT clock running) while an equally deep all-batch queue is not —
    # the per-request charge makes placement prefer burying new work
    # behind batch backlog over interactive backlog.
    interactive_queue: float = 4.0
    batch_queue: float = 1.0
    # Blend between static `ReplicaCapacity` hints (0.0) and the
    # *discovered* per-replica service rate (1.0): when every replica has
    # retired enough work to report a `SchedulerStats.note_retire` EWMA,
    # each rate is normalized by the fleet mean and blended over the hint
    # at this weight.  Discovery closes the loop the static hints only
    # approximate — a straggler's real throughput deficit is measured,
    # not declared (fig_rebalance's discovery-only scenarios).  The
    # default is deliberately conservative: a service rate conflates
    # capacity with utilization (an under-fed replica *retires* slowly no
    # matter how fast it could go), so measured rates nudge the score
    # rather than dominate it; set 1.0 to trust measurement fully on a
    # cluster you know stays saturated.  Discovery only applies when the
    # operator declared no capacities at all — explicit hints are truth
    # and are never diluted by utilization-confounded measurement.
    discovered_rate: float = 0.25


@dataclass(frozen=True)
class ReplicaCapacity:
    """Static capacity hint for one replica, stated as hardware facts.

    The router only consumes the derived `scalar()` (throughput relative to
    a 1.0 reference replica), but callers declare what they actually know —
    relative FLOPs, KV pool size, pipeline depth — and the constructors
    derive the scalar, so benchmark configs stay in the language of the
    heterogeneity they model (fig_router_balance's slow / straggler cases).
    """

    rel_flops: float = 1.0
    kv_pool_pages: Optional[int] = None
    pipeline_depth: Optional[int] = None

    @staticmethod
    def scaled(slow_factor: float, **kw) -> "ReplicaCapacity":
        """Uniformly `slow_factor`x slower silicon."""
        return ReplicaCapacity(rel_flops=1.0 / slow_factor, **kw)

    @staticmethod
    def straggler(pp: int, slow_factor: float, **kw) -> "ReplicaCapacity":
        """One of `pp` stages is `slow_factor`x slower.  A fully *packed*
        ring is gated by the slow stage alone (1/slow_factor), but serving
        pipelines spend much of their time decode-bubbled, where per-batch
        latency — the sum of stages, (pp-1+f)/pp relative — is what gates
        throughput; this hint uses that sum-of-stages ratio,
        pp / (pp - 1 + slow_factor), which fig_router_balance validates
        empirically.  Use `scaled(slow_factor)` for a pipeline you expect
        to stay packed."""
        return ReplicaCapacity(rel_flops=pp / (pp - 1 + slow_factor),
                               pipeline_depth=pp, **kw)

    def scalar(self) -> float:
        return self.rel_flops


@dataclass(frozen=True)
class RebalancePolicy:
    """Control-plane knobs: when to act and how much state to move.

    A pass triggers when max/min balance score exceeds `trigger_ratio`
    (with an absolute `min_score_gap` floor so near-idle clusters don't
    ping-pong).  Steals are cheap (waiting requests carry no device state),
    so up to `steal_batch` happen first; live migrations move KV over the
    interconnect, so they carry hysteresis: they fire only past the higher
    `migrate_trigger_ratio` (imbalance that stealing alone could not clear),
    are rationed to `migrate_batch` per pass, prefer requests with the most
    output still to generate (durable relief per transfer; at least
    `min_remaining_tokens`), and each request moves at most
    `max_request_migrations` times — without that cap a relieved replica
    looks attractive again next pass and the same KV bounces back and
    forth.  `calibrate_decode_weight` keeps `BalanceWeights.decode_tokens`
    tracking an EWMA of observed output lengths (charged at half: the
    expected *remaining* length of a request in steady state).
    """

    interval: float = 0.25
    trigger_ratio: float = 1.5
    min_score_gap: float = 256.0
    steal_batch: int = 8
    migrate: bool = True
    migrate_trigger_ratio: float = 2.5
    migrate_batch: int = 2
    min_remaining_tokens: int = 16
    max_request_migrations: int = 1
    calibrate_decode_weight: bool = True
    ewma_alpha: float = 0.01


def remaining_decode_growth(sched) -> int:
    """KV tokens the resident decode population will still append before
    finishing (bounded by each request's max_new_tokens) — the forward-
    looking half of every KV projection below."""
    return sum(r.sampling.max_new_tokens - r.num_output_tokens
               for r in sched.running_decode)


def kv_activation(weights: BalanceWeights, kv_threshold: float) -> float:
    """Free-rate level below which the pressure term engages: a margin
    above the replica scheduler's own UT stall point."""
    return min(1.0, weights.kv_activation_margin * kv_threshold)


@dataclass(frozen=True)
class ReplicaSnapshot:
    """The router's view of one replica at a routing instant.

    `projected_kv_free` looks past the instantaneous idle rate: resident
    decodes keep appending KV until they finish, so a structurally small
    pool that *looks* idle can be minutes from the UT stall.  The projection
    subtracts `remaining_decode_growth` — the KV-aware signal both
    admission and the rebalance control plane score against.
    """

    waiting_prefill_tokens: int
    running_decode: int
    kv_free_rate: float
    kv_threshold: float = 0.05      # the replica scheduler's UT stall point
    projected_kv_free: Optional[float] = None
    # Discovered tokens-retired-per-second EWMA (scheduler service clock);
    # None until the replica has retired work over a measurable window.
    # First step toward replacing static `ReplicaCapacity` hints: exposed
    # through `LLMServer.stats()` so operators can compare hint vs. reality.
    service_rate: Optional[float] = None
    # Waiting-queue composition by SLO class: a queue of interactive
    # requests is latency debt; an equally deep all-batch queue is not.
    # Folded into `balance_score` via `BalanceWeights.interactive_queue` /
    # `batch_queue` (class-aware placement, DESIGN.md §11).
    waiting_interactive: int = 0
    waiting_batch: int = 0
    # Tokens of the candidate request's prompt whose KV is already cached
    # here (longest hash-chained full-page prefix, non-mutating probe).
    # 0 when the snapshot was taken without a candidate prompt or the
    # replica has prefix caching disabled.
    cached_prefix_tokens: int = 0

    @staticmethod
    def of(replica,
           prompt: Optional[Sequence[int]] = None) -> "ReplicaSnapshot":
        sched = replica.scheduler
        pool = sched.kv.num_pages * sched.kv.page_size
        growth = remaining_decode_growth(sched)
        n_batch = sum(1 for r in sched.waiting
                      if r.sampling.slo_class == SLO_BATCH)
        cached = 0
        if prompt is not None and getattr(sched.kv, "enable_prefix_caching",
                                          False):
            # mirror the admission probe exactly (it matches the effective
            # prompt minus the final token, which the first chunk must
            # still consume to sample from)
            cached = sched.kv.peek_prefix(list(prompt)[:-1])
        return ReplicaSnapshot(
            waiting_prefill_tokens=sched.num_waiting_prefill_tokens,
            running_decode=sched.num_running_decode,
            kv_free_rate=sched.kv.kv_free_rate,
            kv_threshold=sched.cfg.kv_threshold,
            projected_kv_free=sched.kv.kv_free_rate - growth / pool,
            service_rate=sched.stats.service_rate,
            waiting_interactive=len(sched.waiting) - n_batch,
            waiting_batch=n_batch,
            cached_prefix_tokens=cached,
        )


def balance_score(snap: ReplicaSnapshot, prompt_tokens: int,
                  weights: BalanceWeights, capacity: float = 1.0) -> float:
    """Estimated completion burden of placing `prompt_tokens` on a replica:
    pending work (incl. the candidate request) per unit capacity, inflated
    by proximity to the KV stall point.  Lower is better.

    Cache affinity: tokens of the candidate's prompt already cached on
    this replica (`snap.cached_prefix_tokens`) are prefill work it will
    never do — they are credited against the candidate's burden at
    `weights.cache_affinity` per token (clamped so a cache hit can reduce
    the candidate's own charge to zero, never below)."""
    burden = prompt_tokens - min(
        weights.cache_affinity * snap.cached_prefix_tokens,
        float(prompt_tokens))
    load = (snap.waiting_prefill_tokens + burden
            + weights.decode_tokens * snap.running_decode
            + weights.interactive_queue * snap.waiting_interactive
            + weights.batch_queue * snap.waiting_batch)
    activation = kv_activation(weights, snap.kv_threshold)
    free = snap.kv_free_rate
    if snap.projected_kv_free is not None:
        # decode residents keep growing their KV: pressure engages on where
        # the pool is *heading*, not only where it is
        free = min(free, snap.projected_kv_free)
    shortfall = max(0.0, activation - free) / max(activation, 1e-9)
    pressure = 1.0 + weights.kv_pressure * shortfall
    return load * pressure / max(capacity, 1e-9)


def discovered_capacities(snaps: Sequence[ReplicaSnapshot],
                          static: Sequence[float],
                          blend: float) -> List[float]:
    """Effective per-replica capacities: the static hints until *every*
    replica reports a discovered service rate, then each rate normalized
    by the fleet mean, blended in at `blend` (1.0 fully replaces the
    hints).  All-or-nothing on purpose: mixing measured rates with
    declared hints inside one score vector would compare replicas in two
    different currencies."""
    if blend <= 0.0:
        return list(static)
    rates = [s.service_rate for s in snaps]
    if any(r is None or r <= 0.0 for r in rates):
        return list(static)
    mean = sum(rates) / len(rates)
    if mean <= 0.0:
        return list(static)
    return [(1.0 - blend) * c + blend * (r / mean)
            for c, r in zip(static, rates)]


@dataclass
class RebalanceStats:
    passes: int = 0
    stolen: int = 0
    migrated: int = 0
    migrated_tokens: int = 0        # KV tokens shipped over the interconnect
    migration_fallbacks: int = 0    # destination pool shrank in transit


class ReplicaRouter:
    """Fronts N serving replicas; routes by global balance score.

    A replica is anything exposing `scheduler` (a `PipelineScheduler`) and
    `backend` (an `ExecutionBackend` — the migration hooks live there);
    engine replicas additionally expose `add_request`/`step`/`has_work`/
    `busy` so the router can serve as a drop-in engine for the serving
    layer (`repro.serving.LLMServer`) and the launchers.

    With `rebalance=RebalancePolicy(...)` the router runs the periodic
    control plane: step-driven replicas (engines) get control ticks from
    `step()` on the backend clock; `SimCluster` drives them explicitly in
    virtual time via `next_control_event`/`control_tick`.
    """

    def __init__(
        self,
        replicas: Sequence[Any],
        policy: str | RoutingPolicy = RoutingPolicy.BALANCED,
        *,
        weights: Optional[BalanceWeights] = None,
        capacities: Optional[Sequence[Any]] = None,
        rebalance: Optional[RebalancePolicy] = None,
        roles: Optional[Sequence[str]] = None,
        handoff: Optional[HandoffPolicy] = None,
        autoscale: Optional[AutoscalePolicy] = None,
        replica_factory: Optional[Callable[[int], Any]] = None,
        trace_path: Optional[str] = None,
    ) -> None:
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        self.replicas = list(replicas)
        self.policy = RoutingPolicy(policy)
        self.weights = weights or BalanceWeights()
        n = len(self.replicas)
        # The replica set is *elastic* (§16): every piece of per-replica
        # bookkeeping that outlives a single pass is keyed by a stable
        # replica ordinal (`replica_ids[i]`), never by position — positions
        # shift when a replica retires.  The parallel positional lists
        # (`capacities`, `roles`, `_caps_eff`, ...) are mutated together in
        # `add_replica` / `_retire` only.
        self.replica_ids: List[int] = list(range(n))
        self._next_ordinal = n
        self.capacity_hints = list(capacities) if capacities is not None \
            else [1.0] * n
        if len(self.capacity_hints) != n:
            raise ValueError("one capacity per replica")
        self.capacities = [c.scalar() if isinstance(c, ReplicaCapacity)
                           else float(c) for c in self.capacity_hints]
        # discovery refines the *uniform default*; explicitly declared
        # hints are operator truth and are never diluted by measured
        # rates (which conflate capacity with utilization)
        self._caps_declared = capacities is not None
        self._caps_eff = list(self.capacities)
        self.roles = (validate_roles(roles, n) if roles is not None
                      else (ROLE_MIXED,) * n)
        self.handoff_policy = handoff
        self.disagg_stats = DisaggStats()
        self._handoffs_of: dict = {}        # rid -> times handed off
        self._next_handoff_due = handoff.interval if handoff is not None \
            else None
        self._rr_next = 0
        self._routed_by_id: Dict[int, int] = {o: 0 for o in self.replica_ids}
        self.rebalance_policy = rebalance
        self.rebalance_stats = RebalanceStats()
        self._next_due = rebalance.interval if rebalance is not None else None
        # elastic lifecycle: the autoscaler pass, draining ordinals, and
        # replicas retired (kept for finished-request accounting)
        self.autoscale_policy = autoscale
        self.autoscale_stats = AutoscaleStats()
        self.replica_factory = replica_factory
        self._add_hooks: List[Callable[[Any, int, float], None]] = []
        self._draining: set = set()         # ordinals mid-drain
        self._next_drain_due: Optional[float] = None
        self.retired: List[Any] = []
        self._next_autoscale_due = autoscale.interval \
            if autoscale is not None else None
        self._pressure_ewma: Optional[float] = None
        self._last_scale_up = -float("inf")
        self._last_scale_down = 0.0     # first drain waits a full cooldown
        # in-transit entries address the destination by *ordinal* — the
        # replica list can change while a payload is on the wire, and a
        # delivery to a retired/draining destination is re-homed at flush
        self._in_transit: List[Tuple[float, int, int, Request, KVExport,
                                     Any, Any, str]] = []
        self._transit_seq = itertools.count()
        self._aborted: List[Request] = []   # aborted while in transit
        self._migrations_of: dict = {}      # rid -> times live-migrated
        self._seen_finished: Dict[int, int] = {o: 0 for o in self.replica_ids}
        self._ewma_output: Optional[float] = None
        self._calib_count = 0
        self._trace = None
        if trace_path is not None:
            self.open_trace(trace_path)

    # ------------------------------------------------------- replica indexing
    @property
    def _admissible(self) -> List[int]:
        """Admission candidates: prefill-capable (a pure decode replica only
        ever receives handed-off / migrated work) and not draining (a
        draining replica is masked from new placements)."""
        return [i for i, r in enumerate(self.roles)
                if prefill_capable(r)
                and self.replica_ids[i] not in self._draining]

    def _serving(self) -> List[int]:
        """Indices counted toward fleet capacity: not draining."""
        return [i for i in range(len(self.replicas))
                if self.replica_ids[i] not in self._draining]

    def _index_of(self, ordinal: int) -> Optional[int]:
        try:
            return self.replica_ids.index(ordinal)
        except ValueError:
            return None

    @property
    def routed_counts(self) -> List[int]:
        """Requests placed on each *current* replica, position-aligned with
        `self.replicas` (backed by ordinal-keyed counters, so the list stays
        correct as the fleet grows and shrinks)."""
        return [self._routed_by_id[o] for o in self.replica_ids]

    # ---------------------------------------------------------------- tracing
    def open_trace(self, sink) -> None:
        """Log every placement decision (per-replica scores + chosen index)
        and every control-plane pass to a `gllm-route` JSONL stream — the
        routing counterpart of the per-replica tick traces
        (runtime/trace.py)."""
        from repro.runtime.trace import (ROUTE_SCHEMA, SCHEMA_MAJOR,
                                         SCHEMA_MINOR, TraceWriter)
        assert self._trace is None, "router trace already open"
        self._trace = TraceWriter(sink)
        header = {
            "kind": "header",
            "schema": ROUTE_SCHEMA,
            "version": [SCHEMA_MAJOR, SCHEMA_MINOR],
            "replicas": len(self.replicas),
            "policy": self.policy.value,
            "capacities": list(self.capacities),
        }
        if self.rebalance_policy is not None:
            header["rebalance"] = dataclasses.asdict(self.rebalance_policy)
        if any(r != ROLE_MIXED for r in self.roles):
            header["roles"] = list(self.roles)
        if self.handoff_policy is not None:
            header["handoff"] = dataclasses.asdict(self.handoff_policy)
        if self.autoscale_policy is not None:
            header["autoscale"] = dataclasses.asdict(self.autoscale_policy)
        self._trace.write(header)

    def close_trace(self) -> None:
        if self._trace is not None:
            self._trace.close()

    # ---------------------------------------------------------------- routing
    def scores(self, prompt_tokens: int = 0,
               prompt: Optional[Sequence[int]] = None) -> List[float]:
        """Per-replica balance scores for a candidate request.  Passing the
        actual `prompt` token ids (not just the count) lets each snapshot
        probe its replica's prefix cache (`peek_prefix`, non-mutating) and
        apply the `cache_affinity` credit — cache-aware routing."""
        if prompt is not None:
            prompt_tokens = len(prompt)
        snaps = [ReplicaSnapshot.of(r, prompt) for r in self.replicas]
        self._caps_eff = discovered_capacities(
            snaps, self.capacities,
            0.0 if self._caps_declared else self.weights.discovered_rate)
        return [balance_score(s, prompt_tokens, self.weights, c)
                for s, c in zip(snaps, self._caps_eff)]

    def select(self, prompt_tokens: int = 0,
               prompt: Optional[Sequence[int]] = None) -> int:
        """Index of the replica the next request should land on (only
        prefill-capable replicas are admission candidates)."""
        if prompt is not None:
            prompt_tokens = len(prompt)
        scores: Optional[List[float]] = None
        admissible = self._admissible
        if self.policy is RoutingPolicy.ROUND_ROBIN:
            i = admissible[self._rr_next % len(admissible)]
            self._rr_next = (self._rr_next + 1) % len(admissible)
        else:
            scores = self.scores(prompt_tokens, prompt)
            i = min(admissible, key=lambda j: scores[j])
        self._routed_by_id[self.replica_ids[i]] += 1
        if self._trace is not None:
            self._trace.write({"kind": "route", "n": prompt_tokens,
                               "scores": scores, "replica": i})
        return i

    # -------------------------------------------------- control plane ticking
    @property
    def has_in_transit(self) -> bool:
        return bool(self._in_transit)

    def next_control_event(self) -> Optional[float]:
        """Earliest instant the control plane must run: the next periodic
        rebalance or handoff pass, or an in-flight transfer completing.
        None without any policy and nothing in transit."""
        cands = [t for t, *_ in self._in_transit]
        if self.rebalance_policy is not None and self._next_due is not None:
            cands.append(self._next_due)
        if self.handoff_policy is not None \
                and self._next_handoff_due is not None:
            cands.append(self._next_handoff_due)
        if self.autoscale_policy is not None \
                and self._next_autoscale_due is not None:
            cands.append(self._next_autoscale_due)
        if self._next_drain_due is not None:
            # active drains need periodic control ticks to push moves and
            # retire even when no policy supplies a cadence
            cands.append(self._next_drain_due)
        return min(cands) if cands else None

    def control_tick(self, now: float) -> None:
        """Run everything due at `now`: deliver completed transfers, push
        active drains forward, then a handoff / rebalance / autoscale pass
        if their intervals elapsed."""
        self._flush_in_transit(now)
        if self._draining:
            self._drain_pass(now)
        if self._next_drain_due is not None:
            if not self._draining:
                self._next_drain_due = None
            elif now >= self._next_drain_due:
                interval = self._drain_interval()
                missed = int((now - self._next_drain_due) // interval) + 1
                self._next_drain_due += missed * interval
        if self.handoff_policy is not None and now >= self._next_handoff_due:
            self._handoff_pass(now)
            interval = self.handoff_policy.interval
            missed = int((now - self._next_handoff_due) // interval) + 1
            self._next_handoff_due += missed * interval
        if self.rebalance_policy is not None and now >= self._next_due:
            self.rebalance(now)
            # re-anchor arithmetically: engine clocks are time.monotonic(),
            # so `now` can be arbitrarily far past the virtual-time-zero
            # anchor — a += loop would spin once per elapsed interval
            interval = self.rebalance_policy.interval
            missed = int((now - self._next_due) // interval) + 1
            self._next_due += missed * interval
        if self.autoscale_policy is not None \
                and now >= self._next_autoscale_due:
            self._autoscale_pass(now)
            interval = self.autoscale_policy.interval
            missed = int((now - self._next_autoscale_due) // interval) + 1
            self._next_autoscale_due += missed * interval

    # ---------------------------------------------------- first-decode handoff
    def _handoff_pass(self, now: float) -> None:
        """One disagg control pass: every prefill-role replica ships its
        freshly-prefilled requests (first decode: the final chunk sampled
        the first token, no decode step has run) to the decode-capable
        replica with the lowest balance score, up to the per-pass cap.
        Deferred candidates (no destination with KV headroom) stay put —
        the prefill replica keeps decoding them, and later passes retry
        until they outgrow `max_decode_tokens`."""
        pol = self.handoff_policy
        st = self.disagg_stats
        st.passes += 1
        moved = 0
        for src_i, src in enumerate(self.replicas):
            if self.roles[src_i] != ROLE_PREFILL:
                continue
            for req in handoff_candidates(src, pol, self._handoffs_of):
                if moved >= pol.handoff_batch:
                    break
                dst_i = self._pick_handoff_dst(src_i, req)
                if dst_i is None:
                    st.deferred += 1
                    continue
                if self._move_request(req.request_id, src_i, dst_i,
                                      now=now, kind="handoff"):
                    moved += 1
        if self._trace is not None and moved:
            self._trace.write({"kind": "handoff", "now": now,
                               "moved": moved})

    def _pick_handoff_dst(self, src_i: int, req: Request) -> Optional[int]:
        """Lowest-balance-score decode-capable replica that can actually
        take the request: servable, pages allocatable now, and projected
        KV headroom after absorbing everything it will still write."""
        best = None
        best_score = None
        for i, r in enumerate(self.replicas):
            if i == src_i or not decode_capable(self.roles[i]):
                continue
            if self.replica_ids[i] in self._draining:
                continue
            if not self._servable_on(r, req):
                continue
            if not r.scheduler.kv.can_allocate(req.request_id,
                                               req.num_prefilled):
                continue
            if not self._dst_headroom_ok(r, req):
                continue
            score = balance_score(ReplicaSnapshot.of(r), 0, self.weights,
                                  self._caps_eff[i])
            if best_score is None or score < best_score:
                best, best_score = i, score
        return best

    # ------------------------------------------------------------- rebalance
    def _imbalance(self, trigger_ratio: float
                   ) -> Optional[Tuple[int, int, List[float]]]:
        """(overloaded, underloaded, scores) when the spread warrants a
        move, else None."""
        pol = self.rebalance_policy
        scores = self.scores(0)
        # a draining replica may *shed* load (src) but never receive it —
        # the drain pass is emptying it
        serving = self._serving()
        if not serving:
            return None
        src = int(np.argmax(scores))
        dst = min(serving, key=lambda j: scores[j])
        if src == dst:
            return None
        if scores[src] - scores[dst] < pol.min_score_gap:
            return None
        if scores[src] <= trigger_ratio * max(scores[dst], 1e-9):
            return None
        return src, dst, scores

    def rebalance(self, now: float) -> None:
        """One control-plane pass: calibrate weights, steal waiting work,
        then live-migrate decode state while imbalance persists."""
        pol = self.rebalance_policy
        self._calibrate()
        self.rebalance_stats.passes += 1
        stolen = migrated = 0
        trigger = self._imbalance(pol.trigger_ratio)
        while trigger is not None and stolen < pol.steal_batch:
            src, dst, scores = trigger
            if not self._steal_one(src, dst, now, scores[src]):
                break
            stolen += 1
            trigger = self._imbalance(pol.trigger_ratio)
        if pol.migrate:
            trigger = self._imbalance(pol.migrate_trigger_ratio)
            while trigger is not None and migrated < pol.migrate_batch:
                src, dst, scores = trigger
                if not self._migrate_one(src, dst, now, scores[src]):
                    break
                migrated += 1
                trigger = self._imbalance(pol.migrate_trigger_ratio)
        if self._trace is not None and (stolen or migrated):
            self._trace.write({"kind": "rebalance", "now": now,
                               "stolen": stolen, "migrated": migrated,
                               "decode_tokens": self.weights.decode_tokens})

    def _calibrate(self) -> None:
        """Walk newly finished requests: retire their control-plane
        bookkeeping, and feed output lengths into a debiased EWMA ->
        decode_tokens weight (charged at half: a request's expected
        *remaining* output in steady state).  During warm-up (the first
        1/alpha completions) the EWMA is the plain running mean — a
        recency-weighted average over few samples would chase completion
        order, which anti-correlates with length (short outputs finish
        first, long ones dominate the drain tail)."""
        pol = self.rebalance_policy
        calibrate = pol is not None and pol.calibrate_decode_weight
        for ordinal, r in zip(self.replica_ids, self.replicas):
            fin = _finished_of(r)
            for req in fin[self._seen_finished.get(ordinal, 0):]:
                # move counts only matter while the request is alive
                self._migrations_of.pop(req.request_id, None)
                self._handoffs_of.pop(req.request_id, None)
                if not calibrate:
                    continue
                n = req.num_output_tokens
                self._calib_count += 1
                alpha = max(pol.ewma_alpha, 1.0 / self._calib_count)
                if self._ewma_output is None:
                    self._ewma_output = float(n)
                else:
                    self._ewma_output += alpha * (n - self._ewma_output)
            self._seen_finished[ordinal] = len(fin)
        if calibrate and self._ewma_output is not None:
            self.weights = dataclasses.replace(
                self.weights,
                decode_tokens=max(1.0, self._ewma_output / 2.0))

    # ------------------------------------------------------------- stealing
    def _servable_on(self, replica, req: Request) -> bool:
        sched = replica.scheduler
        total = req.num_effective_prompt_tokens + req.sampling.max_new_tokens
        return (total <= sched.max_model_len
                and total <= sched.kv.num_pages * sched.kv.page_size)

    def _improves_max(self, src_i: int, dst_i: int, req: Request,
                      src_score: float) -> bool:
        """A move must reduce the cluster's worst score: after receiving the
        request, the destination has to remain clearly below the source —
        otherwise the move just relocates the hot spot (and a big request
        landing on a marginally-less-loaded replica makes the tail worse)."""
        burden = (req.remaining_prefill_tokens
                  + self.weights.decode_tokens * bool(req.prefill_done))
        after = balance_score(ReplicaSnapshot.of(self.replicas[dst_i]),
                              int(burden), self.weights,
                              self._caps_eff[dst_i])
        return after < src_score

    def _dst_headroom_ok(self, dst, req: Request) -> bool:
        """KV-aware destination guard for moves that land *resident* state
        (live migration, first-decode handoff): after absorbing everything
        this request will still write (remaining prefill + all remaining
        outputs), plus the projected growth of the destination's own decode
        residents, the pool must stay out of the pressure band — shipping
        KV into a pool that is heading for its UT stall trades one hot
        spot for a worse one.  Steals of *waiting* requests skip this
        guard on purpose: they land in the destination's waiting queue
        with no KV written, the destination's own WT/UT throttle decides
        when (whether) to start them, and the dst's KV pressure already
        inflates the `_improves_max` score it must beat."""
        sched = dst.scheduler
        pool = sched.kv.num_pages * sched.kv.page_size
        need = (req.num_effective_prompt_tokens + req.sampling.max_new_tokens
                - req.num_prefilled)
        projected = sched.kv.kv_free_rate - (
            remaining_decode_growth(sched) + need) / pool
        return projected > kv_activation(self.weights,
                                         sched.cfg.kv_threshold)

    def _role_ok(self, dst_i: int, req: Request) -> bool:
        """Role guard for rebalance moves: decode residents may only move
        to decode-capable replicas; anything with prefill still ahead
        (waiting or mid-prefill) needs a prefill-capable destination —
        without this the rebalance plane would undo the disagg shape the
        handoff plane maintains."""
        role = self.roles[dst_i]
        if req.state is RequestState.DECODING:
            return decode_capable(role)
        return prefill_capable(role)

    def _steal_one(self, src_i: int, dst_i: int, now: float,
                   src_score: float) -> bool:
        """Move one *waiting* request (no device state) off the saturated
        replica.  Cheap: drain from the source queue tail, adopt at the
        destination queue tail."""
        src, dst = self.replicas[src_i], self.replicas[dst_i]
        for req in src.scheduler.steal_candidates():
            if not self._servable_on(dst, req) \
                    or not self._role_ok(dst_i, req):
                continue
            if not self._improves_max(src_i, dst_i, req, src_score):
                continue
            drained = src.scheduler.drain_request(req.request_id)
            if drained is None:
                continue
            # waiting requests carry no KV, but host-side per-request state
            # (encoder embeddings) must follow them or the destination
            # prefills without it
            state = src.backend.export_request_state(drained)
            _record_move_out(src, drained.request_id, now, "migrate")
            dst.backend.import_request_state(drained, state, resident=False)
            dst.scheduler.adopt_request(drained)
            _record_move_in(dst, drained, now, "migrate")
            _advance_replica_clock(dst, now)
            self.rebalance_stats.stolen += 1
            return True
        return False

    # ------------------------------------------------------------- migration
    def _source_pressured(self, src) -> bool:
        """Live migration moves state, so it needs *persistent* saturation,
        not a cosmetic decode-population spread: the source must still have
        admission work it cannot start (waiting queue survived the steal
        phase) or be inside the KV pressure band (resident decode is
        forcing the UT guard / preemption churn).  Without this gate a
        discovery-only straggler cluster migrates in the wrong direction —
        the *fast* replica carries more decode and looks overloaded."""
        sched = src.scheduler
        if sched.waiting:
            return True
        return sched.kv.kv_free_rate <= kv_activation(
            self.weights, sched.cfg.kv_threshold)

    @staticmethod
    def _remaining_work(req: Request) -> int:
        """Tokens the request will still produce/consume wherever it runs:
        unprefilled prompt plus unsampled output (zero prefill remainder
        for a decode resident)."""
        return (req.remaining_prefill_tokens
                + req.sampling.max_new_tokens - req.num_output_tokens)

    def _migration_candidates(self, src) -> List[Request]:
        pol = self.rebalance_policy
        if not self._source_pressured(src):
            return []
        # decode residents *and* mid-prefill requests are movable: a
        # partially-prefilled request carries its chunk cursor
        # (`num_prefilled`) and resident KV — including any adopted prefix
        # head — through drain/export, and resumes at the right chunk on
        # the destination (the disagg enabler, DESIGN.md §15)
        live = list(src.scheduler.running_decode) + [
            r for r in src.scheduler.running_prefill if r.num_prefilled > 0]
        out = [r for r in live
               if self._remaining_work(r) >= pol.min_remaining_tokens
               and self._migrations_of.get(r.request_id, 0)
               < pol.max_request_migrations]
        # most remaining work first: each transfer should buy the most
        # durable relief (ties broken toward smaller resident KV = cheaper)
        out.sort(key=lambda r: (-self._remaining_work(r), r.num_prefilled))
        return out

    def _migrate_one(self, src_i: int, dst_i: int, now: float,
                     src_score: float) -> bool:
        """Policy layer of migration: pick a candidate worth moving and
        hand it to `migrate_request`."""
        src, dst = self.replicas[src_i], self.replicas[dst_i]
        for req in self._migration_candidates(src):
            if not self._servable_on(dst, req) \
                    or not self._role_ok(dst_i, req):
                continue
            if not dst.scheduler.kv.can_allocate(req.request_id,
                                                 req.num_prefilled):
                continue
            if not self._improves_max(src_i, dst_i, req, src_score) \
                    or not self._dst_headroom_ok(dst, req):
                continue
            if self.migrate_request(req.request_id, src_i, dst_i, now=now):
                return True
        return False

    def migrate_request(self, rid: str, src_i: int, dst_i: int,
                        *, now: Optional[float] = None) -> bool:
        """Mechanism layer: live-migrate one request (§9 protocol):
        drain -> export KV addressing -> gather device pages -> free source
        -> (transfer latency) -> import at destination -> adopt, resuming at
        the current position with no recompute.  Returns False when the
        request is in flight this tick (the caller may retry next pass).
        Public so operators and tests can force a move the policy would
        not pick."""
        return self._move_request(rid, src_i, dst_i, now=now,
                                  kind="migrate")

    def _move_request(self, rid: str, src_i: int, dst_i: int, *,
                      now: Optional[float] = None,
                      kind: str = "migrate") -> bool:
        """Shared mechanism under both planes: `kind` selects the trace
        record vocabulary and the stats bucket — `"migrate"` for the
        rebalance control plane, `"handoff"` for the disagg prefill ->
        decode transfer (identical wire format, distinct intent)."""
        if now is None:
            now = self._clock()
        src = self.replicas[src_i]
        drained = src.scheduler.drain_request(rid)
        if drained is None:
            return False
        if not src.scheduler.kv.has_request(rid):
            # nothing resident (a waiting request): this is just a steal
            dst = self.replicas[dst_i]
            state = src.backend.export_request_state(drained)
            _record_move_out(src, rid, now, kind)
            dst.backend.import_request_state(drained, state, resident=False)
            dst.scheduler.adopt_request(drained)
            _record_move_in(dst, drained, now, kind)
            _advance_replica_clock(dst, now)
            self.rebalance_stats.stolen += 1
            return True
        export = src.scheduler.kv.export_kv(rid)
        payload = src.backend.export_kv_pages(rid, export.slots)
        state = src.backend.export_request_state(drained)
        delay = src.backend.migration_cost(export.num_tokens)
        src.scheduler.kv.free(rid)
        _record_move_out(src, rid, now, kind)
        if kind == "handoff":
            self._handoffs_of[rid] = self._handoffs_of.get(rid, 0) + 1
            self.disagg_stats.handoffs += 1
            self.disagg_stats.handoff_tokens += export.num_tokens
        else:
            self._migrations_of[rid] = self._migrations_of.get(rid, 0) + 1
            self.rebalance_stats.migrated += 1
            self.rebalance_stats.migrated_tokens += export.num_tokens
        if delay <= 0.0:
            self._deliver(dst_i, drained, export, payload, state, now, kind)
        else:
            heapq.heappush(self._in_transit,
                           (now + delay, next(self._transit_seq),
                            self.replica_ids[dst_i],
                            drained, export, payload, state, kind))
        return True

    def _flush_in_transit(self, now: float) -> None:
        while self._in_transit and self._in_transit[0][0] <= now:
            at, _, dst_ord, req, export, payload, state, kind = heapq.heappop(
                self._in_transit)
            dst_i = self._index_of(dst_ord)
            if dst_i is None or dst_ord in self._draining:
                # the destination drained/retired while the payload was on
                # the wire: re-home the delivery instead of dropping it —
                # the source already freed its pages, so this host-held
                # copy is the only live form of the request
                dst_i = self._rehome_dst(req)
                self.autoscale_stats.rehomed += 1
            self._deliver(dst_i, req, export, payload, state,
                          max(at, now), kind)

    def _rehome_dst(self, req: Request) -> int:
        """Pick a fresh destination for an orphaned in-transit delivery:
        lowest-score serving replica whose role can hold the request (the
        `_deliver` fallback path absorbs any KV shortfall by degrading to
        recompute admission, so headroom is a preference, not a guard)."""
        cands = [i for i in self._serving()
                 if self._role_ok(i, req)
                 and self._servable_on(self.replicas[i], req)]
        if not cands:   # no serving replica fits: any serving role-ok one
            cands = [i for i in self._serving() if self._role_ok(i, req)]
        if not cands:
            raise RuntimeError(
                f"no serving replica can adopt in-transit request "
                f"{req.request_id!r}")
        scores = self.scores(0)
        good = [i for i in cands
                if self._dst_headroom_ok(self.replicas[i], req)]
        return min(good or cands, key=lambda i: scores[i])

    def _deliver(self, dst_i: int, req: Request, export: KVExport,
                 payload: Any, state: Any, now: float,
                 kind: str = "migrate") -> None:
        dst = self.replicas[dst_i]
        kv = dst.scheduler.kv
        rid = req.request_id
        imported = False
        if kv.can_allocate(rid, export.num_tokens):
            dst_slots = kv.import_kv(export)
            try:
                dst.backend.import_kv_pages(rid, payload, dst_slots)
                dst.backend.import_request_state(req, state)
                imported = True
            except MemoryError:
                # destination ran out of per-request device state (e.g.
                # recurrent-state slots, which the KV headroom checks don't
                # cover): release the pages and degrade below
                kv.free(rid)
        if not imported:
            # destination capacity shrank in transit: fall back to recompute
            # admission (correctness preserved — outputs fold into the
            # effective prompt exactly like a preemption).  resident=False:
            # recompute rebuilds recurrent state from scratch, so only
            # recompute-surviving state (encoder embeddings) attaches.
            req.preempt()
            if kind == "handoff":
                self.disagg_stats.fallbacks += 1
            else:
                self.rebalance_stats.migration_fallbacks += 1
            dst.backend.import_request_state(req, state, resident=False)
        dst.scheduler.adopt_request(req)
        _record_move_in(dst, req, now, kind)
        _advance_replica_clock(dst, now)

    # ------------------------------------------------- elastic lifecycle (§16)
    def add_replica_hook(self, fn: Callable[[Any, int, float], None]) -> None:
        """Register `fn(replica, ordinal, now)` to run on every replica the
        autoscaler adds — the integration seam: `SimCluster` namespaces the
        rid stream and attaches the per-replica trace; `LLMServer` wires
        token/preempt callbacks."""
        self._add_hooks.append(fn)

    def add_replica(self, now: Optional[float] = None) -> int:
        """Grow the fleet by one replica from `replica_factory` (role
        `mixed`, unit capacity — elastic replicas are the homogeneous pool;
        heterogeneous hints belong to the static fleet).  Returns the new
        replica's index."""
        if self.replica_factory is None:
            raise RuntimeError("ReplicaRouter has no replica_factory; "
                               "cannot scale up")
        if now is None:
            now = self._clock()
        ordinal = self._next_ordinal
        self._next_ordinal += 1
        replica = self.replica_factory(ordinal)
        self.replicas.append(replica)
        self.replica_ids.append(ordinal)
        self.capacity_hints.append(1.0)
        self.capacities.append(1.0)
        self._caps_eff.append(1.0)
        self.roles = self.roles + (ROLE_MIXED,)
        self._routed_by_id[ordinal] = 0
        self._seen_finished[ordinal] = 0
        for hook in self._add_hooks:
            hook(replica, ordinal, now)
        rec = getattr(replica, "recorder", None)
        if rec is not None:     # first record of the newborn's trace stream
            rec.record_scale_event("scale_up", now)
        self.autoscale_stats.replicas_added += 1
        self.autoscale_stats.note(now, "scale_up", len(self._serving()))
        if self._trace is not None:
            self._trace.write({"kind": "scale_up", "now": now,
                               "replica": ordinal,
                               "fleet": len(self._serving())})
        return len(self.replicas) - 1

    def start_drain(self, i: int, now: Optional[float] = None) -> None:
        """Begin retiring replica `i`: mask it from admission and from
        control-plane destinations; subsequent control ticks move its work
        off (waiting requests are stolen, residents live-migrated) and
        retire it once empty.  Refuses a drain that would leave the serving
        fleet without prefill or decode cover (§15 roles)."""
        ordinal = self.replica_ids[i]
        if ordinal in self._draining:
            raise ValueError(f"replica {ordinal} is already draining")
        if now is None:
            now = self._clock()
        serving = self._serving()
        serving_roles = [self.roles[j] for j in serving]
        if len(serving) <= 1 or not retirable(serving_roles,
                                              serving.index(i)):
            raise ValueError(
                f"draining replica {ordinal} would leave the fleet without "
                f"prefill or decode cover (serving roles: {serving_roles})")
        self._draining.add(ordinal)
        if self._next_drain_due is None:
            self._next_drain_due = now + self._drain_interval()
        self.autoscale_stats.drains_started += 1
        self.autoscale_stats.note(now, "drain", len(self._serving()))
        _record_scale(self.replicas[i], "drain", now)
        if self._trace is not None:
            self._trace.write({"kind": "drain", "now": now,
                               "replica": ordinal,
                               "fleet": len(self._serving())})

    def _drain_interval(self) -> float:
        """Cadence of drain pushes: the autoscaler's interval when the
        drain came from the policy loop, a fixed 50ms for manual drains."""
        return (self.autoscale_policy.interval
                if self.autoscale_policy is not None else 0.05)

    def _drain_dst(self, victim_i: int, req: Request) -> Optional[int]:
        """Destination for work leaving a draining replica.  Unlike the
        rebalance plane's moves, drains are *mandatory* — the victim must
        empty — so headroom is a preference, not a gate: prefer serving
        replicas whose projected KV absorbs the request, but fall back to
        any serving role-compatible one (`_deliver` degrades to recompute
        admission if its pool shrank by arrival time)."""
        cands = [i for i in self._serving()
                 if i != victim_i and self._role_ok(i, req)
                 and self._servable_on(self.replicas[i], req)]
        if not cands:
            return None
        scores = self.scores(0)
        good = [i for i in cands
                if self._dst_headroom_ok(self.replicas[i], req)]
        return min(good or cands, key=lambda i: scores[i])

    def _drain_move(self, victim_i: int, dst_i: int, req: Request,
                    now: float) -> bool:
        """One forced move off a draining replica (kept as a single seam so
        chaos tests can fault-inject a broken drain)."""
        return self._move_request(req.request_id, victim_i, dst_i,
                                  now=now, kind="migrate")

    def _drain_pass(self, now: float) -> None:
        """Push every active drain forward: move the victim's waiting queue
        and resident prefill/decode state to serving replicas (up to
        `drain_batch` per pass — in-flight requests are undrainable this
        tick and retry next pass), then retire victims that emptied."""
        cap = self.autoscale_policy.drain_batch \
            if self.autoscale_policy is not None else 16
        for ordinal in sorted(self._draining):
            i = self._index_of(ordinal)
            victim = self.replicas[i]
            sched = victim.scheduler
            moved = 0
            # waiting first (cheap, no KV on the wire), then residents
            candidates = (list(sched.waiting) + list(sched.running_decode)
                          + list(sched.running_prefill))
            for req in candidates:
                if moved >= cap:
                    break
                dst_i = self._drain_dst(i, req)
                if dst_i is None:
                    continue
                if self._drain_move(i, dst_i, req, now):
                    moved += 1
            self.autoscale_stats.drain_moves += moved
            self._try_retire(ordinal, now)

    def _try_retire(self, ordinal: int, now: float) -> bool:
        """Retire a draining replica iff nothing references it anymore: no
        scheduler work, no in-flight ticks, nothing in transit toward it.
        The replica object moves to `self.retired` so its finished-request
        history stays part of the cluster's accounting."""
        i = self._index_of(ordinal)
        victim = self.replicas[i]
        if victim.has_work or victim.busy:
            return False
        if any(entry[2] == ordinal for entry in self._in_transit):
            return False
        # final bookkeeping sweep before the finished list freezes
        for req in _finished_of(victim)[self._seen_finished.get(ordinal, 0):]:
            self._migrations_of.pop(req.request_id, None)
            self._handoffs_of.pop(req.request_id, None)
        self._seen_finished.pop(ordinal, None)
        _record_scale(victim, "retire", now)
        rec = getattr(victim, "recorder", None)
        if rec is not None:
            rec.close()     # `retire` is the stream's last record
        del self.replicas[i]
        del self.replica_ids[i]
        del self.capacities[i]
        del self.capacity_hints[i]
        del self._caps_eff[i]
        self.roles = self.roles[:i] + self.roles[i + 1:]
        self._draining.discard(ordinal)
        self.retired.append(victim)
        self.autoscale_stats.retired += 1
        self.autoscale_stats.note(now, "retire", len(self._serving()))
        if self._trace is not None:
            self._trace.write({"kind": "retire", "now": now,
                               "replica": ordinal,
                               "fleet": len(self._serving())})
        return True

    def _autoscale_pass(self, now: float) -> None:
        """One autoscale decision on the EWMA of fleet pressure: grow on
        sustained overload, start (at most one) drain on sustained
        underload.  Hysteresis = threshold gap + per-direction cooldowns;
        the drain victim is the lowest-pressure replica whose removal keeps
        role cover."""
        pol = self.autoscale_policy
        self.autoscale_stats.passes += 1
        serving = self._serving()
        p = fleet_pressure([self.replicas[i] for i in serving], pol)
        if self._pressure_ewma is None:
            self._pressure_ewma = p
        else:
            self._pressure_ewma += pol.ewma_alpha * (p - self._pressure_ewma)
        ewma = self._pressure_ewma
        n = len(serving)
        if (ewma > pol.up_threshold and n < pol.max_replicas
                and now - self._last_scale_up >= pol.up_cooldown
                and self.replica_factory is not None):
            step = scale_up_step(n, ewma, pol)
            for _ in range(step):
                self.add_replica(now)
            if step:
                self.autoscale_stats.scale_ups += 1
                self._last_scale_up = now
            return
        if (ewma < pol.down_threshold and n > pol.min_replicas
                and not self._draining
                and now - self._last_scale_down >= pol.down_cooldown):
            victims = sorted(
                serving,
                key=lambda i: replica_pressure(self.replicas[i], pol))
            roles = [self.roles[i] for i in serving]
            for i in victims:
                if retirable(roles, serving.index(i)):
                    self.start_drain(i, now)
                    self._last_scale_down = now
                    break

    def check_invariants(self,
                         expected_rids: Optional[Sequence[str]] = None
                         ) -> None:
        """Cluster-wide conservation audit (the chaos suite runs this after
        every operation): every per-replica scheduler invariant holds, no
        request id appears in two places at once (across all waiting /
        running groups and the in-transit heap), no id finishes twice, and
        — when `expected_rids` is given — every submitted request is
        accounted for somewhere (alive, in transit, or finished)."""
        alive: Dict[str, str] = {}

        def see(rid: str, where: str) -> None:
            if rid in alive:
                raise AssertionError(
                    f"request {rid!r} is both {alive[rid]} and {where}")
            alive[rid] = where

        for ordinal, r in zip(self.replica_ids, self.replicas):
            sched = r.scheduler
            sched.check_invariants()
            local: Dict[str, str] = {}
            for group, name in ((sched.waiting, "waiting"),
                                (sched.running_prefill, "running_prefill"),
                                (sched.running_decode, "running_decode")):
                for req in group:
                    if req.request_id in local:
                        raise AssertionError(
                            f"request {req.request_id!r} is both "
                            f"{local[req.request_id]} and {name} on "
                            f"replica{ordinal}")
                    local[req.request_id] = name
            # mid-tick, a request whose *final* prefill chunk is in flight
            # has left `waiting` but not yet entered `running_decode` — it
            # is alive only in the scheduled batch (a decode/chunk seq also
            # appears in its running list, hence setdefault, not see)
            for bid in sched.active_batch_ids():
                for seq in sched.get_batch(bid).seqs:
                    local.setdefault(seq.request.request_id, "in-flight")
            for rid, name in local.items():
                see(rid, f"replica{ordinal}:{name}")
        for entry in self._in_transit:
            see(entry[3].request_id, "in-transit")
        counts: Dict[str, int] = {}
        for req in self.finished:
            counts[req.request_id] = counts.get(req.request_id, 0) + 1
        dups = sorted(rid for rid, c in counts.items() if c > 1)
        if dups:
            raise AssertionError(f"requests finished more than once: {dups}")
        both = sorted(set(alive) & set(counts))
        if both:
            raise AssertionError(
                f"requests both alive and finished: {both}")
        if expected_rids is not None:
            seen = set(alive) | set(counts)
            missing = sorted(set(expected_rids) - seen)
            if missing:
                raise AssertionError(f"requests lost (not alive, in "
                                     f"transit, or finished): {missing}")

    # ---------------------------------------------------------------- abort
    def abort_request(self, rid: str) -> bool:
        """Abort a request anywhere in the cluster: on whichever replica
        holds it (waiting — including a stolen request sitting in a
        destination queue — or running), or *mid-migration* while its KV
        payload is in transit between replicas.

        The in-transit case is the one only the router can see: the source
        already exported-and-freed the pages and released the request's
        state slot, the destination has allocated nothing yet, so dropping
        the queued delivery leaks nothing — the payload and exported state
        are host-held copies.  Without this path the delivery would land
        after the abort and permanently re-admit a request nobody wants
        (re-acquiring pages and a slot on the destination).
        """
        for i, entry in enumerate(self._in_transit):
            req = entry[3]
            if req.request_id == rid:
                self._in_transit.pop(i)
                heapq.heapify(self._in_transit)
                self._migrations_of.pop(rid, None)
                self._handoffs_of.pop(rid, None)
                req.state = RequestState.FINISHED_ABORTED
                req.metrics.finish_time = self._clock()
                self._aborted.append(req)
                return True
        for replica in self.replicas:
            if _abort_on_replica(replica, rid):
                self._migrations_of.pop(rid, None)
                self._handoffs_of.pop(rid, None)
                return True
        return False

    # ------------------------------------------------- engine-cluster surface
    def add_request(self, prompt: Sequence[int],
                    sampling: Optional[SamplingParams] = None,
                    request_id: Optional[str] = None, **kw) -> Request:
        i = self.select(len(prompt), prompt=prompt)
        return self.replicas[i].add_request(prompt, sampling, request_id,
                                            **kw)

    @property
    def scheduler(self):
        """Single-replica compatibility: the scheduler when fronting one
        replica (ambiguous otherwise)."""
        if len(self.replicas) != 1:
            raise AttributeError(
                "ReplicaRouter fronts multiple replicas; inspect "
                ".replicas[i].scheduler")
        return self.replicas[0].scheduler

    @property
    def has_work(self) -> bool:
        return any(r.has_work for r in self.replicas) or self.has_in_transit

    @property
    def busy(self) -> bool:
        return any(r.busy for r in self.replicas)

    def _clock(self) -> float:
        return max(r.backend.clock() for r in self.replicas)

    def step(self) -> List[Request]:
        """One tick on every replica that has work (the single-process
        analogue of N independent driver loops), preceded by any due
        control-plane work on the backend clock."""
        if self.rebalance_policy is not None \
                or self.handoff_policy is not None \
                or self.autoscale_policy is not None \
                or self._draining or self._in_transit:
            self.control_tick(self._clock())
        out: List[Request] = []
        for r in self.replicas:
            if r.has_work or r.busy:
                out.extend(r.step())
        return out

    def drain(self, max_ticks: int = 100000) -> List[Request]:
        out: List[Request] = []
        t = 0
        while (self.has_work or self.busy) and t < max_ticks:
            out.extend(self.step())
            t += 1
        return out

    @property
    def finished(self) -> List[Request]:
        out: List[Request] = []
        for r in self.replicas:
            out.extend(_finished_of(r))
        for r in self.retired:     # history survives the replica's retirement
            out.extend(_finished_of(r))
        out.extend(self._aborted)
        return out


# --------------------------------------------------------------------------
# Replica plumbing helpers (engines and simulators expose slightly different
# surfaces; the control plane treats them uniformly through these)
# --------------------------------------------------------------------------

def _finished_of(replica) -> List[Request]:
    fin = getattr(replica, "finished", None)
    if fin is not None:
        return fin
    return replica.metrics.finished


def _abort_on_replica(replica, rid: str) -> bool:
    """Abort through the replica's own entry point when it has one (engines
    and simulators serialize against their tick/trace machinery); fall back
    to the bare scheduler + backend release for test doubles."""
    fn = getattr(replica, "abort_request", None)
    if fn is not None:
        return bool(fn(rid))
    req = replica.scheduler.abort_request(rid, replica.backend.clock())
    if req is None:
        return False
    if req.is_finished:
        replica.backend.finish_request(req)
    return True


def _advance_replica_clock(replica, now: float) -> None:
    """A request materialized on this replica at `now` by control-plane
    action (not an arrival): virtual-time backends must not tick earlier
    than that.  Wall-clock backends ignore it."""
    fn = getattr(replica, "advance_clock", None)
    if fn is not None:
        fn(now)


def _record_move_out(replica, rid: str, now: float, kind: str) -> None:
    rec = getattr(replica, "recorder", None)
    if rec is not None:
        rec.record_move_out(rid, now, kind=kind)


def _record_move_in(replica, req: Request, now: float, kind: str) -> None:
    rec = getattr(replica, "recorder", None)
    if rec is not None:
        rec.record_move_in(req, now, kind=kind)


def _record_scale(replica, kind: str, now: float) -> None:
    rec = getattr(replica, "recorder", None)
    if rec is not None:
        rec.record_scale_event(kind, now)


class SimCluster:
    """N `PipelineSimulator` replicas behind a `ReplicaRouter`, driven in
    causally-consistent virtual time: each arrival first advances every
    replica to the arrival instant, then routes on the resulting state.
    Control-plane events (periodic rebalance passes, migration deliveries)
    are interleaved at their own instants the same way."""

    def __init__(self, sims: Sequence[Any], router: ReplicaRouter,
                 *, trace_dir: Optional[str] = None) -> None:
        # the router's replica list is authoritative — the autoscaler
        # mutates it (add/retire) and the cluster must track those changes,
        # so `self.sims` is a live view, not a copy
        if list(sims) != router.replicas:
            raise ValueError(
                "SimCluster must front the router's own replica list")
        self.router = router
        for ordinal, sim in zip(router.replica_ids, self.sims):
            # migration needs cluster-unique request ids: namespace each
            # replica's default id stream (engines already share a
            # process-wide counter)
            if getattr(sim, "rid_prefix", None) == "r":
                sim.rid_prefix = f"r{ordinal}:"
        self._trace_dir = trace_dir
        if trace_dir is not None:
            # one tick trace per replica + the router's placement stream —
            # together they capture the whole cluster run for offline replay
            import os
            os.makedirs(trace_dir, exist_ok=True)
            for ordinal, sim in zip(router.replica_ids, self.sims):
                sim.attach_trace(
                    os.path.join(trace_dir, f"replica{ordinal}.trace.jsonl"))
            if router._trace is None:
                router.open_trace(
                    os.path.join(trace_dir, "router.trace.jsonl"))
        router.add_replica_hook(self._on_add_replica)

    @property
    def sims(self) -> List[Any]:
        return self.router.replicas

    def _on_add_replica(self, sim, ordinal: int, now: float) -> None:
        """Bring an autoscaler-added simulator into the cluster: namespaced
        rid stream, its own trace file, clock advanced to its birth instant
        (it must not tick in the past)."""
        if getattr(sim, "rid_prefix", None) == "r":
            sim.rid_prefix = f"r{ordinal}:"
        if self._trace_dir is not None:
            import os
            sim.attach_trace(os.path.join(
                self._trace_dir, f"replica{ordinal}.trace.jsonl"))
        sim.advance_clock(now)

    def _advance_to(self, t: float) -> None:
        """Advance every replica to `t`, running control-plane events
        (rebalance passes, migration deliveries) at their due instants."""
        while True:
            due = self.router.next_control_event()
            if due is None or due > t:
                break
            for sim in self.sims:
                sim.run_until(due)
            self.router.control_tick(due)
        for sim in self.sims:
            sim.run_until(t)

    @property
    def _cluster_busy(self) -> bool:
        return self.router.has_in_transit or bool(self.router._draining) \
            or any(s.sched.has_work or s.loop.busy or s._arrivals
                   for s in self.sims)

    # ------------------------------------------------- engine-compatible API
    # The serving layer drives a sim cluster through the same surface as a
    # single engine: submissions are placed by the router at the cluster's
    # current virtual instant; one `step()` advances the earliest-due
    # replica (control-plane events interleaved at their own instants).

    @property
    def replicas(self) -> List[Any]:
        return self.sims

    @property
    def has_work(self) -> bool:
        return self._cluster_busy

    @property
    def busy(self) -> bool:
        return any(s.loop.busy for s in self.sims)

    def add_request(self, prompt: Sequence[int],
                    sampling: Optional[SamplingParams] = None,
                    request_id: Optional[str] = None) -> Request:
        # causality: route on the state every replica has reached by "now"
        t = max(s.backend.time for s in self.sims)
        self._advance_to(t)
        return self.router.add_request(prompt, sampling, request_id)

    def abort_request(self, rid: str) -> bool:
        return self.router.abort_request(rid)

    def _finished_marks(self) -> Dict[Any, int]:
        """Per-source finished-list lengths (every replica — live *or*
        retired — plus the router's in-transit-aborted list), keyed by the
        source object: new finishes land in *whichever* source's list, the
        fleet can change size between marks, and a replica can finish work
        and then retire within one step — so the marks must survive both."""
        marks: Dict[Any, int] = {
            id(s): len(s.metrics.finished)
            for s in itertools.chain(self.sims, self.router.retired)}
        marks["aborted"] = len(self.router._aborted)
        return marks

    def _finished_since(self, marks: Dict[Any, int]) -> List[Request]:
        out: List[Request] = []
        for sim in itertools.chain(self.sims, self.router.retired):
            out.extend(sim.metrics.finished[marks.get(id(sim), 0):])
        out.extend(self.router._aborted[marks.get("aborted", 0):])
        return out

    def step(self) -> List[Request]:
        """Advance the cluster by one event: every replica runs to the
        earliest pending tick instant (control-plane events — rebalance
        passes, migration deliveries — fire at their due times on the way)."""
        marks = self._finished_marks()
        pending = [s for s in self.sims
                   if s.sched.has_work or s.loop.busy or s._arrivals]
        if pending:
            self._advance_to(min(s._next_tick_time() for s in pending))
        elif self.router.has_in_transit or self.router._draining:
            due = self.router.next_control_event()
            if due is not None:
                self._advance_to(due)
                self.router.control_tick(due)
        return self._finished_since(marks)

    def drain(self, max_ticks: int = 1_000_000) -> List[Request]:
        marks = self._finished_marks()
        last = None
        for _ in range(max_ticks):
            if not self._cluster_busy:
                break
            self.step()
            # wedge guard: identical clocks + frontiers + completions across
            # two steps means nothing can unblock (e.g. every waiting request
            # UT-gated with no decode to retire) — stop instead of spinning
            state = (tuple((s.backend.time, s.backend.stage_free_at[0])
                           for s in self.sims),
                     self._finished_marks(), len(self.router._in_transit))
            if state == last:
                break
            last = state
        return self._finished_since(marks)

    def run(self, arrivals: Iterable[Tuple],
            until: float = float("inf")) -> List[Request]:
        """arrivals: (time, prompt_tokens, output_len[, sampling]), any
        order — the optional 4th element is a `SamplingParams` (SLO class,
        priority, ...).  Returns all finished requests across replicas."""
        t = 0.0
        for t, prompt, out_len, *rest in sorted(arrivals,
                                                key=lambda a: a[0]):
            if t > until:
                break
            self._advance_to(t)
            i = self.router.select(len(prompt), prompt=prompt)
            self.sims[i].inject_request(t, prompt, out_len, *rest)
        intervals = [p.interval for p in (self.router.rebalance_policy,
                                          self.router.handoff_policy,
                                          self.router.autoscale_policy)
                     if p is not None]
        if not intervals:
            for sim in self.sims:
                sim.run(until)
            return self.finished
        # drain with the control plane still ticking: advance in interval
        # steps so rebalance/handoff keep seeing fresh state until the last
        # replica goes idle
        step = min(intervals)
        for _ in range(10_000_000):
            if not self._cluster_busy or t > until:
                break
            t += step
            self._advance_to(min(t, until))
        for sim in self.sims:
            sim.run(until)
        return self.finished

    @property
    def finished(self) -> List[Request]:
        out: List[Request] = []
        for sim in itertools.chain(self.sims, self.router.retired):
            out.extend(sim.metrics.finished)
        out.extend(self.router._aborted)   # aborted while in transit
        return out

    # ------------------------------------------------------------- aggregates
    def ttft_quantile(self, q: float) -> float:
        vals = [r.metrics.ttft() for r in self.finished
                if r.metrics.ttft() is not None]
        return float(np.quantile(vals, q)) if vals else 0.0

    def mean_ttft(self) -> float:
        vals = [r.metrics.ttft() for r in self.finished
                if r.metrics.ttft() is not None]
        return float(np.mean(vals)) if vals else 0.0

    def throughput(self) -> float:
        return float(sum(s.metrics.throughput()
                         for s in itertools.chain(self.sims,
                                                  self.router.retired)))
