"""DEPRECATED: decoupled asynchronous frontend (paper §3.3 principle 2).

The async intake/streaming loop now lives inside the public serving API —
`repro.serving.LLMServer.generate_stream` spawns the same
worker-thread-steps / event-loop-streams split on demand.  `AsyncFrontend`
is kept for one release as a thin back-compat veneer and warns on
construction; new code should do:

    from repro.serving import ServeSpec, SamplingParams, build
    server = build(ServeSpec(...))
    async for delta in server.generate_stream(prompt, sampling): ...
"""

from __future__ import annotations

import asyncio
import itertools
import warnings
from typing import AsyncIterator, Dict, List, Optional, Sequence, Union

from repro.core import Request, SamplingParams
from repro.runtime.engine import PipelineEngine
from repro.runtime.router import ReplicaRouter


class AsyncFrontend:
    def __init__(self, engine: Union[PipelineEngine, ReplicaRouter]) -> None:
        warnings.warn(
            "AsyncFrontend is deprecated; use repro.serving.build(...) and "
            "LLMServer.generate_stream instead",
            DeprecationWarning, stacklevel=2)
        if isinstance(engine, ReplicaRouter):
            self.router = engine
        else:
            self.router = ReplicaRouter([engine])
        self.engine = engine                      # as handed in (back-compat)
        self._streams: Dict[str, asyncio.Queue] = {}
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._stop = False
        for replica in self.router.replicas:
            replica.on_token = self._on_token

    _rid_counter = itertools.count()

    # ------------------------------------------------------------- intake
    async def submit(self, prompt: Sequence[int],
                     sampling: Optional[SamplingParams] = None,
                     request_id: Optional[str] = None) -> str:
        rid = request_id or f"fe-{next(AsyncFrontend._rid_counter)}"
        # register the stream BEFORE the engine can see the request: the
        # worker thread may step the moment add_request lands, and tokens
        # emitted before the queue exists would be lost
        self._streams[rid] = asyncio.Queue()
        try:
            self.router.add_request(prompt, sampling, rid)
        except Exception:
            self._streams.pop(rid, None)
            raise
        return rid

    async def stream(self, request_id: str) -> AsyncIterator[int]:
        q = self._streams[request_id]
        while True:
            tok = await q.get()
            if tok is None:
                break
            yield tok
        self._streams.pop(request_id, None)

    async def generate(self, prompt: Sequence[int],
                       sampling: Optional[SamplingParams] = None
                       ) -> List[int]:
        rid = await self.submit(prompt, sampling)
        return [t async for t in self.stream(rid)]

    # --------------------------------------------------------------- engine
    def _on_token(self, req: Request, tok: int) -> None:
        q = self._streams.get(req.request_id)
        if q is None or self._loop is None:
            return
        self._loop.call_soon_threadsafe(q.put_nowait, tok)
        if req.is_finished:
            self._loop.call_soon_threadsafe(q.put_nowait, None)

    async def run(self, idle_sleep: float = 0.002) -> None:
        """Engine loop: blocking ticks on a thread; intake stays responsive."""
        self._loop = asyncio.get_running_loop()
        while not self._stop:
            if self.router.has_work or self.router.busy:
                await asyncio.to_thread(self.router.step)
            else:
                await asyncio.sleep(idle_sleep)

    def stop(self) -> None:
        self._stop = True
