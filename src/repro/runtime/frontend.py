"""Decoupled asynchronous frontend (paper §3.3 design principle 2).

Request intake and token streaming run on the asyncio loop; the engine's
blocking device steps run on a worker thread, so user interaction never
stalls model execution (and vice versa).  This is the JAX-native analogue of
gLLM's separate frontend process + ZeroMQ sockets.
"""

from __future__ import annotations

import asyncio
from typing import AsyncIterator, Dict, List, Optional, Sequence

from repro.core import Request, SamplingParams
from repro.runtime.engine import PipelineEngine


class AsyncFrontend:
    def __init__(self, engine: PipelineEngine) -> None:
        self.engine = engine
        self._streams: Dict[str, asyncio.Queue] = {}
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._stop = False
        engine.on_token = self._on_token

    # ------------------------------------------------------------- intake
    async def submit(self, prompt: Sequence[int],
                     sampling: Optional[SamplingParams] = None,
                     request_id: Optional[str] = None) -> str:
        req = self.engine.add_request(prompt, sampling, request_id)
        self._streams[req.request_id] = asyncio.Queue()
        return req.request_id

    async def stream(self, request_id: str) -> AsyncIterator[int]:
        q = self._streams[request_id]
        while True:
            tok = await q.get()
            if tok is None:
                break
            yield tok
        self._streams.pop(request_id, None)

    async def generate(self, prompt: Sequence[int],
                       sampling: Optional[SamplingParams] = None
                       ) -> List[int]:
        rid = await self.submit(prompt, sampling)
        return [t async for t in self.stream(rid)]

    # --------------------------------------------------------------- engine
    def _on_token(self, req: Request, tok: int) -> None:
        q = self._streams.get(req.request_id)
        if q is None or self._loop is None:
            return
        self._loop.call_soon_threadsafe(q.put_nowait, tok)
        if req.is_finished:
            self._loop.call_soon_threadsafe(q.put_nowait, None)

    async def run(self, idle_sleep: float = 0.002) -> None:
        """Engine loop: blocking ticks on a thread; intake stays responsive."""
        self._loop = asyncio.get_running_loop()
        while not self._stop:
            if self.engine.has_work or self.engine._ring_busy():
                await asyncio.to_thread(self.engine.step)
            else:
                await asyncio.sleep(idle_sleep)

    def stop(self) -> None:
        self._stop = True
