"""Sharded, async checkpointing (no orbax/tensorstore in this container).

Layout: one .npy per pytree leaf (path-encoded filename) + manifest.json
(tree structure, shapes, dtypes, step metadata, engine/scheduler snapshot).
On restore, leaves are device_put with the *target* sharding — which may
belong to a different mesh factoring than the one that saved them (elastic
re-sharding: params are stored logically, so a pp=16/tp=1 checkpoint loads
into a pp=8/tp=2 engine unchanged; see distributed/elastic.py for stacked-dim
repartitioning when the stage grid itself changes).

`AsyncCheckpointer` snapshots to host memory synchronously (cheap) and
writes in a background thread so the train/serve loop is never blocked —
the "async checkpointing" of the 1000+-node design (DESIGN.md §5).
"""

from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, tree, *, extra: Optional[dict] = None
                    ) -> None:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    manifest = {"leaves": {}, "extra": extra or {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        fname = key.replace("/", "__") + ".npy"
        dtype = str(arr.dtype)
        if dtype == "bfloat16":          # numpy can't round-trip ml_dtypes
            np.save(os.path.join(directory, fname), arr.view(np.uint16))
        else:
            np.save(os.path.join(directory, fname), arr)
        manifest["leaves"][key] = {"file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": dtype}
    tmp = os.path.join(directory, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(directory, "manifest.json"))


def load_manifest(directory: str) -> dict:
    with open(os.path.join(directory, "manifest.json")) as f:
        return json.load(f)


def restore_checkpoint(directory: str, target_tree, *, shardings=None):
    """Restore into the structure of `target_tree` (values ignored).  With
    `shardings` (matching pytree of jax.sharding.Sharding), leaves are placed
    sharded — this is the elastic-rescale path."""
    manifest = load_manifest(directory)
    flat_target = _flatten_with_paths(target_tree)
    flat_shard = _flatten_with_paths(shardings) if shardings is not None \
        else {}
    out = {}
    for key in flat_target:
        info = manifest["leaves"].get(key)
        if info is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(directory, info["file"]))
        if info["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        sh = flat_shard.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None else arr
    # rebuild the tree
    leaves_paths = jax.tree_util.tree_flatten_with_path(target_tree)
    keys = ["/".join(_path_str(p) for p in path)
            for path, _ in leaves_paths[0]]
    return jax.tree_util.tree_unflatten(leaves_paths[1],
                                        [out[k] for k in keys])


class AsyncCheckpointer:
    """Non-blocking checkpoint writer (single background thread, snapshot
    taken synchronously on submit)."""

    def __init__(self) -> None:
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            directory, host_tree, extra = item
            try:
                save_checkpoint(directory, host_tree, extra=extra)
            except BaseException as e:  # noqa: BLE001
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, directory: str, tree, *, extra: Optional[dict] = None
               ) -> None:
        if self._err:
            raise self._err
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot
        self._q.put((directory, host_tree, extra))

    def wait(self) -> None:
        self._q.join()
        if self._err:
            raise self._err

    def close(self) -> None:
        self._q.put(None)
        self._t.join()
