"""Disaggregated prefill/decode serving: replica roles + handoff policy
(DESIGN.md §15).

gLLM's Token Throttling balances prefill and decode *within* hybrid
batches; TD-Pipe argues the two phases should be *temporally separated* —
prefill and decode interfere inside a tick (a large prefill chunk inflates
every co-scheduled decode's token-to-token latency), so dedicating whole
replicas to each phase buys clean TBT at the cost of moving every
request's KV once.  This module holds the declarative half of that cluster
shape:

* **roles** — each replica is `"prefill"`, `"decode"`, or `"mixed"`.
  `ReplicaRouter` admits new requests only to prefill-capable replicas
  (prefill or mixed) and hands work off to decode-capable ones.
* **`HandoffPolicy`** — when and how aggressively a prefill-role replica
  ships a request that has completed its prefill to a decode replica.
  The handoff rides the PR 3 live-migration wire format (`export_kv` /
  `import_kv` + backend page gather/scatter) and is recorded as
  `handoff` records (trace schema 1.5) so per-replica traces replay
  byte-identically through the move.

The router owns the pass itself (it needs balance scores and the
in-transit machinery); this module stays import-light — policy data,
role vocabulary, candidate selection — so the spec layer can depend on
it without pulling in the control plane.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

ROLE_PREFILL = "prefill"
ROLE_DECODE = "decode"
ROLE_MIXED = "mixed"
ROLES = (ROLE_PREFILL, ROLE_DECODE, ROLE_MIXED)


def prefill_capable(role: str) -> bool:
    """May admit new requests (runs prefill chunks)."""
    return role != ROLE_DECODE


def decode_capable(role: str) -> bool:
    """May hold decode residents (receives handoffs)."""
    return role != ROLE_PREFILL


def validate_roles(roles: Sequence[str], num_replicas: int
                   ) -> Tuple[str, ...]:
    """Normalize + validate a per-replica role assignment: one role per
    replica, values from `ROLES`, and the cluster must be able to both
    admit (>=1 prefill-capable) and decode (>=1 decode-capable)."""
    out = tuple(roles)
    if len(out) != num_replicas:
        raise ValueError(
            f"one role per replica: got {len(out)} roles for "
            f"{num_replicas} replicas")
    for r in out:
        if r not in ROLES:
            raise ValueError(f"unknown replica role {r!r}; "
                             f"expected one of {ROLES}")
    if not any(prefill_capable(r) for r in out):
        raise ValueError("cluster has no prefill-capable replica; "
                         "new requests could never be admitted")
    if not any(decode_capable(r) for r in out):
        raise ValueError("cluster has no decode-capable replica; "
                         "prefilled requests could never decode")
    return out


def retirable(roles: Sequence[str], i: int) -> bool:
    """May replica `i` be drained and retired without breaking the fleet's
    role cover?  The surviving set must still satisfy `validate_roles`'
    liveness conditions: at least one prefill-capable replica (or new
    requests could never be admitted) and at least one decode-capable one
    (or prefilled requests could never decode).  The autoscaler checks
    this before choosing a drain victim — the last prefill- or
    decode-capable replica of a disaggregated fleet is never retired."""
    rest = [r for j, r in enumerate(roles) if j != i]
    return (any(prefill_capable(r) for r in rest)
            and any(decode_capable(r) for r in rest))


@dataclass(frozen=True)
class HandoffPolicy:
    """When a prefill-role replica ships a freshly-prefilled request to a
    decode replica.  Mirrors `RebalancePolicy`'s shape: a polling
    `interval`, a per-pass cap, and hysteresis so the disagg plane and
    the rebalance plane don't fight over the same KV.

    A request becomes handoff-eligible the moment its prefill completes
    (the final chunk samples the first token, so "zero decode steps
    executed" is `num_output_tokens <= 1`); it *stays* eligible while it
    has sampled at most `max_decode_tokens` outputs — a deferred handoff
    (no destination headroom this pass) retries on later passes until the
    request is established decode work, at which point moving it is the
    rebalance plane's call, not a handoff.  Destination choice reuses
    `balance_score` over decode-capable replicas with the same
    projected-KV headroom guard as live migration; each request moves at
    most `max_request_handoffs` times.
    """

    interval: float = 0.05
    handoff_batch: int = 8
    max_decode_tokens: int = 4
    max_request_handoffs: int = 1


@dataclass
class DisaggStats:
    """Control-plane counters for the handoff plane (surfaced through
    `LLMServer.stats()` / `GET /v1/stats`)."""

    passes: int = 0
    handoffs: int = 0
    handoff_tokens: int = 0     # KV tokens shipped prefill -> decode
    deferred: int = 0           # eligible but no destination had headroom
    fallbacks: int = 0          # delivery degraded to recompute admission


def handoff_candidates(replica, policy: HandoffPolicy,
                       handoffs_of: Dict[str, int]) -> List:
    """First-decode requests on a prefill-role replica, in handoff
    priority order: least decode progress first (the cheapest point to
    move — minimal KV beyond the prompt, no decode momentum lost), ties
    broken toward the earliest arrival (TTFT debt)."""
    out = [r for r in replica.scheduler.running_decode
           if r.num_output_tokens <= policy.max_decode_tokens
           and handoffs_of.get(r.request_id, 0)
           < policy.max_request_handoffs]
    out.sort(key=lambda r: (r.num_output_tokens,
                            r.metrics.arrival_time))
    return out
