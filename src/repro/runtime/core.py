"""Shared serving-runtime core: the ring/schedule/complete cycle (DESIGN.md §1).

Every serving scenario in this repo — the exact JAX engine, the calibrated
discrete-event simulator, the benchmark drivers — runs the same loop: form a
micro-batch, push it into a depth-S pipeline ring, execute one tick, retire
the micro-batch that exits the ring.  `TickLoop` owns that cycle once;
*what a tick costs and produces* is delegated to an `ExecutionBackend`:

  * `JaxBackend` (runtime/engine.py)   — the jitted SPMD serve tick; tokens
    are real, the clock is the wall clock.
  * `SimBackend` (runtime/simulator.py) — the roofline cost model; tokens are
    placeholders, the clock is virtual time.

This is the same policy/execution split Sarathi-Serve and TD-Pipe use, and it
is what lets `ReplicaRouter` (runtime/router.py) front N replicas of either
kind without touching the tick loop.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, List, Optional, Sequence, Tuple

from repro.core import PipelineScheduler, Request, ScheduledBatch


@dataclass
class ExecResult:
    """Outcome of one pipeline tick, as seen by the exiting micro-batch.

    `tokens` has one sampled token per token-producing seq of the exiting
    batch, in batch order (prefill entries first, then decode) — exactly the
    currency `PipelineScheduler.complete` expects.  `completed_at` is the
    backend-clock time the exiting batch finished its last stage (for the
    engine this is "now"; the simulator reports the modeled completion time).

    `stage_times` optionally attributes the *entering* micro-batch's service
    time per pipeline stage — backends that can't split time per stage
    (the live engine) leave it None; the simulator and trace replay fill it,
    and `CostModel.fit_from_trace` calibrates against it.
    """

    tokens: List[int] = field(default_factory=list)
    completed_at: float = 0.0
    stage_times: Optional[List[float]] = None


class ExecutionBackend:
    """Executes micro-batches for a `TickLoop`.

    Subclasses override `depth`, `prepare`, and `execute`; the remaining
    hooks default to no-ops.  `scheduler` is attached by the TickLoop so the
    backend can resolve batch ids via the public `get_batch` API.
    """

    scheduler: PipelineScheduler

    @property
    def depth(self) -> int:
        """Pipeline depth S = number of in-flight micro-batches (ring size)."""
        raise NotImplementedError

    def clock(self) -> float:
        """Current time on this backend's clock (wall or virtual)."""
        return 0.0

    def prepare(self, batch: Optional[ScheduledBatch]) -> Any:
        """Host-side per-batch payload computed at schedule time (one tick
        ahead of execution — the engine's dual-phase metadata path).  `batch`
        is None for a bubble tick."""
        return None

    def execute(self, ring: Sequence[Tuple[Optional[int], Any]],
                exiting_id: Optional[int], now: float) -> ExecResult:
        """Advance the pipeline by one tick.  `ring[0]` is the micro-batch
        entering stage 0 this tick; `exiting_id` identifies the batch leaving
        the last stage (None for a bubble)."""
        raise NotImplementedError

    def finish_request(self, req: Request) -> None:
        """A request fully completed: release backend-held per-request state."""

    def reset(self, now: float) -> None:
        """Fault recovery: all in-flight work was lost; restart at `now`."""

    # ------------------------------------------------- live migration (§9)
    # The router's control plane moves a *running* request between replicas:
    # the source backend gathers the request's device-resident bytes (KV
    # pages + per-request state), the destination scatters them into its own
    # pools at freshly-allocated addresses.  Backends without real device
    # state (the simulator, trace replay) keep the no-op defaults — the
    # host-side addressing (`PagedKVManager.export_kv/import_kv`) is the
    # shared protocol; these hooks move only the payload.

    def export_kv_pages(self, request_id: str,
                        slots: Sequence[Tuple[int, int]]) -> Any:
        """Gather the KV cache content at `slots` ((page, slot) per resident
        token, sequence order).  Returns an opaque payload for
        `import_kv_pages` on the destination backend; None when the backend
        holds no real bytes."""
        return None

    def import_kv_pages(self, request_id: str, payload: Any,
                        slots: Sequence[Tuple[int, int]]) -> None:
        """Scatter a payload from `export_kv_pages` into this backend's KV
        pools at `slots` (the destination addressing from `import_kv`)."""

    def export_request_state(self, req: Request) -> Any:
        """Detach non-KV per-request device state (encoder caches, state
        slots) for migration; releases it locally."""
        return None

    def import_request_state(self, req: Request, state: Any,
                             resident: bool = True) -> None:
        """Attach state from `export_request_state` on the destination.
        `resident=False` means the request arrives *non-resident* (it will
        recompute from scratch — a stolen waiting request, or a migration
        that fell back to recompute): attach only state that must survive a
        recompute (e.g. encoder embeddings), not residency-scoped state
        like recurrent slots, which recompute rebuilds anyway."""

    def migration_cost(self, num_tokens: int) -> float:
        """Modeled wall-clock seconds to move `num_tokens` of KV off this
        backend (interconnect transfer).  Real backends pay the cost in the
        copy itself and report 0; the simulator models it so migration
        thresholds are tunable in sim."""
        return 0.0


class TickLoop:
    """The single schedule→execute→complete cycle (paper §3.3 driver loop).

    One `step()`:
      1. asks the scheduler for this tick's micro-batch (empty = bubble),
      2. rotates it into the depth-S ring (the batch entering stage 0),
      3. has the backend execute one pipeline tick,
      4. retires the batch exiting the ring: applies its sampled tokens,
         streams them, and releases finished requests.

    A request scheduled at tick t is retired at tick t+S-1 (same tick for a
    depth-1 pipeline) — the pipeline-parallel in-flight window the
    scheduler's exclusion rule (one resident micro-batch per request) is
    built around.
    """

    def __init__(self, scheduler: PipelineScheduler, backend: ExecutionBackend,
                 on_token: Optional[Callable[[Request, int], None]] = None
                 ) -> None:
        self.scheduler = scheduler
        self.backend = backend
        backend.scheduler = scheduler
        S = backend.depth
        self.ring: Deque[Tuple[Optional[int], Any]] = deque(
            [(None, backend.prepare(None)) for _ in range(S)], maxlen=S)
        self.on_token = on_token
        self.finished: List[Request] = []
        self.last_tick_empty = False

    # ------------------------------------------------------------------ state
    @property
    def busy(self) -> bool:
        """True while any real micro-batch is still in the ring."""
        return any(bid is not None for bid, _ in self.ring)

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work or self.busy

    # ------------------------------------------------------------------- tick
    def step(self, now: Optional[float] = None) -> List[Request]:
        """One pipeline tick.  Returns requests finishing this tick."""
        if now is None:
            now = self.backend.clock()
        batch = self.scheduler.schedule(now)
        if batch.is_empty:
            # nothing resident this tick: retire the empty batch immediately
            self.scheduler.complete(batch.batch_id, [], now)
            entry: Tuple[Optional[int], Any] = (None, self.backend.prepare(None))
        else:
            entry = (batch.batch_id, self.backend.prepare(batch))
        self.last_tick_empty = batch.is_empty
        # Rotate: the new batch enters stage 0; the entry reaching the ring's
        # tail is the one executing its LAST stage this tick — its results
        # materialize when `execute` returns.  (For depth 1 that is this
        # tick's own batch: schedule, execute, retire in one step.)
        self.ring.appendleft(entry)
        exiting_id, _ = self.ring[-1]

        result = self.backend.execute(tuple(self.ring), exiting_id, now)

        if exiting_id is None:
            return []
        finished = self._retire(exiting_id, result.tokens,
                                result.completed_at)
        # the retired entry is never read again (the next push would drop
        # it); clear it so `busy` reflects only live work
        self.ring[-1] = (None, self.backend.prepare(None))
        return finished

    def drain(self, now_fn: Callable[[], float],
              max_ticks: int = 100000) -> List[Request]:
        out: List[Request] = []
        t = 0
        while self.has_work and t < max_ticks:
            out.extend(self.step(now_fn()))
            t += 1
        return out

    # ----------------------------------------------------------------- retire
    def _retire(self, batch_id: int, tokens: Sequence[int],
                now: float) -> List[Request]:
        batch = self.scheduler.get_batch(batch_id)
        if batch is None:
            return []
        producing = [s.request for s in batch.seqs if s.produces_token]
        finished = self.scheduler.complete(batch_id, tokens, now)
        if self.on_token is not None:
            for req, tok in zip(producing, tokens):
                self.on_token(req, int(tok))
        for req in finished:
            self.backend.finish_request(req)
            self.finished.append(req)
        return finished

    # ------------------------------------------------------------ fault paths
    def abort_inflight(self, now: Optional[float] = None) -> List[Request]:
        """A worker died: every in-flight micro-batch's results are lost.
        Requests recover by recompute via `scheduler.abort_batch`; requests
        with a pending user abort finalize it instead (backend state
        released, surfaced through `finished` like any completion)."""
        if now is None:
            now = self.backend.clock()
        affected: List[Request] = []
        for bid, _ in list(self.ring):
            if bid is not None:
                affected.extend(self.scheduler.abort_batch(bid, now))
        S = self.ring.maxlen or self.backend.depth
        self.ring.clear()
        self.ring.extend((None, self.backend.prepare(None)) for _ in range(S))
        for req in affected:
            if req.is_finished:
                self.backend.finish_request(req)
                self.finished.append(req)
        return affected
