"""Shared serving-runtime core: the ring/schedule/complete cycle (DESIGN.md §1).

Every serving scenario in this repo — the exact JAX engine, the calibrated
discrete-event simulator, the benchmark drivers — runs the same loop: form a
micro-batch, push it into a depth-S pipeline ring, execute one tick, retire
the micro-batch that exits the ring.  `TickLoop` owns that cycle once;
*what a tick costs and produces* is delegated to an `ExecutionBackend`:

  * `JaxBackend` (runtime/engine.py)   — the jitted SPMD serve tick; tokens
    are real, the clock is the wall clock.
  * `SimBackend` (runtime/simulator.py) — the roofline cost model; tokens are
    placeholders, the clock is virtual time.

This is the same policy/execution split Sarathi-Serve and TD-Pipe use, and it
is what lets `ReplicaRouter` (runtime/router.py) front N replicas of either
kind without touching the tick loop.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, List, Optional, Sequence, Tuple

from repro.core import PipelineScheduler, Request, ScheduledBatch


@dataclass
class ExecResult:
    """Outcome of one pipeline tick, as seen by the exiting micro-batch.

    `tokens` has one sampled token per token-producing seq of the exiting
    batch, in batch order (prefill entries first, then decode) — exactly the
    currency `PipelineScheduler.complete` expects.  `completed_at` is the
    backend-clock time the exiting batch finished its last stage (for the
    engine this is "now"; the simulator reports the modeled completion time).

    `stage_times` optionally attributes the *entering* micro-batch's service
    time per pipeline stage — backends that can't split time per stage
    (the live engine) leave it None; the simulator and trace replay fill it,
    and `CostModel.fit_from_trace` calibrates against it.

    `host_s` optionally reports the host-side time this tick spent outside
    device execution (metadata assembly, embedding lookups, dispatch) — the
    engine measures it, the simulator models it, and trace schema ≥ 1.3
    records it so `RuntimeModel.fit_from_trace` can calibrate the overhead.

    **Deferred form.**  A backend that dispatches asynchronously returns the
    result with `pending` set: a thunk that blocks on the device readback and
    yields the token list.  `resolve()` forces it (idempotently) and caches
    into `tokens`; callers must resolve before reading `tokens`.  Plain
    synchronous results leave `pending` None and `resolve()` is a no-op.
    `ready` optionally carries a *non-blocking* probe for whether the
    deferred readback has already materialized (the engine wires it to
    `jax.Array.is_ready`); the async TickLoop uses it to retire a finished
    batch before scheduling instead of a full tick later.
    """

    tokens: List[int] = field(default_factory=list)
    completed_at: float = 0.0
    stage_times: Optional[List[float]] = None
    host_s: Optional[float] = None
    pending: Optional[Callable[[], List[int]]] = None
    ready: Optional[Callable[[], bool]] = None

    def resolve(self) -> List[int]:
        """Force the deferred readback (if any) and return the tokens."""
        if self.pending is not None:
            thunk, self.pending = self.pending, None
            self.tokens = list(thunk())
        return self.tokens

    def is_ready(self) -> bool:
        """True when `resolve()` would not block: synchronous results always,
        deferred ones when the backend's probe says the device is done (a
        deferred result without a probe conservatively reports False)."""
        if self.pending is None:
            return True
        return bool(self.ready()) if self.ready is not None else False


class ExecutionBackend:
    """Executes micro-batches for a `TickLoop`.

    Subclasses override `depth`, `prepare`, and `execute`; the remaining
    hooks default to no-ops.  `scheduler` is attached by the TickLoop so the
    backend can resolve batch ids via the public `get_batch` API.
    """

    scheduler: PipelineScheduler

    @property
    def depth(self) -> int:
        """Pipeline depth S = number of in-flight micro-batches (ring size)."""
        raise NotImplementedError

    def clock(self) -> float:
        """Current time on this backend's clock (wall or virtual)."""
        return 0.0

    def prepare(self, batch: Optional[ScheduledBatch]) -> Any:
        """Host-side per-batch payload computed at schedule time (one tick
        ahead of execution — the engine's dual-phase metadata path).  `batch`
        is None for a bubble tick."""
        return None

    def execute(self, ring: Sequence[Tuple[Optional[int], Any]],
                exiting_id: Optional[int], now: float) -> ExecResult:
        """Advance the pipeline by one tick.  `ring[0]` is the micro-batch
        entering stage 0 this tick; `exiting_id` identifies the batch leaving
        the last stage (None for a bubble)."""
        raise NotImplementedError

    def finish_request(self, req: Request) -> None:
        """A request fully completed: release backend-held per-request state."""

    def reset(self, now: float) -> None:
        """Fault recovery: all in-flight work was lost; restart at `now`."""

    # ------------------------------------------------- live migration (§9)
    # The router's control plane moves a *running* request between replicas:
    # the source backend gathers the request's device-resident bytes (KV
    # pages + per-request state), the destination scatters them into its own
    # pools at freshly-allocated addresses.  Backends without real device
    # state (the simulator, trace replay) keep the no-op defaults — the
    # host-side addressing (`PagedKVManager.export_kv/import_kv`) is the
    # shared protocol; these hooks move only the payload.

    def export_kv_pages(self, request_id: str,
                        slots: Sequence[Tuple[int, int]]) -> Any:
        """Gather the KV cache content at `slots` ((page, slot) per resident
        token, sequence order).  Returns an opaque payload for
        `import_kv_pages` on the destination backend; None when the backend
        holds no real bytes."""
        return None

    def import_kv_pages(self, request_id: str, payload: Any,
                        slots: Sequence[Tuple[int, int]]) -> None:
        """Scatter a payload from `export_kv_pages` into this backend's KV
        pools at `slots` (the destination addressing from `import_kv`)."""

    def export_request_state(self, req: Request) -> Any:
        """Detach non-KV per-request device state (encoder caches, state
        slots) for migration; releases it locally."""
        return None

    def import_request_state(self, req: Request, state: Any,
                             resident: bool = True) -> None:
        """Attach state from `export_request_state` on the destination.
        `resident=False` means the request arrives *non-resident* (it will
        recompute from scratch — a stolen waiting request, or a migration
        that fell back to recompute): attach only state that must survive a
        recompute (e.g. encoder embeddings), not residency-scoped state
        like recurrent slots, which recompute rebuilds anyway."""

    def migration_cost(self, num_tokens: int) -> float:
        """Modeled wall-clock seconds to move `num_tokens` of KV off this
        backend (interconnect transfer).  Real backends pay the cost in the
        copy itself and report 0; the simulator models it so migration
        thresholds are tunable in sim."""
        return 0.0


class TickLoop:
    """The single schedule→execute→complete cycle (paper §3.3 driver loop).

    One `step()`:
      1. asks the scheduler for this tick's micro-batch (empty = bubble),
      2. rotates it into the depth-S ring (the batch entering stage 0),
      3. has the backend execute one pipeline tick,
      4. retires the batch exiting the ring: applies its sampled tokens,
         streams them, and releases finished requests.

    A request scheduled at tick t is retired at tick t+S-1 (same tick for a
    depth-1 pipeline) — the pipeline-parallel in-flight window the
    scheduler's exclusion rule (one resident micro-batch per request) is
    built around.

    **Async double-buffered mode** (`async_dispatch=True`, DESIGN.md §12):
    the exiting batch's readback is *not* forced inside its own tick.
    Instead the deferred `ExecResult` is parked in `_pending` and retired
    one tick later — after the next tick's schedule/prepare host work has
    already run and the next device tick has been dispatched — so host
    metadata assembly for tick N+1 overlaps device execution of tick N
    (jax async dispatch provides the overlap).  The completion lag is
    invisible to outputs: a pending request is still in the scheduler's
    in-flight set, so it simply becomes schedulable one tick later, and
    greedy sampling makes per-request token streams independent of tick
    placement (the Table-1 equivalence property).  Sync mode stays the
    default — the simulator and trace replay/record paths depend on results
    materializing within their own tick.
    """

    def __init__(self, scheduler: PipelineScheduler, backend: ExecutionBackend,
                 on_token: Optional[Callable[[Request, int], None]] = None,
                 *, async_dispatch: bool = False) -> None:
        self.scheduler = scheduler
        self.backend = backend
        backend.scheduler = scheduler
        S = backend.depth
        self.ring: Deque[Tuple[Optional[int], Any]] = deque(
            [(None, backend.prepare(None)) for _ in range(S)], maxlen=S)
        self.on_token = on_token
        self.finished: List[Request] = []
        self.last_tick_empty = False
        self.async_dispatch = async_dispatch
        # async mode: the exiting batch of the *previous* tick, its readback
        # still deferred — retired at the top of the next step
        self._pending: Optional[Tuple[int, ExecResult]] = None

    # ------------------------------------------------------------------ state
    @property
    def _ring_busy(self) -> bool:
        return any(bid is not None for bid, _ in self.ring)

    @property
    def busy(self) -> bool:
        """True while any real micro-batch is in the ring or awaiting its
        deferred retirement."""
        return self._ring_busy or self._pending is not None

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work or self.busy

    # ------------------------------------------------------------------- tick
    def step(self, now: Optional[float] = None) -> List[Request]:
        """One pipeline tick.  Returns requests finishing this tick."""
        if now is None:
            now = self.backend.clock()
        finished_early: List[Request] = []
        if (self.async_dispatch and self._pending is not None
                and self._pending[1].is_ready()):
            # The deferred readback already materialized on the device, so
            # retiring it costs no wait — and doing it BEFORE scheduling
            # makes its requests schedulable this very tick.  Without this,
            # deferred retirement delays every completion by a full tick and
            # the decode population freezes into two alternating disjoint
            # cohorts, inflating the tick count (~51 vs 36 on the bench
            # workload).  When the probe says "still running", the parked
            # result waits as before and the overlap is preserved.
            finished_early = self._retire_pending(now)
        batch = self.scheduler.schedule(now)
        if batch.is_empty:
            # nothing resident this tick: retire the empty batch immediately
            self.scheduler.complete(batch.batch_id, [], now)
            entry: Tuple[Optional[int], Any] = (None, self.backend.prepare(None))
        else:
            entry = (batch.batch_id, self.backend.prepare(batch))
        self.last_tick_empty = batch.is_empty
        if (self.async_dispatch and batch.is_empty and not self._ring_busy
                and self._pending is not None):
            # nothing to execute — only the deferred batch remains; retire it
            # without paying a bubble device tick
            return finished_early + self._retire_pending(now)
        # Rotate: the new batch enters stage 0; the entry reaching the ring's
        # tail is the one executing its LAST stage this tick — its results
        # materialize when `execute` returns.  (For depth 1 that is this
        # tick's own batch: schedule, execute, retire in one step.)
        self.ring.appendleft(entry)
        exiting_id, _ = self.ring[-1]

        result = self.backend.execute(tuple(self.ring), exiting_id, now)

        if self.async_dispatch:
            # This tick is now in flight on the device.  Retire the PREVIOUS
            # tick's exiting batch — its readback has had a full device tick
            # to complete, so the resolve below rarely blocks — and park this
            # tick's exiting batch until the next step.
            finished = (self._retire_pending(now)
                        if self._pending is not None else [])
            if exiting_id is not None:
                self._pending = (exiting_id, result)
            self.ring[-1] = (None, self.backend.prepare(None))
            return finished_early + finished

        result.resolve()
        if exiting_id is None:
            return []
        finished = self._retire(exiting_id, result.tokens,
                                result.completed_at)
        # the retired entry is never read again (the next push would drop
        # it); clear it so `busy` reflects only live work
        self.ring[-1] = (None, self.backend.prepare(None))
        return finished

    def drain(self, now_fn: Callable[[], float],
              max_ticks: int = 100000) -> List[Request]:
        out: List[Request] = []
        t = 0
        while self.has_work and t < max_ticks:
            out.extend(self.step(now_fn()))
            t += 1
        return out

    # ----------------------------------------------------------------- retire
    def _retire_pending(self, now: float) -> List[Request]:
        """Force the deferred readback of the previous tick's exiting batch
        and retire it.  `now` (resolve-time clock) is the completion time —
        the tokens materialized no later than this."""
        assert self._pending is not None
        bid, result = self._pending
        self._pending = None
        return self._retire(bid, result.resolve(), now)

    def _retire(self, batch_id: int, tokens: Sequence[int],
                now: float) -> List[Request]:
        batch = self.scheduler.get_batch(batch_id)
        if batch is None:
            return []
        producing = [s.request for s in batch.seqs if s.produces_token]
        finished = self.scheduler.complete(batch_id, tokens, now)
        if self.on_token is not None:
            for req, tok in zip(producing, tokens):
                self.on_token(req, int(tok))
        for req in finished:
            self.backend.finish_request(req)
            self.finished.append(req)
        return finished

    # ------------------------------------------------------------ fault paths
    def abort_inflight(self, now: Optional[float] = None) -> List[Request]:
        """A worker died: every in-flight micro-batch's results are lost.
        Requests recover by recompute via `scheduler.abort_batch`; requests
        with a pending user abort finalize it instead (backend state
        released, surfaced through `finished` like any completion)."""
        if now is None:
            now = self.backend.clock()
        affected: List[Request] = []
        if self._pending is not None:
            bid, _ = self._pending
            self._pending = None          # deferred readback never forced
            affected.extend(self.scheduler.abort_batch(bid, now))
        for bid, _ in list(self.ring):
            if bid is not None:
                affected.extend(self.scheduler.abort_batch(bid, now))
        S = self.ring.maxlen or self.backend.depth
        self.ring.clear()
        self.ring.extend((None, self.backend.prepare(None)) for _ in range(S))
        for req in affected:
            if req.is_finished:
                self.backend.finish_request(req)
                self.finished.append(req)
        return affected
