"""gLLM serving engine: the asynchronous pipeline runtime (paper §3.3)
adapted to JAX.

Roles (paper -> here):
  * driver worker   -> the shared `TickLoop` (runtime/core.py): owns the
    schedule→execute→complete cycle and the depth-S micro-batch ring.
  * ordinary worker -> `JaxBackend`: the SPMD serving tick
    (`build_serve_tick`); each mesh `stage` shard executes its resident
    micro-batch; activations move by collective-permute (the NCCL path),
    metadata is computed host-side one tick ahead (the ZeroMQ dual-phase
    path) and overlaps device compute because jit dispatch is asynchronous.
  * frontend        -> `repro.serving.LLMServer` (streams on the asyncio
    loop or an HTTP handler thread while a worker thread ticks) and the
    HTTP process around it (`repro.serving.http`): decoupled request
    intake / token streaming.

`PipelineEngine` is the user-facing handle binding scheduler + KV + backend
+ loop; it is exact (it runs the real model) and is used by the examples,
integration tests, and the output-equivalence benchmark.  Scale experiments
run the *same* TickLoop over the calibrated roofline `SimBackend` instead
(runtime/simulator.py).
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    Request,
    SamplingParams,
    ScheduledBatch,
    ThrottleConfig,
)
from repro.models import serve as serve_lib
from repro.models import transformer as tfm
from repro.models.serve import ServeDims
from repro.runtime.core import ExecResult, ExecutionBackend, TickLoop


def _mesh_scope(mesh):
    """Context manager putting `mesh` in scope for a jitted tick call —
    entering it only when it isn't already the active mesh.

    The ambient mesh context is part of jit's compilation-cache key, and on
    jax versions where `set_mesh` is the legacy stack-based `with mesh:`,
    re-entering an already-active mesh *changes* that key (stack depth 2 vs
    1).  Ticks dispatched from inside a caller's `with jax.set_mesh(...)`
    block (engine construction, warm_start) must hit the same compiled
    signatures as ticks dispatched bare (drain on a worker thread), so the
    scope is made idempotent here.
    """
    import contextlib
    try:
        from jax._src.mesh import get_concrete_mesh
        if get_concrete_mesh() == mesh:       # new-style set_mesh active
            return contextlib.nullcontext()
    except Exception:
        pass
    try:
        from jax._src.mesh import thread_resources
        if thread_resources.env.physical_mesh == mesh:   # legacy `with mesh:`
            return contextlib.nullcontext()
    except Exception:
        pass
    return jax.set_mesh(mesh)


class SlotAllocator:
    """Sequence slots for recurrent state / encoder caches."""

    def __init__(self, n: int) -> None:
        self.free = list(range(n - 1, -1, -1))
        self.owner: Dict[str, int] = {}

    def get(self, request_id: str) -> int:
        if request_id in self.owner:
            return self.owner[request_id]
        if not self.free:
            raise MemoryError("out of state slots")
        s = self.free.pop()
        self.owner[request_id] = s
        return s

    def release(self, request_id: str) -> None:
        s = self.owner.pop(request_id, None)
        if s is not None:
            self.free.append(s)


@dataclass
class EngineStats:
    ticks: int = 0
    tokens_out: int = 0
    padded_prefill: int = 0     # bucket padding = TPU pipeline bubbles
    padded_decode: int = 0
    scheduled_prefill: int = 0
    scheduled_decode: int = 0
    scanned_pages: int = 0      # KV pages the attention scan walks per tick
    live_pages: int = 0         # KV pages actually holding context
    host_s: float = 0.0         # host-side per-tick work (meta/fresh/dispatch)
    device_s: float = 0.0       # host time *blocked* on device readback
    last_bucket: Optional[Dict[str, int]] = None  # selected serve shape


class JaxBackend(ExecutionBackend):
    """ExecutionBackend running the exact jitted SPMD serve tick.

    Owns everything device-side: params, paged KV tensors, recurrent-state
    caches, the inter-stage activation carry, and the per-request host state
    (state slots, encoder embeddings).  `prepare` builds the tick metadata at
    schedule time; `execute` stacks the ring's metadata, dispatches the tick,
    and returns a *deferred* `ExecResult` — the blocking readback of the
    exiting micro-batch's tokens lives in its `pending` thunk, so a sync
    TickLoop forces it immediately while the async loop lets it overlap the
    next tick's host work (DESIGN.md §12).

    With `bucketed=True` the backend compiles the fixed `bucket_ladder`
    of serve shapes (all sharing the full-dims caches and carry) and each
    tick runs in the smallest bucket covering every micro-batch in the
    ring; `warm_start()` compiles the whole ladder up front so steady
    state never recompiles (`compile_count()` exposes the jit cache sizes
    for the zero-recompile assertion).
    """

    def __init__(self, cfg: ArchConfig, dims: ServeDims, params, mesh,
                 kv: PagedKVManager, *, dtype=None,
                 bucketed: bool = False) -> None:
        from repro.distributed.pipeline import build_serve_tick

        self.cfg = cfg
        self.dims = dims
        self.mesh = mesh
        self.params = params
        self.dtype = dtype or jnp.dtype(cfg.dtype)
        self.kv = kv
        self.slots = SlotAllocator(dims.slots)
        self.enc_embeds: Dict[str, np.ndarray] = {}
        self.stats = EngineStats()
        self.bucketed = bucketed
        self.ladder: Tuple[ServeDims, ...] = (
            serve_lib.bucket_ladder(dims) if bucketed else (dims,))
        self._build_serve_tick = build_serve_tick
        self._ticks: Dict[Tuple[int, int, int, int, int], Any] = {}

        self._embed = jax.jit(
            lambda p, t: jnp.take(p["embed"]["tok"], t, axis=0))
        S = cfg.plan.pp
        with jax.set_mesh(mesh):
            self.caches = serve_lib.init_caches(cfg, dims, self.dtype)
            W = dims.prefill_width
            self.carry = {
                "xp": jnp.zeros((S, dims.Sp, W, cfg.d_model), self.dtype),
                "xd": jnp.zeros((S, dims.Sd, 1, cfg.d_model), self.dtype),
            }
        self._seed = 0
        self._prep_s = 0.0          # host prepare() time since last execute
        self._zero_meta_np()        # build the template now: one-time jnp
        #                             dispatch must not bill the first tick

    # ------------------------------------------------------- bucket programs
    def _get_tick(self, bucket: ServeDims):
        key = (bucket.Sp, bucket.C, bucket.Sd, bucket.Bp, bucket.Bd)
        fn = self._ticks.get(key)
        if fn is None:
            carry_dims = self.dims if bucket != self.dims else None
            tick, _ = self._build_serve_tick(self.cfg, self.mesh, bucket,
                                             carry_dims=carry_dims)
            fn = jax.jit(tick, donate_argnums=(1, 2))
            self._ticks[key] = fn
        return fn

    def compile_count(self) -> int:
        """Total jit-compiled signatures across the bucket programs (the
        zero-recompile-in-steady-state assertion reads this)."""
        total = 0
        for fn in self._ticks.values():
            if hasattr(fn, "_cache_size"):
                total += fn._cache_size()
        return total

    def warm_start(self) -> None:
        """Compile every ladder program with a bubble tick (zero metadata —
        a state no-op, like any pipeline bubble) before serving begins.

        The ladder's first program runs once more at the end: its first call
        took the freshly-allocated caches/carry, whose shardings differ from
        the donated program outputs every steady-state call receives, so it
        alone needs its steady-state signature compiled separately.  After
        warm_start no serving tick compiles (``compile_count()`` is flat).
        """
        def bubble(bucket: ServeDims) -> None:
            meta_dev = self._stack_meta(zero_ring, bucket)
            fresh = self._build_fresh(None, bucket)
            sampling = {
                "temps": jnp.zeros(bucket.Sp + bucket.Sd, jnp.float32),
                "seed": jnp.asarray(0, jnp.uint32),
            }
            # same mesh context as execute(): the jit cache keys on the
            # ambient mesh, so warming under a different context would
            # compile signatures serving never hits
            with _mesh_scope(self.mesh):
                self.carry, self.caches, tokens, _ = self._get_tick(bucket)(
                    self.params, self.caches, self.carry, meta_dev, fresh,
                    sampling)
            np.asarray(tokens)      # block: compile + execute now, not later

        zero_ring = tuple(
            (None, self._zero_meta_np()) for _ in range(self.depth))
        for bucket in self.ladder:
            bubble(bucket)
        bubble(self.ladder[0])

    def _select_bucket(self, ring: Sequence[Tuple[Optional[int], Any]]
                       ) -> ServeDims:
        if not self.bucketed:
            return self.dims
        need_c = need_d = need_bp = need_bd = 0
        page = self.dims.page
        for _, m in ring:
            if m["p_chunk_lens"].size:
                need_c = max(need_c, int(m["p_chunk_lens"].max()))
                # block-table depth demand = ring-wide max pages-in-use;
                # context_lens is 0 on empty rows so the max is safe
                need_bp = max(need_bp,
                              -(-int(m["p_context_lens"].max()) // page))
            if m["d_valid"].size:
                need_d = max(need_d, int(np.count_nonzero(m["d_valid"])))
                need_bd = max(need_bd,
                              -(-int(m["d_context_lens"].max()) // page))
        return serve_lib.select_bucket(self.ladder, need_c, need_d,
                                       need_bp=need_bp, need_bd=need_bd)

    @staticmethod
    def _slice_meta_field(key: str, arr: np.ndarray,
                          bucket: ServeDims) -> np.ndarray:
        """Cut one stage-stacked full-dims meta field down to bucket shape."""
        if key.startswith("p_"):
            arr = arr[:, :bucket.Sp]
            if key in ("p_positions", "p_slot_pages", "p_slot_offsets"):
                arr = arr[:, :, :bucket.C]
            elif key == "p_block_tables":
                # depth bucket: the selector guarantees every live page index
                # sits below bucket.Bp, so the tail is always zero padding
                arr = arr[:, :, :bucket.Bp]
        else:
            arr = arr[:, :bucket.Sd]
            if key == "d_block_tables":
                arr = arr[:, :, :bucket.Bd]
        return arr

    def _stack_meta(self, ring: Sequence[Tuple[Optional[int], Any]],
                    bucket: ServeDims) -> dict:
        full = bucket == self.dims
        out = {}
        for k in self._zero_meta_np():
            stacked = np.stack([m[1][k] for m in ring], axis=0)
            if not full:
                stacked = np.ascontiguousarray(
                    self._slice_meta_field(k, stacked, bucket))
            out[k] = jnp.asarray(stacked)
        return out

    # --------------------------------------------------------------- protocol
    @property
    def depth(self) -> int:
        return self.cfg.plan.pp

    def clock(self) -> float:
        return time.monotonic()

    def prepare(self, batch: Optional[ScheduledBatch]) -> dict:
        t0 = time.perf_counter()
        out = self._zero_meta_np() if batch is None else self._build_meta(batch)
        self._prep_s += time.perf_counter() - t0
        return out

    def execute(self, ring: Sequence[Tuple[Optional[int], Any]],
                exiting_id: Optional[int], now: float) -> ExecResult:
        t0 = time.perf_counter()
        bucket = self._select_bucket(ring)
        meta_dev = self._stack_meta(ring, bucket)
        entering = (self.scheduler.get_batch(ring[0][0])
                    if ring[0][0] is not None else None)
        fresh = self._build_fresh(entering, bucket)
        sampling = self._build_sampling(exiting_id, bucket)
        with _mesh_scope(self.mesh):
            self.carry, self.caches, tokens, top_lp = self._get_tick(bucket)(
                self.params, self.caches, self.carry, meta_dev, fresh,
                sampling)

        n_p = entering.num_prefill_tokens if entering is not None else 0
        n_d = entering.num_decode_tokens if entering is not None else 0
        self.stats.ticks += 1
        self.stats.scheduled_prefill += n_p
        self.stats.scheduled_decode += n_d
        self.stats.padded_prefill += bucket.Sp * bucket.C - n_p
        self.stats.padded_decode += bucket.Sd - n_d
        # attention-depth accounting (same entering-batch convention as the
        # padded_* counters): what the bucket scans vs. what holds context
        self.stats.scanned_pages += bucket.Sp * bucket.Bp + bucket.Sd * bucket.Bd
        if entering is not None:
            page = self.dims.page
            live = sum(-(-(seq.start_pos + seq.num_tokens) // page)
                       for seq in entering.prefill)
            live += sum(-(-(seq.start_pos + 1) // page)
                        for seq in entering.decode)
            self.stats.live_pages += live
        self.stats.last_bucket = {"Sp": bucket.Sp, "C": bucket.C,
                                  "Sd": bucket.Sd, "Bp": bucket.Bp,
                                  "Bd": bucket.Bd}
        # host_s: everything this tick spent off-device — the prepare()
        # calls since the last execute plus the stack/embed/dispatch above
        host_s = self._prep_s + (time.perf_counter() - t0)
        self._prep_s = 0.0
        self.stats.host_s += host_s

        exiting = (self.scheduler.get_batch(exiting_id)
                   if exiting_id is not None else None)
        if exiting is None:
            return ExecResult(completed_at=now, host_s=host_s)

        prefill_rows = [i for i, seq in enumerate(exiting.prefill)
                        if seq.produces_token]
        n_decode = len(exiting.decode)
        d_off = bucket.Sp

        def readback() -> List[int]:
            t1 = time.perf_counter()
            host = np.asarray(tokens)       # blocks until the tick finishes
            self.stats.device_s += time.perf_counter() - t1
            toks = [int(host[i]) for i in prefill_rows]
            toks += [int(host[d_off + j]) for j in range(n_decode)]
            self.stats.tokens_out += len(toks)
            return toks

        def probe() -> bool:
            # non-blocking: lets the async loop retire this batch the moment
            # the device is done instead of a fixed tick later
            try:
                return bool(tokens.is_ready())
            except AttributeError:
                return False

        return ExecResult(completed_at=now, host_s=host_s, pending=readback,
                          ready=probe)

    def finish_request(self, req: Request) -> None:
        self.slots.release(req.request_id)
        self.enc_embeds.pop(req.request_id, None)

    def release_resident_state(self, req: Request) -> None:
        """Preemption/abort recovery: the request lost residency, so its
        state slot can be reassigned (recompute rebuilds recurrent state from
        scratch).  Encoder embeddings are kept — recompute needs them."""
        self.slots.release(req.request_id)

    # ------------------------------------------------- live migration (§9)
    # Paged "kv" cache leaves are (stage, repeat, pages, page, ...): one
    # fancy-indexed gather/scatter on the (page, slot) axes moves a request's
    # whole context across every stage and layer.  Slot-indexed leaves
    # (recurrent conv/ssm/wkv state, encoder hidden caches) move by state
    # slot.  On one host this is an array copy; across hosts the same
    # payloads are what would go over the interconnect.

    _SLOT_LEAF_AXIS = {"conv": 2, "ssm": 2, "tm_x": 2, "cm_x": 2, "wkv": 2,
                       "h": 1}

    def export_kv_pages(self, request_id: str,
                        slots: Sequence[Tuple[int, int]]) -> dict:
        pg = jnp.asarray([p for p, _ in slots], jnp.int32)
        off = jnp.asarray([o for _, o in slots], jnp.int32)
        payload = {}
        for gk, grp in self.caches.items():
            for name, arr in grp.items():
                if name == "kv":
                    payload[f"{gk}/{name}"] = arr[:, :, pg, off]
        return payload

    def import_kv_pages(self, request_id: str, payload: dict,
                        slots: Sequence[Tuple[int, int]]) -> None:
        if payload is None:
            return
        pg = jnp.asarray([p for p, _ in slots], jnp.int32)
        off = jnp.asarray([o for _, o in slots], jnp.int32)
        for gk, grp in self.caches.items():
            for name, arr in grp.items():
                if name == "kv":
                    vals = jnp.asarray(payload[f"{gk}/{name}"], arr.dtype)
                    grp[name] = arr.at[:, :, pg, off].set(vals)

    def export_request_state(self, req: Request) -> dict:
        state: Dict[str, Any] = {"enc": self.enc_embeds.pop(req.request_id,
                                                            None),
                                 "slot_leaves": {}}
        s = self.slots.owner.get(req.request_id)
        if s is not None:
            for gk, grp in self.caches.items():
                for name, arr in grp.items():
                    ax = self._SLOT_LEAF_AXIS.get(name)
                    if ax is not None:
                        state["slot_leaves"][f"{gk}/{name}"] = \
                            jnp.take(arr, s, axis=ax)
            self.slots.release(req.request_id)
        return state

    def import_request_state(self, req: Request, state: Optional[dict],
                             resident: bool = True) -> None:
        if state is None:
            return
        if state.get("enc") is not None:
            self.enc_embeds[req.request_id] = state["enc"]
        # residency-scoped state: a non-resident arrival recomputes from
        # scratch, so scattering stale recurrent state (and burning a slot)
        # would only be overwritten
        leaves = state.get("slot_leaves") or {} if resident else {}
        if not leaves:
            return
        s = self.slots.get(req.request_id)
        for gk, grp in self.caches.items():
            for name, arr in grp.items():
                key = f"{gk}/{name}"
                if key in leaves:
                    idx = [slice(None)] * arr.ndim
                    idx[self._SLOT_LEAF_AXIS[name]] = s
                    grp[name] = arr.at[tuple(idx)].set(
                        jnp.asarray(leaves[key], arr.dtype))

    # -------------------------------------------------------------- internals
    def _build_sampling(self, exiting_id, dims: Optional[ServeDims] = None):
        """Per-row temperatures for the micro-batch exiting this tick."""
        dims = dims or self.dims
        rows = dims.Sp + dims.Sd
        temps = np.zeros(rows, np.float32)
        batch = (self.scheduler.get_batch(exiting_id)
                 if exiting_id is not None else None)
        if batch is not None:
            for i, seq in enumerate(batch.prefill):
                temps[i] = seq.request.sampling.temperature
            for j, seq in enumerate(batch.decode):
                temps[dims.Sp + j] = seq.request.sampling.temperature
        self._seed = (self._seed + 1) % (2**31)
        return {"temps": jnp.asarray(temps),
                "seed": jnp.asarray(self._seed, jnp.uint32)}

    def _zero_meta_np(self) -> dict:
        if not hasattr(self, "_zm"):
            self._zm = {k: np.asarray(v)
                        for k, v in serve_lib.zero_meta(self.dims).items()}
        return self._zm

    def _build_meta(self, batch: ScheduledBatch) -> dict:
        dims = self.dims
        zm = self._zero_meta_np()
        # copy-on-write off the cached zero template: a field is copied the
        # first time the batch writes it, untouched fields alias the shared
        # template (safe — consumers only read; `_stack_meta` copies via
        # np.stack).  A decode-only batch never materializes the p_* fields.
        m = dict(zm)

        def w(k: str) -> np.ndarray:
            if m[k] is zm[k]:
                m[k] = zm[k].copy()
            return m[k]

        for s, seq in enumerate(batch.prefill):
            req = seq.request
            L = seq.num_tokens
            w("p_positions")[s, :L] = seq.start_pos + np.arange(L)
            w("p_chunk_lens")[s] = L
            w("p_context_lens")[s] = seq.start_pos + L
            table = self.kv.block_table(req.request_id)[: dims.Bp]
            w("p_block_tables")[s, : len(table)] = table
            pages = [p for p, _ in seq.slots]
            offs = [o for _, o in seq.slots]
            w("p_slot_pages")[s, :L] = pages
            w("p_slot_offsets")[s, :L] = offs
            w("p_state_slots")[s] = self.slots.get(req.request_id)
            w("p_sample")[s] = int(seq.produces_token)
        for s, seq in enumerate(batch.decode):
            req = seq.request
            w("d_positions")[s] = seq.start_pos
            w("d_context_lens")[s] = seq.start_pos + 1
            table = self.kv.block_table(req.request_id)[: dims.Bd]
            w("d_block_tables")[s, : len(table)] = table
            w("d_slot_pages")[s] = seq.slots[0][0]
            w("d_slot_offsets")[s] = seq.slots[0][1]
            w("d_state_slots")[s] = self.slots.get(req.request_id)
            w("d_valid")[s] = 1
        return m

    def _build_fresh(self, batch: Optional[ScheduledBatch],
                     dims: Optional[ServeDims] = None) -> dict:
        dims, cfg = dims or self.dims, self.cfg
        prefill = batch.prefill if batch is not None else []
        decode = batch.decode if batch is not None else []
        W = dims.prefill_width
        full = self.dims
        xp = np.zeros((max(dims.Sp, 0), W, cfg.d_model), np.float32)
        xd = np.zeros((dims.Sd, 1, cfg.d_model), np.float32)
        # token buffers stay at FULL dims even for smaller buckets, so the
        # embed jit keeps one signature across the whole ladder (warmed at
        # startup) instead of compiling per chunk width mid-serve
        p_tok = np.zeros((max(full.Sp, 1), max(full.C, 1)), np.int32)
        d_tok = np.zeros((max(full.Sd, 1), 1), np.int32)
        for s, seq in enumerate(prefill):
            toks = seq.request.effective_prompt[
                seq.start_pos : seq.start_pos + seq.num_tokens]
            p_tok[s, : len(toks)] = toks
        for s, seq in enumerate(decode):
            d_tok[s, 0] = seq.request.effective_prompt[seq.start_pos]
        # the embed jit keys on the ambient mesh context like any other
        # program: run it under the same scope as the tick call so the
        # warm-time and serve-time signatures coincide
        if dims.Sp:
            with _mesh_scope(self.mesh):
                emb = np.asarray(self._embed(self.params,
                                             jnp.asarray(p_tok)), np.float32)
            emb = emb[: dims.Sp, : max(dims.C, 1)]
            xp[:, dims.Te : dims.Te + emb.shape[1], :] = emb
            for s, seq in enumerate(prefill):
                enc = self.enc_embeds.get(seq.request.request_id)
                if enc is not None:
                    xp[s, : enc.shape[0], :] = enc
        if dims.Sd:
            with _mesh_scope(self.mesh):
                xd[:, 0, :] = np.asarray(
                    self._embed(self.params, jnp.asarray(d_tok)),
                    np.float32)[: dims.Sd, 0, :]
        return {"xp": jnp.asarray(xp, self.dtype),
                "xd": jnp.asarray(xd, self.dtype)}


class PipelineEngine:
    """Single-process engine (mesh may be 1 device for CPU runs — the SPMD
    tick is identical; only the mesh size changes).  Binds scheduler + KV +
    `JaxBackend` under the shared `TickLoop`."""

    def __init__(
        self,
        cfg: ArchConfig,
        dims: ServeDims,
        params,
        mesh,
        throttle: ThrottleConfig,
        *,
        num_pages: Optional[int] = None,
        dtype=None,
        trace_path: Optional[str] = None,
        async_dispatch: bool = False,
        bucketed: bool = False,
        enable_prefix_caching: bool = False,
    ) -> None:
        if trace_path is not None and async_dispatch:
            # the recorder writes each tick's exit tokens at execute time;
            # a deferred retire would interleave records out of order and
            # break strict replay, so traced engines stay synchronous
            raise ValueError("async_dispatch is incompatible with trace_path "
                             "(traces require synchronous retirement)")
        self.cfg = cfg
        self.dims = dims
        self.mesh = mesh
        self.params = params
        self.kv = PagedKVManager(num_pages or dims.pages, dims.page,
                                 enable_prefix_caching=enable_prefix_caching)
        self.scheduler = PipelineScheduler(
            throttle, self.kv,
            max_model_len=dims.page * max(dims.Bp, dims.Bd),
            max_prefill_seqs=max(dims.Sp, 0),
            max_chunk_tokens=max(dims.C, 1),
            max_decode_seqs=dims.Sd)
        self.backend = JaxBackend(cfg, dims, params, mesh, self.kv,
                                  dtype=dtype, bucketed=bucketed)
        if bucketed:
            self.backend.warm_start()
        # with --trace-out, every tick of the live engine is logged to a
        # replayable JSONL trace (runtime/trace.py); the recorder is a
        # transparent shim around the backend.  The serving layer submits
        # from client threads while a worker thread ticks, so traced
        # engines serialize intake against the tick — otherwise a request's
        # `req` record could land after the tick that batched it and strict
        # replay of our own output would diverge.  Untraced engines keep the
        # lock-free path.
        self.recorder = None
        self._trace_lock = None
        loop_backend = self.backend
        if trace_path is not None:
            import threading

            from repro.runtime.trace import TraceRecorder
            self.recorder = TraceRecorder(self.backend, trace_path)
            self._trace_lock = threading.Lock()
            loop_backend = self.recorder
        self.loop = TickLoop(self.scheduler, loop_backend,
                             async_dispatch=async_dispatch)
        # state slots are tied to residency: free them when the scheduler
        # evicts a request (preemption or batch abort), not only on finish
        self.scheduler.on_preempt = self.backend.release_resident_state
        self._now_fn: Callable[[], float] = time.monotonic

    # ----------------------------------------------------- delegated surfaces
    @property
    def slots(self) -> SlotAllocator:
        return self.backend.slots

    @property
    def enc_embeds(self) -> Dict[str, np.ndarray]:
        return self.backend.enc_embeds

    @property
    def stats(self) -> EngineStats:
        return self.backend.stats

    @property
    def finished(self) -> List[Request]:
        return self.loop.finished

    @property
    def on_token(self) -> Optional[Callable[[Request, int], None]]:
        return self.loop.on_token

    @on_token.setter
    def on_token(self, fn: Optional[Callable[[Request, int], None]]) -> None:
        # streaming hook: called as on_token(request, token_id) per new token
        self.loop.on_token = fn

    # ------------------------------------------------------------------ API
    # process-wide: ids must stay unique across router replicas (the
    # frontend keys token streams by request id)
    _req_counter = itertools.count()

    def add_request(self, prompt: Sequence[int],
                    sampling: Optional[SamplingParams] = None,
                    request_id: Optional[str] = None,
                    enc_embeds: Optional[np.ndarray] = None) -> Request:
        rid = request_id or f"req-{next(PipelineEngine._req_counter)}"
        req = Request(rid, list(prompt), sampling or SamplingParams())
        req.metrics.arrival_time = self._now_fn()
        if self.cfg.is_encoder_decoder:
            Te, d = self.dims.Te, self.cfg.d_model
            if enc_embeds is None:
                enc_embeds = np.zeros((Te, d), np.float32)
            self.enc_embeds[rid] = np.asarray(enc_embeds, np.float32)[:Te]
        if self._trace_lock is None:
            self.scheduler.add_request(req)
        else:
            with self._trace_lock:
                self.scheduler.add_request(req)
                self.recorder.record_arrival(req)
        return req

    def abort_request(self, request_id: str) -> bool:
        """User abort: frees KV pages and the state slot / encoder cache.
        In-flight requests finalize when their micro-batch retires (the
        TickLoop's normal release path); returns False when unknown."""
        now = self._now_fn()
        if self._trace_lock is None:
            req = self.scheduler.abort_request(request_id, now)
            if req is None:
                return False
        else:
            with self._trace_lock:
                req = self.scheduler.abort_request(request_id, now)
                if req is None:
                    return False
                self.recorder.record_abort(request_id, now)
        if req.is_finished:
            # immediately finalized (waiting / running): the TickLoop will
            # never retire it, so release backend state and surface it here
            self.backend.finish_request(req)
            self.loop.finished.append(req)
        return True

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    @property
    def busy(self) -> bool:
        return self.loop.busy

    def _ring_busy(self) -> bool:   # back-compat alias
        return self.loop.busy

    # ----------------------------------------------------------------- tick
    def step(self) -> List[Request]:
        """One pipeline tick.  Returns requests finishing this tick."""
        if self._trace_lock is None:
            return self.loop.step(self._now_fn())
        with self._trace_lock:
            return self.loop.step(self._now_fn())

    def drain(self, max_ticks: int = 100000) -> List[Request]:
        if self._trace_lock is None:
            return self.loop.drain(self._now_fn, max_ticks)
        out: List[Request] = []
        for _ in range(max_ticks):          # lock per tick, not per drain
            # the no-work check and the step share ONE lock acquisition:
            # with a check outside the lock, an add_request landing between
            # check and step would be missed by this drain pass
            with self._trace_lock:
                if not (self.has_work or self.busy):
                    break
                out.extend(self.loop.step(self._now_fn()))
        return out

    # -------------------------------------------------------- checkpointing
    def snapshot_state(self) -> dict:
        """Scheduler + KV state for engine checkpoint/restart (in-flight
        micro-batches are recovered by recompute: anything in the ring is
        folded back into the waiting queue)."""
        reqs = []
        seen = set()
        for group in (list(self.scheduler.waiting),
                      self.scheduler.running_prefill,
                      self.scheduler.running_decode):
            for r in group:
                if r.request_id in seen:
                    continue
                seen.add(r.request_id)
                reqs.append({
                    "request_id": r.request_id,
                    "prompt": list(r.prompt_token_ids),
                    "output": list(r.output_token_ids),
                    "max_new_tokens": r.sampling.max_new_tokens,
                    "arrival": r.metrics.arrival_time,
                })
        return {"requests": reqs, "ticks": self.stats.ticks}

    @staticmethod
    def restore_requests(engine: "PipelineEngine", snap: dict) -> None:
        for r in snap["requests"]:
            req = Request(r["request_id"], list(r["prompt"]),
                          SamplingParams(max_new_tokens=r["max_new_tokens"]))
            req.output_token_ids = list(r["output"])
            req.metrics.arrival_time = r["arrival"]
            # recompute semantics: prompt+outputs re-prefill from scratch
            engine.scheduler.add_request(req)
