"""gLLM serving engine: the asynchronous pipeline runtime (paper §3.3)
adapted to JAX.

Roles (paper -> here):
  * driver worker   -> the shared `TickLoop` (runtime/core.py): owns the
    schedule→execute→complete cycle and the depth-S micro-batch ring.
  * ordinary worker -> `JaxBackend`: the SPMD serving tick
    (`build_serve_tick`); each mesh `stage` shard executes its resident
    micro-batch; activations move by collective-permute (the NCCL path),
    metadata is computed host-side one tick ahead (the ZeroMQ dual-phase
    path) and overlaps device compute because jit dispatch is asynchronous.
  * frontend        -> `repro.serving.LLMServer` (streams on the asyncio
    loop or an HTTP handler thread while a worker thread ticks) and the
    HTTP process around it (`repro.serving.http`): decoupled request
    intake / token streaming.

`PipelineEngine` is the user-facing handle binding scheduler + KV + backend
+ loop; it is exact (it runs the real model) and is used by the examples,
integration tests, and the output-equivalence benchmark.  Scale experiments
run the *same* TickLoop over the calibrated roofline `SimBackend` instead
(runtime/simulator.py).
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    Request,
    SamplingParams,
    ScheduledBatch,
    ThrottleConfig,
)
from repro.models import serve as serve_lib
from repro.models import transformer as tfm
from repro.models.serve import ServeDims
from repro.runtime.core import ExecResult, ExecutionBackend, TickLoop


class SlotAllocator:
    """Sequence slots for recurrent state / encoder caches."""

    def __init__(self, n: int) -> None:
        self.free = list(range(n - 1, -1, -1))
        self.owner: Dict[str, int] = {}

    def get(self, request_id: str) -> int:
        if request_id in self.owner:
            return self.owner[request_id]
        if not self.free:
            raise MemoryError("out of state slots")
        s = self.free.pop()
        self.owner[request_id] = s
        return s

    def release(self, request_id: str) -> None:
        s = self.owner.pop(request_id, None)
        if s is not None:
            self.free.append(s)


@dataclass
class EngineStats:
    ticks: int = 0
    tokens_out: int = 0
    padded_prefill: int = 0     # bucket padding = TPU pipeline bubbles
    padded_decode: int = 0
    scheduled_prefill: int = 0
    scheduled_decode: int = 0


class JaxBackend(ExecutionBackend):
    """ExecutionBackend running the exact jitted SPMD serve tick.

    Owns everything device-side: params, paged KV tensors, recurrent-state
    caches, the inter-stage activation carry, and the per-request host state
    (state slots, encoder embeddings).  `prepare` builds the tick metadata at
    schedule time; `execute` stacks the ring's metadata, dispatches the tick,
    and reads back the sampled tokens of the exiting micro-batch.
    """

    def __init__(self, cfg: ArchConfig, dims: ServeDims, params, mesh,
                 kv: PagedKVManager, *, dtype=None) -> None:
        from repro.distributed.pipeline import build_serve_tick

        self.cfg = cfg
        self.dims = dims
        self.mesh = mesh
        self.params = params
        self.dtype = dtype or jnp.dtype(cfg.dtype)
        self.kv = kv
        self.slots = SlotAllocator(dims.slots)
        self.enc_embeds: Dict[str, np.ndarray] = {}
        self.stats = EngineStats()

        tick, specs = build_serve_tick(cfg, mesh, dims)
        self._tick = jax.jit(tick, donate_argnums=(1, 2))
        self._embed = jax.jit(
            lambda p, t: jnp.take(p["embed"]["tok"], t, axis=0))
        S = cfg.plan.pp
        with jax.set_mesh(mesh):
            self.caches = serve_lib.init_caches(cfg, dims, self.dtype)
            W = dims.prefill_width
            self.carry = {
                "xp": jnp.zeros((S, dims.Sp, W, cfg.d_model), self.dtype),
                "xd": jnp.zeros((S, dims.Sd, 1, cfg.d_model), self.dtype),
            }
        self._seed = 0

    # --------------------------------------------------------------- protocol
    @property
    def depth(self) -> int:
        return self.cfg.plan.pp

    def clock(self) -> float:
        return time.monotonic()

    def prepare(self, batch: Optional[ScheduledBatch]) -> dict:
        if batch is None:
            return self._zero_meta_np()
        return self._build_meta(batch)

    def execute(self, ring: Sequence[Tuple[Optional[int], Any]],
                exiting_id: Optional[int], now: float) -> ExecResult:
        meta_dev = {
            k: jnp.asarray(np.stack([m[1][k] for m in ring], axis=0))
            for k in self._zero_meta_np()
        }
        entering = (self.scheduler.get_batch(ring[0][0])
                    if ring[0][0] is not None else None)
        fresh = self._build_fresh(entering)
        sampling = self._build_sampling(exiting_id)
        self.carry, self.caches, tokens, top_lp = self._tick(
            self.params, self.caches, self.carry, meta_dev, fresh, sampling)

        dims = self.dims
        n_p = entering.num_prefill_tokens if entering is not None else 0
        n_d = entering.num_decode_tokens if entering is not None else 0
        self.stats.ticks += 1
        self.stats.scheduled_prefill += n_p
        self.stats.scheduled_decode += n_d
        self.stats.padded_prefill += dims.Sp * dims.C - n_p
        self.stats.padded_decode += dims.Sd - n_d

        toks: List[int] = []
        if exiting_id is not None:
            exiting = self.scheduler.get_batch(exiting_id)
            if exiting is not None:
                host = np.asarray(tokens)
                for i, seq in enumerate(exiting.prefill):
                    if seq.produces_token:
                        toks.append(int(host[i]))
                for j, seq in enumerate(exiting.decode):
                    toks.append(int(host[dims.Sp + j]))
        self.stats.tokens_out += len(toks)
        return ExecResult(tokens=toks, completed_at=now)

    def finish_request(self, req: Request) -> None:
        self.slots.release(req.request_id)
        self.enc_embeds.pop(req.request_id, None)

    def release_resident_state(self, req: Request) -> None:
        """Preemption/abort recovery: the request lost residency, so its
        state slot can be reassigned (recompute rebuilds recurrent state from
        scratch).  Encoder embeddings are kept — recompute needs them."""
        self.slots.release(req.request_id)

    # ------------------------------------------------- live migration (§9)
    # Paged "kv" cache leaves are (stage, repeat, pages, page, ...): one
    # fancy-indexed gather/scatter on the (page, slot) axes moves a request's
    # whole context across every stage and layer.  Slot-indexed leaves
    # (recurrent conv/ssm/wkv state, encoder hidden caches) move by state
    # slot.  On one host this is an array copy; across hosts the same
    # payloads are what would go over the interconnect.

    _SLOT_LEAF_AXIS = {"conv": 2, "ssm": 2, "tm_x": 2, "cm_x": 2, "wkv": 2,
                       "h": 1}

    def export_kv_pages(self, request_id: str,
                        slots: Sequence[Tuple[int, int]]) -> dict:
        pg = jnp.asarray([p for p, _ in slots], jnp.int32)
        off = jnp.asarray([o for _, o in slots], jnp.int32)
        payload = {}
        for gk, grp in self.caches.items():
            for name, arr in grp.items():
                if name == "kv":
                    payload[f"{gk}/{name}"] = arr[:, :, pg, off]
        return payload

    def import_kv_pages(self, request_id: str, payload: dict,
                        slots: Sequence[Tuple[int, int]]) -> None:
        if payload is None:
            return
        pg = jnp.asarray([p for p, _ in slots], jnp.int32)
        off = jnp.asarray([o for _, o in slots], jnp.int32)
        for gk, grp in self.caches.items():
            for name, arr in grp.items():
                if name == "kv":
                    vals = jnp.asarray(payload[f"{gk}/{name}"], arr.dtype)
                    grp[name] = arr.at[:, :, pg, off].set(vals)

    def export_request_state(self, req: Request) -> dict:
        state: Dict[str, Any] = {"enc": self.enc_embeds.pop(req.request_id,
                                                            None),
                                 "slot_leaves": {}}
        s = self.slots.owner.get(req.request_id)
        if s is not None:
            for gk, grp in self.caches.items():
                for name, arr in grp.items():
                    ax = self._SLOT_LEAF_AXIS.get(name)
                    if ax is not None:
                        state["slot_leaves"][f"{gk}/{name}"] = \
                            jnp.take(arr, s, axis=ax)
            self.slots.release(req.request_id)
        return state

    def import_request_state(self, req: Request, state: Optional[dict],
                             resident: bool = True) -> None:
        if state is None:
            return
        if state.get("enc") is not None:
            self.enc_embeds[req.request_id] = state["enc"]
        # residency-scoped state: a non-resident arrival recomputes from
        # scratch, so scattering stale recurrent state (and burning a slot)
        # would only be overwritten
        leaves = state.get("slot_leaves") or {} if resident else {}
        if not leaves:
            return
        s = self.slots.get(req.request_id)
        for gk, grp in self.caches.items():
            for name, arr in grp.items():
                key = f"{gk}/{name}"
                if key in leaves:
                    idx = [slice(None)] * arr.ndim
                    idx[self._SLOT_LEAF_AXIS[name]] = s
                    grp[name] = arr.at[tuple(idx)].set(
                        jnp.asarray(leaves[key], arr.dtype))

    # -------------------------------------------------------------- internals
    def _build_sampling(self, exiting_id):
        """Per-row temperatures for the micro-batch exiting this tick."""
        rows = self.dims.Sp + self.dims.Sd
        temps = np.zeros(rows, np.float32)
        batch = (self.scheduler.get_batch(exiting_id)
                 if exiting_id is not None else None)
        if batch is not None:
            for i, seq in enumerate(batch.prefill):
                temps[i] = seq.request.sampling.temperature
            for j, seq in enumerate(batch.decode):
                temps[self.dims.Sp + j] = seq.request.sampling.temperature
        self._seed = (self._seed + 1) % (2**31)
        return {"temps": jnp.asarray(temps),
                "seed": jnp.asarray(self._seed, jnp.uint32)}

    def _zero_meta_np(self) -> dict:
        if not hasattr(self, "_zm"):
            self._zm = {k: np.asarray(v)
                        for k, v in serve_lib.zero_meta(self.dims).items()}
        return self._zm

    def _build_meta(self, batch: ScheduledBatch) -> dict:
        dims = self.dims
        m = {k: np.asarray(v) for k, v in serve_lib.zero_meta(dims).items()}
        m = {k: v.copy() for k, v in m.items()}
        for s, seq in enumerate(batch.prefill):
            req = seq.request
            L = seq.num_tokens
            m["p_positions"][s, :L] = seq.start_pos + np.arange(L)
            m["p_chunk_lens"][s] = L
            m["p_context_lens"][s] = seq.start_pos + L
            table = self.kv.block_table(req.request_id)[: dims.Bp]
            m["p_block_tables"][s, : len(table)] = table
            pages = [p for p, _ in seq.slots]
            offs = [o for _, o in seq.slots]
            m["p_slot_pages"][s, :L] = pages
            m["p_slot_offsets"][s, :L] = offs
            m["p_state_slots"][s] = self.slots.get(req.request_id)
            m["p_sample"][s] = int(seq.produces_token)
        for s, seq in enumerate(batch.decode):
            req = seq.request
            m["d_positions"][s] = seq.start_pos
            m["d_context_lens"][s] = seq.start_pos + 1
            table = self.kv.block_table(req.request_id)[: dims.Bd]
            m["d_block_tables"][s, : len(table)] = table
            m["d_slot_pages"][s] = seq.slots[0][0]
            m["d_slot_offsets"][s] = seq.slots[0][1]
            m["d_state_slots"][s] = self.slots.get(req.request_id)
            m["d_valid"][s] = 1
        return m

    def _build_fresh(self, batch: Optional[ScheduledBatch]) -> dict:
        dims, cfg = self.dims, self.cfg
        prefill = batch.prefill if batch is not None else []
        decode = batch.decode if batch is not None else []
        W = dims.prefill_width
        xp = np.zeros((max(dims.Sp, 0), W, cfg.d_model), np.float32)
        xd = np.zeros((dims.Sd, 1, cfg.d_model), np.float32)
        p_tok = np.zeros((max(dims.Sp, 0), max(dims.C, 1)), np.int32)
        d_tok = np.zeros((dims.Sd, 1), np.int32)
        for s, seq in enumerate(prefill):
            toks = seq.request.effective_prompt[
                seq.start_pos : seq.start_pos + seq.num_tokens]
            p_tok[s, : len(toks)] = toks
        for s, seq in enumerate(decode):
            d_tok[s, 0] = seq.request.effective_prompt[seq.start_pos]
        if dims.Sp:
            emb = np.asarray(self._embed(self.params,
                                         jnp.asarray(p_tok)), np.float32)
            xp[:, dims.Te : dims.Te + emb.shape[1], :] = emb[:, : dims.C]
            for s, seq in enumerate(prefill):
                enc = self.enc_embeds.get(seq.request.request_id)
                if enc is not None:
                    xp[s, : enc.shape[0], :] = enc
        if dims.Sd:
            xd[:, 0, :] = np.asarray(
                self._embed(self.params, jnp.asarray(d_tok)),
                np.float32)[:, 0, :]
        return {"xp": jnp.asarray(xp, self.dtype),
                "xd": jnp.asarray(xd, self.dtype)}


class PipelineEngine:
    """Single-process engine (mesh may be 1 device for CPU runs — the SPMD
    tick is identical; only the mesh size changes).  Binds scheduler + KV +
    `JaxBackend` under the shared `TickLoop`."""

    def __init__(
        self,
        cfg: ArchConfig,
        dims: ServeDims,
        params,
        mesh,
        throttle: ThrottleConfig,
        *,
        num_pages: Optional[int] = None,
        dtype=None,
        trace_path: Optional[str] = None,
    ) -> None:
        self.cfg = cfg
        self.dims = dims
        self.mesh = mesh
        self.params = params
        self.kv = PagedKVManager(num_pages or dims.pages, dims.page)
        self.scheduler = PipelineScheduler(
            throttle, self.kv,
            max_model_len=dims.page * max(dims.Bp, dims.Bd),
            max_prefill_seqs=max(dims.Sp, 0),
            max_chunk_tokens=max(dims.C, 1),
            max_decode_seqs=dims.Sd)
        self.backend = JaxBackend(cfg, dims, params, mesh, self.kv,
                                  dtype=dtype)
        # with --trace-out, every tick of the live engine is logged to a
        # replayable JSONL trace (runtime/trace.py); the recorder is a
        # transparent shim around the backend.  The serving layer submits
        # from client threads while a worker thread ticks, so traced
        # engines serialize intake against the tick — otherwise a request's
        # `req` record could land after the tick that batched it and strict
        # replay of our own output would diverge.  Untraced engines keep the
        # lock-free path.
        self.recorder = None
        self._trace_lock = None
        loop_backend = self.backend
        if trace_path is not None:
            import threading

            from repro.runtime.trace import TraceRecorder
            self.recorder = TraceRecorder(self.backend, trace_path)
            self._trace_lock = threading.Lock()
            loop_backend = self.recorder
        self.loop = TickLoop(self.scheduler, loop_backend)
        # state slots are tied to residency: free them when the scheduler
        # evicts a request (preemption or batch abort), not only on finish
        self.scheduler.on_preempt = self.backend.release_resident_state
        self._now_fn: Callable[[], float] = time.monotonic

    # ----------------------------------------------------- delegated surfaces
    @property
    def slots(self) -> SlotAllocator:
        return self.backend.slots

    @property
    def enc_embeds(self) -> Dict[str, np.ndarray]:
        return self.backend.enc_embeds

    @property
    def stats(self) -> EngineStats:
        return self.backend.stats

    @property
    def finished(self) -> List[Request]:
        return self.loop.finished

    @property
    def on_token(self) -> Optional[Callable[[Request, int], None]]:
        return self.loop.on_token

    @on_token.setter
    def on_token(self, fn: Optional[Callable[[Request, int], None]]) -> None:
        # streaming hook: called as on_token(request, token_id) per new token
        self.loop.on_token = fn

    # ------------------------------------------------------------------ API
    # process-wide: ids must stay unique across router replicas (the
    # frontend keys token streams by request id)
    _req_counter = itertools.count()

    def add_request(self, prompt: Sequence[int],
                    sampling: Optional[SamplingParams] = None,
                    request_id: Optional[str] = None,
                    enc_embeds: Optional[np.ndarray] = None) -> Request:
        rid = request_id or f"req-{next(PipelineEngine._req_counter)}"
        req = Request(rid, list(prompt), sampling or SamplingParams())
        req.metrics.arrival_time = self._now_fn()
        if self.cfg.is_encoder_decoder:
            Te, d = self.dims.Te, self.cfg.d_model
            if enc_embeds is None:
                enc_embeds = np.zeros((Te, d), np.float32)
            self.enc_embeds[rid] = np.asarray(enc_embeds, np.float32)[:Te]
        if self._trace_lock is None:
            self.scheduler.add_request(req)
        else:
            with self._trace_lock:
                self.scheduler.add_request(req)
                self.recorder.record_arrival(req)
        return req

    def abort_request(self, request_id: str) -> bool:
        """User abort: frees KV pages and the state slot / encoder cache.
        In-flight requests finalize when their micro-batch retires (the
        TickLoop's normal release path); returns False when unknown."""
        now = self._now_fn()
        if self._trace_lock is None:
            req = self.scheduler.abort_request(request_id, now)
            if req is None:
                return False
        else:
            with self._trace_lock:
                req = self.scheduler.abort_request(request_id, now)
                if req is None:
                    return False
                self.recorder.record_abort(request_id, now)
        if req.is_finished:
            # immediately finalized (waiting / running): the TickLoop will
            # never retire it, so release backend state and surface it here
            self.backend.finish_request(req)
            self.loop.finished.append(req)
        return True

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    @property
    def busy(self) -> bool:
        return self.loop.busy

    def _ring_busy(self) -> bool:   # back-compat alias
        return self.loop.busy

    # ----------------------------------------------------------------- tick
    def step(self) -> List[Request]:
        """One pipeline tick.  Returns requests finishing this tick."""
        if self._trace_lock is None:
            return self.loop.step(self._now_fn())
        with self._trace_lock:
            return self.loop.step(self._now_fn())

    def drain(self, max_ticks: int = 100000) -> List[Request]:
        if self._trace_lock is None:
            return self.loop.drain(self._now_fn, max_ticks)
        out: List[Request] = []
        for _ in range(max_ticks):          # lock per tick, not per drain
            if not (self.has_work or self.busy):
                break
            out.extend(self.step())
        return out

    # -------------------------------------------------------- checkpointing
    def snapshot_state(self) -> dict:
        """Scheduler + KV state for engine checkpoint/restart (in-flight
        micro-batches are recovered by recompute: anything in the ring is
        folded back into the waiting queue)."""
        reqs = []
        seen = set()
        for group in (list(self.scheduler.waiting),
                      self.scheduler.running_prefill,
                      self.scheduler.running_decode):
            for r in group:
                if r.request_id in seen:
                    continue
                seen.add(r.request_id)
                reqs.append({
                    "request_id": r.request_id,
                    "prompt": list(r.prompt_token_ids),
                    "output": list(r.output_token_ids),
                    "max_new_tokens": r.sampling.max_new_tokens,
                    "arrival": r.metrics.arrival_time,
                })
        return {"requests": reqs, "ticks": self.stats.ticks}

    @staticmethod
    def restore_requests(engine: "PipelineEngine", snap: dict) -> None:
        for r in snap["requests"]:
            req = Request(r["request_id"], list(r["prompt"]),
                          SamplingParams(max_new_tokens=r["max_new_tokens"]))
            req.output_token_ids = list(r["output"])
            req.metrics.arrival_time = r["arrival"]
            # recompute semantics: prompt+outputs re-prefill from scratch
            engine.scheduler.add_request(req)
