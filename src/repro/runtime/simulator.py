"""Discrete-event simulator of the pipeline-parallel serving runtime.

Reproduces the paper's evaluation methodology at cluster scale on a CPU-only
box: the *real* `PipelineScheduler` (Token Throttling or Sarathi policy — the
actual policy code, not a model of it) drives an event-driven pipeline whose
per-stage latency comes from a roofline cost model calibrated with the v5e
constants used in §Roofline.

Stage semantics match the SPMD tick: a micro-batch occupies one stage at a
time; stage s starts batch b when (a) stage s-1 finished b and (b) stage s
finished its previous batch.  Inter-batch imbalance therefore creates exactly
the bubbles of paper Fig. 3, and Token Throttling's equalized token counts
remove them.

Also models: driver host overhead (serialized for the vLLM-like runtime,
overlapped for the gLLM runtime — paper §3.4's 17% input-prep cost), pod
failures (in-flight work lost, recompute on recovery), and straggler stages.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    Request,
    SamplingParams,
    ScheduledBatch,
    ThrottleConfig,
)
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS


@dataclass
class CostModel:
    """Per-stage latency of one micro-batch (roofline form)."""

    flops_per_token_stage: float      # 2*N_active/pp
    param_bytes_stage: float          # active weight bytes read per tick
    kv_bytes_per_ctx_token: float     # per context token per stage
    chips_per_stage: int = 1
    mfu: float = 0.55                 # achievable compute efficiency
    hbm_eff: float = 0.75
    fixed_us: float = 30.0            # kernel launch / sync floor
    # tensor-parallel baseline: per-token activation all-reduce traffic plus
    # a per-step latency floor (2 all-reduces per layer; each costs
    # ~2(N-1) link latencies regardless of payload — dominant for decode on
    # cross-node fabrics).  PP only communicates inter-stage activations
    # (tiny, overlapped) — exactly the tradeoff the paper exploits (§2.3).
    comm_bytes_per_token: float = 0.0
    comm_latency: float = 0.0         # per-tick serialized all-reduce latency
    net_bw: float = 50e9              # interconnect (ICI link / sim-network)

    def stage_time(self, prefill_tokens: int, decode_tokens: int,
                   prefill_ctx: int, decode_ctx: int) -> float:
        tokens = prefill_tokens + decode_tokens
        t_comp = tokens * self.flops_per_token_stage / (
            PEAK_FLOPS * self.mfu * self.chips_per_stage)
        kv_bytes = (prefill_tokens * 0.5 * prefill_ctx
                    + decode_tokens * decode_ctx) * self.kv_bytes_per_ctx_token
        weight_bytes = self.param_bytes_stage if tokens else 0.0
        t_mem = (weight_bytes + kv_bytes) / (
            HBM_BW * self.hbm_eff * self.chips_per_stage)
        t_comm = tokens * self.comm_bytes_per_token / self.net_bw
        if tokens and self.comm_bytes_per_token:
            t_comm += self.comm_latency
        return max(t_comp, t_mem) + t_comm + self.fixed_us * 1e-6


def cost_model_for(cfg, *, chips_per_stage: int = 1, pp: int = None
                   ) -> CostModel:
    """Stage-cost model for a pipeline of depth `pp` (defaults to the arch's
    plan).  Per stage: 1/pp of the layers on `chips_per_stage` chips."""
    from repro.roofline.analysis import param_count
    n_active = param_count(cfg, active_only=True)
    pp = pp or cfg.plan.pp
    kv_bytes = cfg.kv_cache_dim_per_token * (cfg.num_layers / pp) * 2  # bf16
    return CostModel(
        flops_per_token_stage=2.0 * n_active / pp,
        param_bytes_stage=2.0 * n_active / pp,
        kv_bytes_per_ctx_token=kv_bytes,
        chips_per_stage=chips_per_stage,
    )


@dataclass
class RuntimeModel:
    """Host-side driver behaviour (paper §3.3/§3.4)."""

    overhead_serial: float = 0.0     # blocks the pipeline (vLLM-style coupling)
    overhead_overlap: float = 0.0    # hidden behind compute (gLLM async)

    @staticmethod
    def gllm() -> "RuntimeModel":
        return RuntimeModel(overhead_serial=0.0002, overhead_overlap=0.002)

    @staticmethod
    def vllm_like() -> "RuntimeModel":
        # ~17% of execution serialized on input prep (paper §3.4)
        return RuntimeModel(overhead_serial=0.0025, overhead_overlap=0.0)


@dataclass
class SimMetrics:
    finished: List[Request] = field(default_factory=list)
    sim_time: float = 0.0
    total_output_tokens: int = 0
    total_input_tokens: int = 0
    bubble_time: float = 0.0          # last-stage idle while work pending
    busy_time: float = 0.0

    def _vals(self, fn):
        vals = [fn(r) for r in self.finished]
        return [v for v in vals if v is not None]

    def ttft(self):
        return float(np.mean(self._vals(lambda r: r.metrics.ttft()) or [0]))

    def tpot(self):
        return float(np.mean(self._vals(
            lambda r: r.metrics.tpot(r.num_output_tokens)) or [0]))

    def e2el(self):
        return float(np.mean(self._vals(lambda r: r.metrics.e2el()) or [0]))

    def throughput(self):
        """Steady-state token throughput: tokens completed within the p90
        request-completion window (the paper saturates and excludes the
        drain tail — a lone long-output straggler would otherwise dominate
        the denominator)."""
        if not self.finished:
            return 0.0
        fins = sorted(r.metrics.finish_time for r in self.finished
                      if r.metrics.finish_time is not None)
        if not fins:
            return 0.0
        t90 = fins[max(0, int(len(fins) * 0.9) - 1)]
        tok = sum(r.num_prompt_tokens + r.num_output_tokens
                  for r in self.finished
                  if r.metrics.finish_time is not None
                  and r.metrics.finish_time <= t90)
        return tok / max(t90, 1e-9)

    def slo_attainment(self, ttft_slo: float, tpot_slo: float) -> float:
        ok = 0
        for r in self.finished:
            t1, t2 = r.metrics.ttft(), r.metrics.tpot(r.num_output_tokens)
            if t1 is not None and t1 <= ttft_slo and (t2 or 0) <= tpot_slo:
                ok += 1
        return ok / max(1, len(self.finished))


class PipelineSimulator:
    """Event-driven PP serving simulator around the real scheduler."""

    ARRIVAL, STAGE_DONE, DRIVER, FAIL, RECOVER = range(5)

    def __init__(
        self,
        scheduler: PipelineScheduler,
        pp: int,
        cost: CostModel,
        runtime: RuntimeModel = RuntimeModel.gllm(),
        *,
        straggler_stage: Optional[int] = None,
        straggler_factor: float = 1.0,
    ) -> None:
        self.sched = scheduler
        self.pp = pp
        self.cost = cost
        self.runtime = runtime
        self.straggler = (straggler_stage, straggler_factor)
        self._events: List[Tuple[float, int, int, object]] = []
        self._eid = itertools.count()
        self.stage_free_at = [0.0] * pp
        self.stage_queue: List[List[Tuple[ScheduledBatch, float]]] = \
            [[] for _ in range(pp)]
        self.metrics = SimMetrics()
        self._driver_pending = False
        self._failed_until = -1.0

    # ------------------------------------------------------------------ events
    def _push(self, t: float, kind: int, payload=None):
        heapq.heappush(self._events, (t, kind, next(self._eid), payload))

    def add_workload(self, arrivals: List[Tuple[float, List[int], int]]):
        """arrivals: (time, prompt_tokens, output_len)."""
        for t, prompt, out_len in arrivals:
            self._push(t, self.ARRIVAL, (prompt, out_len))

    def inject_failure(self, at: float, downtime: float):
        self._push(at, self.FAIL, downtime)

    # ------------------------------------------------------------------- run
    def run(self, until: float = float("inf"), max_events: int = 5_000_000
            ) -> SimMetrics:
        self._push(0.0, self.DRIVER)
        n = 0
        last_stage_busy_since = None
        while self._events and n < max_events:
            t, kind, _, payload = heapq.heappop(self._events)
            if t > until and kind == self.ARRIVAL:
                continue
            n += 1
            self.metrics.sim_time = max(self.metrics.sim_time, t)
            if kind == self.ARRIVAL:
                prompt, out_len = payload
                rid = f"r{n}"
                req = Request(rid, prompt,
                              SamplingParams(max_new_tokens=out_len))
                req.metrics.arrival_time = t
                self.metrics.total_input_tokens += len(prompt)
                self.sched.add_request(req)
                self._kick_driver(t)
            elif kind == self.DRIVER:
                self._driver_pending = False
                self._try_schedule(t)
            elif kind == self.STAGE_DONE:
                stage, batch = payload
                self._stage_done(t, stage, batch)
            elif kind == self.FAIL:
                self._failed_until = t + payload
                self._push(self._failed_until, self.RECOVER)
                # in-flight micro-batches lost: abort + recompute on recovery
                for bid in list(self.sched._batches):
                    self.sched.abort_batch(bid)
                self._events = [e for e in self._events
                                if e[1] != self.STAGE_DONE]
                heapq.heapify(self._events)
                self.stage_free_at = [self._failed_until] * self.pp
            elif kind == self.RECOVER:
                self._kick_driver(t)
        return self.metrics

    # -------------------------------------------------------------- pipeline
    def _kick_driver(self, t: float):
        if not self._driver_pending:
            self._driver_pending = True
            self._push(max(t, self.stage_free_at[0]), self.DRIVER)

    def _try_schedule(self, t: float):
        if t < self._failed_until:
            return
        if self.stage_free_at[0] > t:
            self._kick_driver(t)
            return
        batch = self.sched.schedule(t)
        if batch.is_empty:
            # nothing schedulable right now; wake on the next arrival or
            # micro-batch completion (both kick the driver)
            self.sched.complete(batch.batch_id, [], t)
            return
        t0 = t + self.runtime.overhead_serial
        self._start_stage(t0, 0, batch)
        self._kick_driver(t0)

    def _batch_time(self, stage: int, batch: ScheduledBatch) -> float:
        p_ctx = max((s.start_pos + s.num_tokens for s in batch.prefill),
                    default=0)
        d_ctx = int(np.mean([s.start_pos for s in batch.decode])) \
            if batch.decode else 0
        dt = self.cost.stage_time(batch.num_prefill_tokens,
                                  batch.num_decode_tokens, p_ctx, d_ctx)
        st, fac = self.straggler
        if st is not None and stage == st:
            dt *= fac
        return dt

    def _start_stage(self, t: float, stage: int, batch: ScheduledBatch):
        start = max(t, self.stage_free_at[stage])
        dt = self._batch_time(stage, batch)
        if stage == self.pp - 1:
            if self.stage_free_at[stage] < start and self.metrics.sim_time > 0:
                self.metrics.bubble_time += start - self.stage_free_at[stage]
            self.metrics.busy_time += dt
        self.stage_free_at[stage] = start + dt
        self._push(start + dt, self.STAGE_DONE, (stage, batch))

    def _stage_done(self, t: float, stage: int, batch: ScheduledBatch):
        if stage + 1 < self.pp:
            self._start_stage(t, stage + 1, batch)
        else:
            toks = [0] * sum(1 for s in batch.seqs if s.produces_token)
            finished = self.sched.complete(batch.batch_id, toks, t)
            self.metrics.total_output_tokens += len(toks)
            self.metrics.finished.extend(finished)
            self._kick_driver(t)   # completions free in-flight requests
        if stage == 0:
            self._kick_driver(t)
