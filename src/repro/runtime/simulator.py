"""Discrete-event simulator of the pipeline-parallel serving runtime.

Reproduces the paper's evaluation methodology at cluster scale on a CPU-only
box: the *real* `PipelineScheduler` (Token Throttling or Sarathi policy — the
actual policy code, not a model of it) drives the shared `TickLoop`
(runtime/core.py) over a `SimBackend` whose per-stage latency comes from a
roofline cost model calibrated with the v5e constants used in §Roofline.

Stage semantics match the SPMD tick: a micro-batch occupies one stage at a
time; stage s starts batch b when (a) stage s-1 finished b and (b) stage s
finished its previous batch.  Inter-batch imbalance therefore creates exactly
the bubbles of paper Fig. 3, and Token Throttling's equalized token counts
remove them.  The depth-S ring bounds in-flight micro-batches to the pipeline
depth, exactly like the engine.

Also models: driver host overhead (serialized for the vLLM-like runtime,
overlapped for the gLLM runtime — paper §3.4's 17% input-prep cost), pod
failures (in-flight work lost, recompute on recovery), and straggler stages.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    PrefillPolicy,
    Request,
    SamplingParams,
    ScheduledBatch,
    ThrottleConfig,
)
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS
from repro.runtime.core import ExecResult, ExecutionBackend, TickLoop


@dataclass
class CostModel:
    """Per-stage latency of one micro-batch (roofline form)."""

    flops_per_token_stage: float      # 2*N_active/pp
    param_bytes_stage: float          # active weight bytes read per tick
    kv_bytes_per_ctx_token: float     # per context token per stage
    chips_per_stage: int = 1
    mfu: float = 0.55                 # achievable compute efficiency
    hbm_eff: float = 0.75
    fixed_us: float = 30.0            # kernel launch / sync floor
    # tensor-parallel baseline: per-token activation all-reduce traffic plus
    # a per-step latency floor (2 all-reduces per layer; each costs
    # ~2(N-1) link latencies regardless of payload — dominant for decode on
    # cross-node fabrics).  PP only communicates inter-stage activations
    # (tiny, overlapped) — exactly the tradeoff the paper exploits (§2.3).
    comm_bytes_per_token: float = 0.0
    comm_latency: float = 0.0         # per-tick serialized all-reduce latency
    net_bw: float = 50e9              # interconnect (ICI link / sim-network)
    # Paged-attention depth term (DESIGN.md §14).  When > 0, attention HBM
    # traffic is billed per *scanned KV page* (attn_page_bytes each) instead
    # of per context token — mirroring the engine, whose depth-bucketed
    # tables + dead-page-skipping kernel make cost track pages walked, not
    # the pool maximum.  0 keeps the legacy per-token formula (and every
    # previously fitted model / golden fixture) bit-for-bit unchanged.
    attn_page_bytes: float = 0.0
    page_size: int = 16               # tokens per KV page (for the estimator)

    def est_scanned_pages(self, prefill_tokens: int, decode_tokens: int,
                          prefill_ctx: int, decode_ctx: int) -> float:
        """Scanned KV pages per stage estimated from the batch aggregates a
        `TickSample` records — the *same* estimator backs `stage_time` (when
        no exact count is passed), `fit_from_trace`, and
        `calibration_error`, so sim, fit, and validation bill one term."""
        pg = max(self.page_size, 1)
        pages = 0.0
        if decode_tokens:
            pages += decode_tokens * float(-(-max(decode_ctx, 1) // pg))
        if prefill_tokens:
            pages += float(-(-int(prefill_tokens * 0.5 * max(prefill_ctx, 1))
                             // pg))
        return pages

    def stage_time(self, prefill_tokens: int, decode_tokens: int,
                   prefill_ctx: int, decode_ctx: int,
                   scanned_pages: Optional[float] = None) -> float:
        tokens = prefill_tokens + decode_tokens
        t_comp = tokens * self.flops_per_token_stage / (
            PEAK_FLOPS * self.mfu * self.chips_per_stage)
        if self.attn_page_bytes > 0.0:
            pages = (scanned_pages if scanned_pages is not None else
                     self.est_scanned_pages(prefill_tokens, decode_tokens,
                                            prefill_ctx, decode_ctx))
            kv_bytes = pages * self.attn_page_bytes
        else:
            kv_bytes = (prefill_tokens * 0.5 * prefill_ctx
                        + decode_tokens * decode_ctx
                        ) * self.kv_bytes_per_ctx_token
        weight_bytes = self.param_bytes_stage if tokens else 0.0
        t_mem = (weight_bytes + kv_bytes) / (
            HBM_BW * self.hbm_eff * self.chips_per_stage)
        t_comm = tokens * self.comm_bytes_per_token / self.net_bw
        if tokens and self.comm_bytes_per_token:
            t_comm += self.comm_latency
        return max(t_comp, t_mem) + t_comm + self.fixed_us * 1e-6

    def scaled(self, factor: float) -> "CostModel":
        """A uniformly `factor`x slower copy (heterogeneous-replica modeling:
        older silicon, thermal throttling, fewer chips per stage)."""
        import dataclasses
        return dataclasses.replace(
            self, mfu=self.mfu / factor, hbm_eff=self.hbm_eff / factor,
            fixed_us=self.fixed_us * factor)

    @classmethod
    def fit_from_trace(cls, trace, base: "CostModel", *, iters: int = 60
                       ) -> "CostModel":
        """Calibrate the roofline efficiencies from a recorded trace.

        The structural constants (FLOPs/bytes per token per stage) come from
        `base` — they are architecture facts, not free parameters; what a
        trace identifies is how efficiently the hardware achieved them:
        `mfu`, `hbm_eff`, and the fixed per-tick floor.  Fitting alternates
        regime assignment (compute- vs memory-bound under the current
        parameters) with per-regime least squares, exactly the structure of
        `stage_time`.  A trace that never visits one regime leaves that
        regime's efficiency at the base value (it is unidentifiable).

        Closes the sim-vs-engine loop: `calibration_error(trace, fitted)`
        (runtime/trace.py) bounds how well the returned model reproduces the
        recorded per-tick latencies.
        """
        import dataclasses

        from repro.runtime.trace import tick_samples

        samples = tick_samples(trace)
        if not samples:
            raise ValueError("trace has no ticks with stage latencies")
        F = np.empty(len(samples))      # compute seconds at mfu = 1
        M = np.empty(len(samples))      # memory seconds at hbm_eff = 1
        comm = np.empty(len(samples))
        y = np.empty(len(samples))      # observed per-stage service time
        for i, s in enumerate(samples):
            tokens = s.prefill_tokens + s.decode_tokens
            F[i] = tokens * base.flops_per_token_stage / (
                PEAK_FLOPS * base.chips_per_stage)
            if base.attn_page_bytes > 0.0:
                # structural constant like the per-token rate: the fit keeps
                # the same per-page billing `stage_time` uses
                kv_bytes = base.attn_page_bytes * base.est_scanned_pages(
                    s.prefill_tokens, s.decode_tokens,
                    s.prefill_ctx, s.decode_ctx)
            else:
                kv_bytes = (s.prefill_tokens * 0.5 * s.prefill_ctx
                            + s.decode_tokens * s.decode_ctx
                            ) * base.kv_bytes_per_ctx_token
            M[i] = (base.param_bytes_stage + kv_bytes) / (
                HBM_BW * base.chips_per_stage)
            comm[i] = tokens * base.comm_bytes_per_token / base.net_bw
            if tokens and base.comm_bytes_per_token:
                comm[i] += base.comm_latency
            y[i] = s.stage_time

        mfu, hbm_eff = base.mfu, base.hbm_eff
        fixed = base.fixed_us * 1e-6
        for _ in range(iters):
            resid = np.maximum(y - comm - fixed, 1e-12)
            compute_bound = F / mfu >= M / hbm_eff
            for mask, num in ((compute_bound, F), (~compute_bound, M)):
                if mask.any():
                    denom = float((num[mask] * resid[mask]).sum())
                    if denom > 0:
                        eff = float((num[mask] ** 2).sum()) / denom
                        if num is F:
                            mfu = eff
                        else:
                            hbm_eff = eff
            fixed = max(0.0, float(np.mean(
                y - comm - np.maximum(F / mfu, M / hbm_eff))))
        return dataclasses.replace(base, mfu=mfu, hbm_eff=hbm_eff,
                                   fixed_us=fixed * 1e6)


def cost_model_for(cfg, *, chips_per_stage: int = 1, pp: int = None,
                   page_size: Optional[int] = None) -> CostModel:
    """Stage-cost model for a pipeline of depth `pp` (defaults to the arch's
    plan).  Per stage: 1/pp of the layers on `chips_per_stage` chips.
    Passing `page_size` (the KV page length, `ServeDims.page`) enables the
    per-scanned-page attention term at page_size × the per-token KV rate —
    the depth-bucketed engine's cost shape."""
    from repro.roofline.analysis import param_count
    n_active = param_count(cfg, active_only=True)
    pp = pp or cfg.plan.pp
    kv_bytes = cfg.kv_cache_dim_per_token * (cfg.num_layers / pp) * 2  # bf16
    extra = ({"attn_page_bytes": page_size * kv_bytes, "page_size": page_size}
             if page_size else {})
    return CostModel(
        flops_per_token_stage=2.0 * n_active / pp,
        param_bytes_stage=2.0 * n_active / pp,
        kv_bytes_per_ctx_token=kv_bytes,
        chips_per_stage=chips_per_stage,
        **extra,
    )


@dataclass
class RuntimeModel:
    """Host-side driver behaviour (paper §3.3/§3.4)."""

    overhead_serial: float = 0.0     # blocks the pipeline (vLLM-style coupling)
    overhead_overlap: float = 0.0    # hidden behind compute (gLLM async)

    @staticmethod
    def gllm() -> "RuntimeModel":
        return RuntimeModel(overhead_serial=0.0002, overhead_overlap=0.002)

    @staticmethod
    def vllm_like() -> "RuntimeModel":
        # ~17% of execution serialized on input prep (paper §3.4)
        return RuntimeModel(overhead_serial=0.0025, overhead_overlap=0.0)

    @property
    def host_s_per_tick(self) -> float:
        """Total modeled host work per non-bubble tick — the quantity trace
        schema 1.3 records as `host_s` (how much of it blocks the pipeline
        is the serial/overlap split)."""
        return self.overhead_serial + self.overhead_overlap

    @staticmethod
    def fit_from_trace(trace, *, overlap_fraction: float = 0.0
                       ) -> "RuntimeModel":
        """Calibrate the host-overhead term from a schema ≥ 1.3 trace: the
        mean per-tick `host_s` over non-bubble ticks, split by
        `overlap_fraction` into the part hidden behind compute (the async
        double-buffered engine overlaps nearly all of it → fraction near 1)
        versus the part that serializes with the pipeline (a sync engine →
        fraction 0).  Raises ValueError on traces without `host_s` — sim
        throughput would otherwise silently assume a free host."""
        from repro.runtime.trace import host_overhead_samples

        samples = host_overhead_samples(trace)
        if not samples:
            raise ValueError(
                "trace records no per-tick host_s (pre-1.3 schema, or a "
                "backend without host accounting) — cannot calibrate "
                "RuntimeModel")
        if not 0.0 <= overlap_fraction <= 1.0:
            raise ValueError("overlap_fraction must be within [0, 1]")
        mean = float(np.mean(samples))
        return RuntimeModel(
            overhead_serial=mean * (1.0 - overlap_fraction),
            overhead_overlap=mean * overlap_fraction)


@dataclass
class SimMetrics:
    finished: List[Request] = field(default_factory=list)
    sim_time: float = 0.0
    total_output_tokens: int = 0
    total_input_tokens: int = 0
    bubble_time: float = 0.0          # last-stage idle while work pending
    busy_time: float = 0.0

    def _vals(self, fn):
        vals = [fn(r) for r in self.finished]
        return [v for v in vals if v is not None]

    def ttft(self):
        return float(np.mean(self._vals(lambda r: r.metrics.ttft()) or [0]))

    def tpot(self):
        return float(np.mean(self._vals(
            lambda r: r.metrics.tpot(r.num_output_tokens)) or [0]))

    def e2el(self):
        return float(np.mean(self._vals(lambda r: r.metrics.e2el()) or [0]))

    def throughput(self):
        """Steady-state token throughput: tokens completed within the p90
        request-completion window (the paper saturates and excludes the
        drain tail — a lone long-output straggler would otherwise dominate
        the denominator)."""
        if not self.finished:
            return 0.0
        fins = sorted(r.metrics.finish_time for r in self.finished
                      if r.metrics.finish_time is not None)
        if not fins:
            return 0.0
        t90 = fins[max(0, int(len(fins) * 0.9) - 1)]
        tok = sum(r.num_prompt_tokens + r.num_output_tokens
                  for r in self.finished
                  if r.metrics.finish_time is not None
                  and r.metrics.finish_time <= t90)
        return tok / max(t90, 1e-9)

    def slo_attainment(self, ttft_slo: float, tpot_slo: float) -> float:
        ok = 0
        for r in self.finished:
            t1, t2 = r.metrics.ttft(), r.metrics.tpot(r.num_output_tokens)
            if t1 is not None and t1 <= ttft_slo and (t2 or 0) <= tpot_slo:
                ok += 1
        return ok / max(1, len(self.finished))


class SimBackend(ExecutionBackend):
    """ExecutionBackend whose tick cost comes from the roofline model.

    Sampled tokens are placeholders (0): the simulator studies *scheduling*,
    not model outputs.  The backend keeps a virtual clock; `execute` cascades
    the entering micro-batch through the per-stage `stage_free_at` frontier
    and reports the exiting batch's modeled completion time.
    """

    def __init__(
        self,
        pp: int,
        cost: CostModel,
        runtime: RuntimeModel = None,
        *,
        straggler_stage: Optional[int] = None,
        straggler_factor: float = 1.0,
        metrics: Optional[SimMetrics] = None,
    ) -> None:
        self.pp = pp
        self.cost = cost
        self.runtime = runtime or RuntimeModel.gllm()
        self.straggler = (straggler_stage, straggler_factor)
        self.stage_free_at = [0.0] * pp
        self.time = 0.0
        self.metrics = metrics or SimMetrics()
        self._completion_time: Dict[int, float] = {}

    # --------------------------------------------------------------- protocol
    @property
    def depth(self) -> int:
        return self.pp

    def clock(self) -> float:
        return self.time

    def execute(self, ring: Sequence[Tuple[Optional[int], Any]],
                exiting_id: Optional[int], now: float) -> ExecResult:
        self.time = max(self.time, now)
        entering_id = ring[0][0]
        stage_times: Optional[List[float]] = None
        if entering_id is not None:
            batch = self.scheduler.get_batch(entering_id)
            stage_times = []
            t = now + self.runtime.overhead_serial
            for s in range(self.pp):
                start = max(t, self.stage_free_at[s])
                dt = self._batch_time(s, batch)
                stage_times.append(dt)
                if s == self.pp - 1:
                    if self.stage_free_at[s] < start and \
                            self.metrics.sim_time > 0:
                        self.metrics.bubble_time += \
                            start - self.stage_free_at[s]
                    self.metrics.busy_time += dt
                self.stage_free_at[s] = start + dt
                t = start + dt
            self._completion_time[entering_id] = t
        self.metrics.sim_time = max(self.metrics.sim_time, self.time)
        # Modeled per-tick host work (schema 1.3 `host_s`): dispatching a
        # real batch costs the full serial+overlap budget, a bubble costs
        # nothing.  Deterministic, so golden fixtures stay reproducible and
        # RuntimeModel.fit_from_trace recovers the model exactly.
        host_s = self.runtime.host_s_per_tick if entering_id is not None \
            else 0.0

        if exiting_id is None:
            return ExecResult([], now, stage_times=stage_times, host_s=host_s)
        done_at = self._completion_time.pop(exiting_id, now)
        exiting = self.scheduler.get_batch(exiting_id)
        n = sum(1 for s in exiting.seqs if s.produces_token) \
            if exiting is not None else 0
        self.metrics.total_output_tokens += n
        # the driver cannot act on this completion before it happened
        self.time = max(self.time, done_at)
        self.metrics.sim_time = max(self.metrics.sim_time, self.time)
        return ExecResult([0] * n, done_at, stage_times=stage_times,
                          host_s=host_s)

    def reset(self, now: float) -> None:
        self._completion_time.clear()
        self.stage_free_at = [now] * self.pp
        self.time = max(self.time, now)
        self.metrics.sim_time = max(self.metrics.sim_time, self.time)

    def migration_cost(self, num_tokens: int) -> float:
        """Modeled seconds to ship a request's KV off this replica: per-stage
        KV bytes × pipeline depth (every stage holds its own layers' pages)
        over the interconnect, plus the fixed per-transfer floor.  This is
        the price `RebalancePolicy` trades against the imbalance it removes —
        tunable entirely in sim."""
        total_bytes = self.cost.kv_bytes_per_ctx_token * self.pp * num_tokens
        return total_bytes / self.cost.net_bw + self.cost.fixed_us * 1e-6

    # -------------------------------------------------------------- internals
    def _batch_time(self, stage: int, batch: ScheduledBatch) -> float:
        p_ctx = max((s.start_pos + s.num_tokens for s in batch.prefill),
                    default=0)
        d_ctx = int(np.mean([s.start_pos for s in batch.decode])) \
            if batch.decode else 0
        dt = self.cost.stage_time(batch.num_prefill_tokens,
                                  batch.num_decode_tokens, p_ctx, d_ctx)
        st, fac = self.straggler
        if st is not None and stage == st:
            dt *= fac
        return dt


class PipelineSimulator:
    """PP serving simulator: the shared TickLoop over a `SimBackend`.

    Arrival/failure injection and virtual-time advancement live here; the
    schedule→execute→complete cycle is the same code the real engine runs.
    """

    def __init__(
        self,
        scheduler: PipelineScheduler,
        pp: int,
        cost: CostModel,
        runtime: RuntimeModel = RuntimeModel.gllm(),
        *,
        straggler_stage: Optional[int] = None,
        straggler_factor: float = 1.0,
        trace_path: Optional[str] = None,
    ) -> None:
        self.sched = scheduler
        self.pp = pp
        self.backend = SimBackend(pp, cost, runtime,
                                  straggler_stage=straggler_stage,
                                  straggler_factor=straggler_factor)
        self.recorder = None
        loop_backend = self.backend
        if trace_path is not None:
            from repro.runtime.trace import TraceRecorder
            self.recorder = TraceRecorder(self.backend, trace_path)
            loop_backend = self.recorder
        self.loop = TickLoop(scheduler, loop_backend)
        self.metrics = self.backend.metrics
        self._arrivals: List[Tuple[float, int, List[int], int,
                                   Optional[SamplingParams]]] = []
        self._failures: List[Tuple[float, float]] = []
        self._seq = itertools.count(1)
        # Request-id namespace.  Ids must be unique *cluster*-wide once live
        # migration can move a request between replicas (a namesake on the
        # destination would corrupt its block table) — `SimCluster`
        # re-prefixes fresh replicas to guarantee it.
        self.rid_prefix = "r"

    def attach_trace(self, trace_path) -> None:
        """Start recording this replica's ticks (before any work has run —
        used by `SimCluster` which receives already-built simulators)."""
        from repro.runtime.trace import TraceRecorder
        assert self.recorder is None, "trace already attached"
        assert self.backend.time == 0.0 and not self.loop.busy, \
            "attach_trace before the simulator runs"
        self.recorder = TraceRecorder(self.backend, trace_path)
        self.recorder.scheduler = self.sched
        self.loop.backend = self.recorder

    @property
    def scheduler(self) -> PipelineScheduler:   # replica-router signal surface
        return self.sched

    # ------------------------------------------------- engine-compatible API
    # The serving layer (repro.serving) and `ReplicaRouter` drive engines and
    # simulators through one surface: add_request / step / abort_request /
    # has_work / busy / finished / on_token.  For the simulator, "now" is the
    # virtual clock, and one `step()` is one driver action.

    @property
    def finished(self) -> List[Request]:
        return self.metrics.finished

    @property
    def has_work(self) -> bool:
        return self.sched.has_work or bool(self._arrivals)

    @property
    def busy(self) -> bool:
        return self.loop.busy

    @property
    def on_token(self):
        return self.loop.on_token

    @on_token.setter
    def on_token(self, fn) -> None:
        self.loop.on_token = fn

    def add_request(self, prompt: List[int],
                    sampling: Optional[SamplingParams] = None,
                    request_id: Optional[str] = None) -> Request:
        """Admit a request at the current virtual instant (the interactive
        analogue of `inject_request`, which schedules a *future* arrival)."""
        rid = request_id or f"{self.rid_prefix}{next(self._seq)}"
        req = Request(rid, list(prompt), sampling or SamplingParams())
        req.metrics.arrival_time = self.backend.time
        self.metrics.total_input_tokens += len(prompt)
        self.sched.add_request(req)
        if self.recorder is not None:
            self.recorder.record_arrival(req)
        return req

    def step(self) -> List[Request]:
        """One driver action (tick, failure, or arrival jump); returns the
        requests that finished during it."""
        before = len(self.metrics.finished)
        self._advance(float("inf"))
        return self.metrics.finished[before:]

    def abort_request(self, request_id: str) -> bool:
        """User abort at the current virtual instant; frees KV immediately
        for waiting/running requests, at batch retire for in-flight ones."""
        now = self.backend.time
        req = self.sched.abort_request(request_id, now)
        if req is None:
            return False
        if self.recorder is not None:
            self.recorder.record_abort(request_id, now)
        if req.is_finished:
            self.loop.backend.finish_request(req)
            self.metrics.finished.append(req)
            self.loop.finished.append(req)
        return True

    def drain(self, max_ticks: int = 100000) -> List[Request]:
        before = len(self.metrics.finished)
        for _ in range(max_ticks):
            if not self._advance(float("inf")):
                break
        return self.metrics.finished[before:]

    def advance_clock(self, t: float) -> None:
        """Control-plane causality: a request materialized here at `t` (a
        steal or migration delivery) — this replica must not tick earlier."""
        self.backend.time = max(self.backend.time, t)
        self.metrics.sim_time = max(self.metrics.sim_time, self.backend.time)

    # ------------------------------------------------------------------ intake
    def add_workload(self, arrivals: List[Tuple]):
        """arrivals: (time, prompt_tokens, output_len[, sampling])."""
        for t, prompt, out_len, *rest in arrivals:
            self.inject_request(t, prompt, out_len, *rest)

    def inject_request(self, t: float, prompt: List[int], out_len: int,
                       sampling: Optional[SamplingParams] = None) -> None:
        """Schedule a future arrival.  `sampling` overrides the default
        greedy `SamplingParams(max_new_tokens=out_len)` — the hook for
        SLO-class / priority mixes in cluster studies; when given, its
        `max_new_tokens` wins over `out_len`."""
        heapq.heappush(self._arrivals,
                       (t, next(self._seq), prompt, out_len, sampling))

    def inject_failure(self, at: float, downtime: float):
        heapq.heappush(self._failures, (at, downtime))

    # ------------------------------------------------------------------- run
    def run(self, until: float = float("inf"), max_events: int = 5_000_000
            ) -> SimMetrics:
        for _ in range(max_events):
            if not self._advance(until):
                break
        return self.metrics

    def run_until(self, t: float, max_events: int = 5_000_000) -> SimMetrics:
        """Advance virtual time until the next tick would start after `t`
        (or the replica goes idle).  Used by the multi-replica cluster driver
        to keep replicas causally consistent at each routing decision."""
        for _ in range(max_events):
            if self._next_tick_time() > t or not self._advance(float("inf")):
                break
        return self.metrics

    # -------------------------------------------------------------- internals
    def _next_tick_time(self) -> float:
        return max(self.backend.time, self.backend.stage_free_at[0])

    def _advance(self, until: float) -> bool:
        """One driver action: apply a due failure, or run one tick, or jump
        virtual time to the next arrival.  Returns False when fully idle."""
        t = self._next_tick_time()
        if self._failures and self._failures[0][0] <= t:
            at, downtime = heapq.heappop(self._failures)
            self._apply_failure(at, downtime)
            return True
        self._admit_arrivals(t, until)
        if self.sched.has_work or self.loop.busy:
            was_busy = self.loop.busy
            finished = self.loop.step(t)
            self.metrics.finished.extend(finished)
            if self.loop.last_tick_empty and not was_busy:
                # an idle pipeline scheduled nothing (e.g. admission gated on
                # the KV threshold): only an arrival can unblock us
                return self._jump_to_next_arrival(until)
            return True
        return self._jump_to_next_arrival(until)

    def _admit_arrivals(self, t: float, until: float) -> None:
        while self._arrivals and self._arrivals[0][0] <= t:
            at, _, prompt, out_len, sampling = heapq.heappop(self._arrivals)
            if at > until:
                continue            # past the measurement horizon: dropped
            req = Request(f"{self.rid_prefix}{next(self._seq)}", prompt,
                          sampling or SamplingParams(max_new_tokens=out_len))
            req.metrics.arrival_time = at
            self.metrics.total_input_tokens += len(prompt)
            self.metrics.sim_time = max(self.metrics.sim_time, at)
            self.sched.add_request(req)
            if self.recorder is not None:
                self.recorder.record_arrival(req)

    def _jump_to_next_arrival(self, until: float) -> bool:
        while self._arrivals:
            at = self._arrivals[0][0]
            if at > until:
                heapq.heappop(self._arrivals)
                continue
            self.backend.time = max(self.backend.time, at)
            self._admit_arrivals(self.backend.time, until)
            return True
        return False

    def _apply_failure(self, at: float, downtime: float) -> None:
        # in-flight micro-batches lost: abort + recompute on recovery
        # (reset goes through the loop's backend so a TraceRecorder sees it)
        affected = self.loop.abort_inflight(at)
        self.metrics.finished.extend(r for r in affected if r.is_finished)
        self.loop.backend.reset(at + downtime)


def record_sim_trace(
    trace_path,
    arrivals: List[Tuple[float, List[int], int]],
    *,
    arch: str = "qwen2.5-14b",
    pp: int = 4,
    pages: int = 2048,
    page_size: int = 16,
    policy: PrefillPolicy = PrefillPolicy.GLLM,
    runtime: RuntimeModel = None,
    straggler_stage: Optional[int] = None,
    straggler_factor: float = 1.0,
    fail_at: Optional[float] = None,
    downtime: float = 1.0,
    enable_prefix_caching: bool = False,
    attn_page_billing: bool = False,
) -> PipelineSimulator:
    """Run a traced simulation of `arrivals` — the canonical way to mint a
    golden trace (tests/fixtures/traces/make_fixtures.py) or a calibration
    trace (`benchmarks.run --trace-out`).  Returns the finished simulator;
    the trace is at `trace_path` (or in `sim.recorder` for in-memory sinks).
    `attn_page_billing` bills attention HBM traffic per scanned KV page
    (the depth-bucketed engine's cost shape) instead of per context token.
    """
    from repro.configs import get_config

    cfg = get_config(arch)
    th = ThrottleConfig(pipeline_depth=pp, policy=policy)
    kv = PagedKVManager(num_pages=pages, page_size=page_size,
                        enable_prefix_caching=enable_prefix_caching)
    sched = PipelineScheduler(th, kv, max_model_len=pages * page_size)
    cost = cost_model_for(cfg, pp=pp,
                          page_size=page_size if attn_page_billing else None)
    sim = PipelineSimulator(sched, pp, cost, runtime,
                            straggler_stage=straggler_stage,
                            straggler_factor=straggler_factor,
                            trace_path=trace_path)
    sim.add_workload(arrivals)
    if fail_at is not None:
        sim.inject_failure(fail_at, downtime)
    sim.run()
    if sim.recorder is not None:
        sim.recorder.close()
    return sim
