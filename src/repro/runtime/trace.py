"""Trace record/replay: deterministic tick traces as the third
`ExecutionBackend` (DESIGN.md §8).

The runtime's policy/execution split (runtime/core.py) means one tick is
fully described by *what the scheduler decided* (the micro-batch) and *what
the backend reported* (sampled tokens, completion time, per-stage latency).
`TraceRecorder` wraps any `ExecutionBackend` — the live `JaxBackend` or the
analytic `SimBackend` — and logs one structured record per tick to a
versioned JSONL stream.  `TraceBackend` is the third backend: it replays a
recorded trace through the *unmodified* `TickLoop`/`PipelineScheduler`,
substituting recorded latencies for computed ones and (in strict mode)
asserting the scheduler reproduces the recorded batch decisions — any
divergence is reported with the exact tick index and field diff.

This is the calibration loop Sarathi-Serve (arXiv:2403.02310) and TD-Pipe
(arXiv:2506.10470) build their evaluations on: capture what a real run did,
then re-examine, re-test, and re-fit offline.  Every scheduler/throttle/
router claim in this repo becomes deterministically reproducible in CI
without a TPU (tests/test_trace.py replays checked-in golden traces).

Record kinds (one JSON object per line):

  header  schema/version + everything needed to rebuild the scheduler
          (throttle config, KV pool geometry, scheduler caps, ring depth)
  req     a request entering the scheduler (id, arrival, prompt, sampling —
          incl. priority + SLO class since schema 1.2: admission order
          depends on them)
  tick    one pipeline tick: entering micro-batch composition, the throttle
          budgets that shaped it, KV/queue signals, per-stage latency, and
          the exiting batch's sampled tokens + completion time
  reset   fault recovery: all in-flight work was lost (abort + restart)
  abort   user-initiated abort of one request (schema 1.1): applied in
          stream order, so replay reproduces the exact lifecycle —
          including aborts that finalize at the next batch retire
  migrate control-plane live migration (§9): op="out" drains a request off
          this replica; op="in" adopts one at its current position (full
          request state embedded, so each replica's trace replays alone)
  route   (router traces) one placement decision: scores + chosen replica

Compaction: long production runs repeat most tick fields (steady-state
decode ticks differ only in `now`/`exit`).  `compact_records` delta-encodes
ticks against the previous tick — a field absent from a compacted record is
unchanged, and a steady decode batch (same requests, every position advanced
by one, consecutive batch id) collapses to the marker `"batch": "+1"` — and
marks the header `"compact": true`; `Trace.from_records` expands
transparently, so compacted traces replay, fit, and gate CI exactly like
raw ones (the expansion is lossless to the byte).

CLI (used by `make trace-check`):

    python -m repro.runtime.trace check   FILE...   # strict replay + identity
    python -m repro.runtime.trace replay  FILE [--timing-only]
    python -m repro.runtime.trace fit     FILE [--arch A] [--pp N]
    python -m repro.runtime.trace compact FILE [-o OUT]
"""

from __future__ import annotations

import itertools
import json
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, IO, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    PrefillPolicy,
    Request,
    RequestState,
    SamplingParams,
    ThrottleConfig,
)
from repro.runtime.core import ExecResult, ExecutionBackend, TickLoop

SCHEMA = "gllm-trace"
ROUTE_SCHEMA = "gllm-route"
SCHEMA_MAJOR = 1
SCHEMA_MINOR = 6    # 1.1: "abort" record kind; 1.2: req/migrate carry
                    # per-request priority + SLO class; 1.3: ticks may carry
                    # "host_s" (per-tick host overhead — engine measures it,
                    # sim models it, RuntimeModel.fit_from_trace calibrates
                    # against it); absent on backends that don't report it,
                    # so 1.2 traces remain byte-identical; 1.4: ticks carry
                    # "cached" (prefill tokens skipped via adopted cached
                    # prefixes this tick) iff the scheduler has prefix
                    # caching enabled — pre-1.4 traces (and all recordings
                    # with caching off) keep their exact bytes; 1.5:
                    # "handoff" record kind (disagg prefill->decode
                    # transfer, same op=out/in layout as "migrate") and
                    # compacted ticks may run-length encode "stage_times"
                    # and exit token lists — raw (non-compact) tick bytes
                    # are unchanged, so pre-1.5 layouts are preserved; 1.6:
                    # "scale_up" / "drain" / "retire" record kinds (elastic
                    # fleet lifecycle markers written by the autoscaler —
                    # no scheduler state change on replay, re-recorded
                    # verbatim so elastic traces stay byte-identical);
                    # pre-1.6 traces carry none and keep their exact bytes


class TraceSchemaError(ValueError):
    """The stream is not a trace this code can interpret."""


class TraceDivergence(AssertionError):
    """Strict replay produced a different decision than the recording.

    `tick` is the 0-based tick index; `diffs` is [(field, recorded, actual)].
    """

    def __init__(self, tick: int, diffs: List[Tuple[str, Any, Any]]) -> None:
        self.tick = tick
        self.diffs = diffs
        lines = [f"replay diverged from trace at tick {tick}:"]
        for fieldname, want, got in diffs:
            lines.append(f"  {fieldname}: recorded={want!r} replayed={got!r}")
        super().__init__("\n".join(lines))


def _to_jsonable(obj: Any) -> Any:
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    raise TypeError(f"not JSON serializable: {type(obj)}")


def dumps_record(rec: Dict[str, Any]) -> str:
    """Canonical one-line serialization (insertion order, compact, shortest
    round-trip floats) — the unit of the bit-identity guarantee."""
    return json.dumps(rec, separators=(",", ":"), default=_to_jsonable)


Sink = Union[None, str, IO[str]]


class TraceWriter:
    """Appends records to an optional line-flushed sink, keeping them in
    memory (so a finished recording is available as a `Trace` without a
    read-back)."""

    def __init__(self, sink: Sink = None) -> None:
        self.records: List[Dict[str, Any]] = []
        self._owns = isinstance(sink, str)
        self._fh: Optional[IO[str]] = open(sink, "w") if self._owns else sink
        self._lock = threading.Lock()   # whole lines even under threaded use

    def write(self, rec: Dict[str, Any]) -> None:
        with self._lock:
            self.records.append(rec)
            if self._fh is not None:
                self._fh.write(dumps_record(rec) + "\n")
                self._fh.flush()

    def close(self) -> None:
        if self._fh is not None and self._owns:
            self._fh.close()
        self._fh = None

    def __del__(self) -> None:  # pragma: no cover - GC ordering
        try:
            self.close()
        except Exception:
            pass


@dataclass
class Trace:
    """A parsed trace: the header plus all subsequent records, in order."""

    header: Dict[str, Any]
    records: List[Dict[str, Any]]

    # ------------------------------------------------------------------ views
    @property
    def ticks(self) -> List[Dict[str, Any]]:
        return [r for r in self.records if r["kind"] == "tick"]

    @property
    def requests(self) -> List[Dict[str, Any]]:
        return [r for r in self.records if r["kind"] == "req"]

    @property
    def depth(self) -> int:
        return int(self.header["depth"])

    # ----------------------------------------------------------------- (de)io
    @staticmethod
    def from_records(records: Sequence[Dict[str, Any]],
                     expect: str = SCHEMA) -> "Trace":
        if not records:
            raise TraceSchemaError("empty trace")
        header = records[0]
        if header.get("kind") != "header" or header.get("schema") != expect:
            raise TraceSchemaError(
                f"first record is not a {expect!r} header: {header!r}")
        major = int(header.get("version", [0, 0])[0])
        if major != SCHEMA_MAJOR:
            raise TraceSchemaError(
                f"unsupported {expect} schema major {major} "
                f"(this reader speaks {SCHEMA_MAJOR}.x)")
        if header.get("compact"):
            expanded = expand_records(records)
            return Trace(expanded[0], expanded[1:])
        return Trace(header, list(records[1:]))

    @staticmethod
    def loads(text: str, expect: str = SCHEMA) -> "Trace":
        records = [json.loads(line) for line in text.splitlines() if line]
        return Trace.from_records(records, expect)

    @staticmethod
    def load(path: str, expect: str = SCHEMA) -> "Trace":
        with open(path) as fh:
            return Trace.loads(fh.read(), expect)

    def dumps(self) -> str:
        lines = [dumps_record(self.header)]
        lines.extend(dumps_record(r) for r in self.records)
        return "\n".join(lines) + "\n"

    def dump(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.dumps())


# ---------------------------------------------------------------------------
# Compaction: delta-encoded tick records
# ---------------------------------------------------------------------------

# Canonical tick field order, exactly as `TraceRecorder.execute` writes it —
# compaction and expansion both key off this so the round trip is
# byte-identical under `dumps_record`.  Optional fields ("cached", schema
# 1.4; "host_s", schema 1.3) are present uniformly or omitted trace-wide
# (never mixed): "host_s" appears iff the backend reports host overhead,
# "cached" iff the scheduler has prefix caching enabled — so earlier-schema
# streams keep their exact bytes.
TICK_FIELDS = ("now", "batch", "prefill_budget", "decode_budget", "kv_free",
               "wp", "rd", "preempts", "stage_times", "cached", "host_s",
               "exit")
_OPTIONAL_TICK_FIELDS = ("cached", "host_s")
_CANONICAL_TICK_KEYS = ["kind", "tick"] + list(TICK_FIELDS)
# Every omit-in-place subset of the optional fields is a valid canonical
# layout (a trace may carry any combination, each uniformly).
_VALID_TICK_KEY_LISTS = [
    [k for k in _CANONICAL_TICK_KEYS if k not in omitted]
    for r in range(len(_OPTIONAL_TICK_FIELDS) + 1)
    for omitted in itertools.combinations(_OPTIONAL_TICK_FIELDS, r)]


STEADY_DECODE = "+1"    # batch marker: the cohort's previous batch, +1 step
_ABSENT = object()      # sentinel: field not present on the previous tick


def _is_steady_decode(cohort_batch: Optional[Dict[str, Any]],
                      batch: Optional[Dict[str, Any]], depth: int) -> bool:
    """True when `batch` is the *cohort's* previous micro-batch advanced one
    decode step.  The pipeline's exclusion rule (one resident micro-batch
    per request) means a decode cohort recurs every `depth` ticks, not every
    tick — so the reference is the batch from `depth` ticks earlier: no
    prefill on either side, batch id advanced by exactly `depth` (one id per
    tick), and the same requests each one position further.  This is the
    steady state a saturated decode run repeats for thousands of ticks."""
    if cohort_batch is None or batch is None:
        return False
    if cohort_batch["prefill"] or batch["prefill"]:
        return False
    if batch["id"] != cohort_batch["id"] + depth:
        return False
    return batch["decode"] == [[rid, start + 1]
                               for rid, start in cohort_batch["decode"]]


def _steady_decode_batch(cohort_batch: Dict[str, Any],
                         depth: int) -> Dict[str, Any]:
    """Reconstruct a `STEADY_DECODE` batch from the cohort's previous
    expanded one, in the recorder's canonical key order (byte-identity
    depends on it)."""
    return {"id": cohort_batch["id"] + depth,
            "prefill": [],
            "decode": [[rid, start + 1] for rid, start in
                       cohort_batch["decode"]]}


def _rle(lst: List[Any]) -> List[List[Any]]:
    runs: List[List[Any]] = []
    for v in lst:
        if runs and runs[-1][0] == v:
            runs[-1][1] += 1
        else:
            runs.append([v, 1])
    return runs


def _rle_expand(runs: Sequence[Sequence[Any]]) -> List[Any]:
    out: List[Any] = []
    for v, n in runs:
        out.extend([v] * int(n))
    return out


def _maybe_rle(lst: Any) -> Any:
    """Run-length encode a list as `{"r": [[value, count], ...]}` iff the
    encoding is strictly shorter under the canonical serialization (schema
    1.5).  Deterministic, so compaction of an expanded stream reproduces
    the same bytes; a raw list is never a dict, so expansion can always
    tell the two forms apart."""
    if not isinstance(lst, list) or len(lst) < 2:
        return lst
    enc = {"r": _rle(lst)}
    if len(dumps_record(enc)) < len(dumps_record(lst)):
        return enc
    return lst


def _expand_rle_fields(full: Dict[str, Any]) -> None:
    """Undo `_maybe_rle` on a tick's stage_times / exit token list."""
    st = full.get("stage_times")
    if isinstance(st, dict):
        full["stage_times"] = _rle_expand(st["r"])
    ex = full.get("exit")
    if isinstance(ex, dict) and isinstance(ex.get("tokens"), dict):
        full["exit"] = {**ex, "tokens": _rle_expand(ex["tokens"]["r"])}


def compact_records(records: Sequence[Dict[str, Any]]
                    ) -> List[Dict[str, Any]]:
    """Delta-encode a raw trace: each tick keeps only the fields that differ
    from the previous tick, and a steady decode batch (same requests, start
    positions advanced by one, consecutive id) collapses to the
    `STEADY_DECODE` marker — decode-heavy runs shrink a further ~2x beyond
    the scalar-field deltas.  The header gains `"compact": true`; non-tick
    records pass through verbatim.  Raises `TraceSchemaError` on ticks not
    in the recorder's canonical field order — those could not be re-expanded
    byte-identically."""
    header = records[0]
    if header.get("kind") != "header":
        raise TraceSchemaError("first record must be the header")
    if header.get("compact"):
        return list(records)
    depth = int(header.get("depth", 1))
    out: List[Dict[str, Any]] = [{**header, "compact": True}]
    prev: Optional[Dict[str, Any]] = None
    ring: Deque[Dict[str, Any]] = deque(maxlen=depth)   # last `depth` ticks
    counter = 0
    for rec in records[1:]:
        if rec.get("kind") != "tick":
            out.append(rec)
            continue
        if list(rec) not in _VALID_TICK_KEY_LISTS:
            raise TraceSchemaError(
                f"tick {rec.get('tick')} is not in canonical field order; "
                "cannot delta-encode losslessly")
        small: Dict[str, Any] = {"kind": "tick"}
        if rec["tick"] != counter:
            small["tick"] = rec["tick"]
        counter = rec["tick"] + 1
        for f in TICK_FIELDS:
            if f not in rec:                 # optional field, omitted trace-wide
                continue
            if prev is None or prev.get(f, _ABSENT) != rec[f]:
                small[f] = rec[f]
        if len(ring) == depth and _is_steady_decode(ring[0]["batch"],
                                                    rec["batch"], depth):
            small["batch"] = STEADY_DECODE
        # schema 1.5: run-length encode the per-stage latency vector and
        # the exiting micro-batch's token list when that is a net win —
        # long decode runs emit [t]*depth latencies and (in sim) constant
        # token ids every tick, which the field-delta alone cannot touch
        # because "exit" always differs tick-to-tick
        if isinstance(small.get("stage_times"), list):
            small["stage_times"] = _maybe_rle(small["stage_times"])
        ex = small.get("exit")
        if isinstance(ex, dict) and isinstance(ex.get("tokens"), list):
            toks = _maybe_rle(ex["tokens"])
            if toks is not ex["tokens"]:
                small["exit"] = {**ex, "tokens": toks}
        prev = rec
        ring.append(rec)
        out.append(small)
    return out


def expand_records(records: Sequence[Dict[str, Any]]
                   ) -> List[Dict[str, Any]]:
    """Inverse of `compact_records`: reconstruct every tick in full, in
    canonical field order, inheriting absent fields from the previous
    tick."""
    header = {k: v for k, v in records[0].items() if k != "compact"}
    out: List[Dict[str, Any]] = [header]
    depth = int(header.get("depth", 1))
    prev: Optional[Dict[str, Any]] = None
    ring: Deque[Dict[str, Any]] = deque(maxlen=depth)   # last `depth` ticks
    counter = 0
    for rec in records[1:]:
        if rec.get("kind") != "tick":
            out.append(rec)
            continue
        full: Dict[str, Any] = {"kind": "tick",
                                "tick": rec.get("tick", counter)}
        for f in TICK_FIELDS:
            if f == "batch" and rec.get(f) == STEADY_DECODE:
                if len(ring) < depth or ring[0]["batch"] is None:
                    raise TraceSchemaError(
                        f"compacted tick {full['tick']} marks a steady "
                        "decode batch but its cohort's previous batch is "
                        "undefined")
                full[f] = _steady_decode_batch(ring[0]["batch"], depth)
            elif f in rec:
                full[f] = rec[f]
            elif prev is not None and f in prev:
                full[f] = prev[f]
            elif f in _OPTIONAL_TICK_FIELDS:
                continue                     # omitted trace-wide (pre-1.3)
            else:
                raise TraceSchemaError(
                    f"compacted tick {full['tick']} omits {f!r} but no "
                    "previous tick defines it")
        _expand_rle_fields(full)             # schema 1.5 run-length forms
        counter = full["tick"] + 1
        out.append(full)
        prev = full
        ring.append(full)
    return out


# ---------------------------------------------------------------------------
# Recording
# ---------------------------------------------------------------------------

def _batch_summary(batch) -> Optional[Dict[str, Any]]:
    """JSON form of a micro-batch's composition — the scheduler *decision*
    strict replay asserts on.  Prefill entries carry the produces-token flag
    so replayed traces can track decode-population promotions."""
    if batch is None:
        return None
    return {
        "id": batch.batch_id,
        "prefill": [[s.request.request_id, s.start_pos, s.num_tokens,
                     int(s.produces_token)] for s in batch.prefill],
        "decode": [[s.request.request_id, s.start_pos]
                   for s in batch.decode],
    }


def scheduler_header(scheduler: PipelineScheduler, depth: int
                     ) -> Dict[str, Any]:
    cfg = scheduler.cfg
    kv = scheduler.kv
    return {
        "kind": "header",
        "schema": SCHEMA,
        "version": [SCHEMA_MAJOR, SCHEMA_MINOR],
        "depth": depth,
        "throttle": {
            "num_iters_T": cfg.num_iters_T,
            "max_prefill_tokens": cfg.max_prefill_tokens,
            "min_prefill_tokens": cfg.min_prefill_tokens,
            "kv_threshold": cfg.kv_threshold,
            "pipeline_depth": cfg.pipeline_depth,
            "policy": cfg.policy.value,
        },
        "kv": {
            "num_pages": kv.num_pages,
            "page_size": kv.page_size,
            "prefix_caching": kv.enable_prefix_caching,
        },
        "scheduler": {
            "max_model_len": scheduler.max_model_len,
            "max_batch_seqs": scheduler.max_batch_seqs,
            "max_prefill_seqs": scheduler.max_prefill_seqs,
            "max_chunk_tokens": scheduler.max_chunk_tokens,
            "max_decode_seqs": scheduler.max_decode_seqs,
        },
    }


def scheduler_from_header(header: Dict[str, Any]) -> PipelineScheduler:
    """Rebuild the exact scheduler configuration a trace was recorded with."""
    th = header["throttle"]
    cfg = ThrottleConfig(
        num_iters_T=th["num_iters_T"],
        max_prefill_tokens=th["max_prefill_tokens"],
        min_prefill_tokens=th["min_prefill_tokens"],
        kv_threshold=th["kv_threshold"],
        pipeline_depth=th["pipeline_depth"],
        policy=PrefillPolicy(th["policy"]),
    )
    kvh = header["kv"]
    kv = PagedKVManager(kvh["num_pages"], kvh["page_size"],
                        enable_prefix_caching=kvh["prefix_caching"])
    sh = header["scheduler"]
    return PipelineScheduler(
        cfg, kv,
        max_model_len=sh["max_model_len"],
        max_batch_seqs=sh["max_batch_seqs"],
        max_prefill_seqs=sh["max_prefill_seqs"],
        max_chunk_tokens=sh["max_chunk_tokens"],
        max_decode_seqs=sh["max_decode_seqs"],
    )


class TraceRecorder(ExecutionBackend):
    """Wraps any `ExecutionBackend`, logging one record per tick.

    Transparent to the `TickLoop`: every protocol call is forwarded to the
    wrapped backend; the recording is a pure observation of the scheduler
    state at execute time plus the backend's `ExecResult`.  Integrators call
    `record_arrival(req)` right after `scheduler.add_request(req)` so replay
    can reproduce the admission queue order exactly.
    """

    def __init__(self, inner: ExecutionBackend, sink: Sink = None) -> None:
        self.inner = inner
        self.writer = TraceWriter(sink)
        self._tick = 0
        self._last_preempts = 0
        self._header_written = False

    # ------------------------------------------------------------- forwarding
    @property
    def scheduler(self) -> PipelineScheduler:
        return self.inner.scheduler

    @scheduler.setter
    def scheduler(self, sched: PipelineScheduler) -> None:
        self.inner.scheduler = sched

    @property
    def depth(self) -> int:
        return self.inner.depth

    def clock(self) -> float:
        return self.inner.clock()

    def prepare(self, batch) -> Any:
        return self.inner.prepare(batch)

    def finish_request(self, req: Request) -> None:
        self.inner.finish_request(req)

    def __getattr__(self, name: str) -> Any:
        if name == "inner":
            raise AttributeError(name)
        return getattr(self.inner, name)

    # -------------------------------------------------------------- recording
    def _ensure_header(self) -> None:
        if not self._header_written:
            self.writer.write(scheduler_header(self.scheduler, self.depth))
            self._header_written = True

    def record_arrival(self, req: Request) -> None:
        """Log a request the moment it enters the scheduler's waiting queue."""
        self._ensure_header()
        self.writer.write({
            "kind": "req",
            "rid": req.request_id,
            "at": req.metrics.arrival_time,
            "prompt": list(req.prompt_token_ids),
            "max_new": req.sampling.max_new_tokens,
            "stop": list(req.sampling.stop_token_ids),
            "temp": req.sampling.temperature,
            # schema 1.2: scheduling class — admission order depends on it,
            # so replay must rebuild it or strict mode diverges
            "priority": req.sampling.priority,
            "slo": req.sampling.slo_class,
        })

    def record_abort(self, request_id: str, now: float) -> None:
        """A user abort was applied to the scheduler (repro.serving).
        Integrators call this right after `scheduler.abort_request` returns
        non-None, so replay applies the abort at the same stream position."""
        self._ensure_header()
        self.writer.write({"kind": "abort", "rid": request_id, "now": now})

    def record_scale_event(self, kind: str, now: float) -> None:
        """Elastic fleet lifecycle marker (schema 1.6): `scale_up` opens a
        freshly-added replica's stream, `drain` marks the instant this
        replica was masked from admission, `retire` is the last record a
        drained replica writes before its recorder closes.  Markers carry
        no scheduler state — replay re-records them verbatim (the request
        movement a drain causes is already fully described by the
        surrounding migrate/steal records)."""
        if kind not in ("scale_up", "drain", "retire"):
            raise ValueError(f"unknown scale event kind {kind!r}")
        self._ensure_header()
        self.writer.write({"kind": kind, "now": now})

    def record_migrate_out(self, request_id: str, now: float) -> None:
        """The control plane drained a request off this replica (§9)."""
        self.record_move_out(request_id, now, kind="migrate")

    def record_migrate_in(self, req: Request, now: float) -> None:
        self.record_move_in(req, now, kind="migrate")

    def record_move_out(self, request_id: str, now: float, *,
                        kind: str = "migrate") -> None:
        """The control plane drained a request off this replica — `kind`
        is "migrate" (§9 rebalance) or "handoff" (schema 1.5: the disagg
        prefill->decode transfer; identical layout, distinct intent)."""
        if kind not in ("migrate", "handoff"):
            raise ValueError(f"unknown move kind {kind!r}")
        self._ensure_header()
        self.writer.write({"kind": kind, "op": "out",
                           "rid": request_id, "now": now})

    def record_move_in(self, req: Request, now: float, *,
                       kind: str = "migrate") -> None:
        """The control plane adopted a request here at its current position
        (possibly mid-prefill: `prefilled` is the chunk cursor the
        destination resumes from).  The record embeds the full request
        state (progress, outputs so far, timing metrics), so this
        replica's trace replays stand-alone — replay re-materializes the
        migrant exactly as it arrived."""
        if kind not in ("migrate", "handoff"):
            raise ValueError(f"unknown move kind {kind!r}")
        self._ensure_header()
        m = req.metrics
        self.writer.write({
            "kind": kind, "op": "in",
            "rid": req.request_id,
            "now": now,
            "prompt": list(req.prompt_token_ids),
            "output": list(req.output_token_ids),
            "prefilled": req.num_prefilled,
            "state": req.state.value,
            "max_new": req.sampling.max_new_tokens,
            "stop": list(req.sampling.stop_token_ids),
            "temp": req.sampling.temperature,
            "priority": req.sampling.priority,
            "slo": req.sampling.slo_class,
            "arrival": m.arrival_time,
            "first_sched": m.first_scheduled_time,
            "first_token": m.first_token_time,
            "preemptions": m.num_preemptions,
        })

    def reset(self, now: float) -> None:
        self._ensure_header()
        self.writer.write({"kind": "reset", "now": now})
        self.inner.reset(now)

    def execute(self, ring, exiting_id, now) -> ExecResult:
        self._ensure_header()
        result = self.inner.execute(ring, exiting_id, now)
        # the recorder logs exit tokens at execute time, so a deferred
        # result is forced here — traced engines are synchronous by
        # construction (PipelineEngine rejects async_dispatch + trace_path)
        result.resolve()
        sched = self.scheduler
        entering_id = ring[0][0]
        batch = (sched.get_batch(entering_id)
                 if entering_id is not None else None)
        exit_rec = None
        if exiting_id is not None:
            exit_rec = {"id": exiting_id,
                        "tokens": [int(t) for t in result.tokens],
                        "at": result.completed_at}
        preempts = sched.stats.preemptions
        rec: Dict[str, Any] = {
            "kind": "tick",
            "tick": self._tick,
            "now": now,
            "batch": _batch_summary(batch),
            "prefill_budget": sched.stats.prefill_budgets[-1],
            "decode_budget": sched.stats.decode_budgets[-1],
            "kv_free": sched.kv.kv_free_rate,
            "wp": sched.num_waiting_prefill_tokens,
            "rd": sched.num_running_decode,
            "preempts": preempts - self._last_preempts,
            "stage_times": result.stage_times,
        }
        if sched.kv.enable_prefix_caching:   # schema 1.4, optional
            rec["cached"] = sched.stats.cached_prefill_tokens[-1]
        if result.host_s is not None:        # schema 1.3, optional per-backend
            rec["host_s"] = result.host_s
        rec["exit"] = exit_rec
        self.writer.write(rec)
        self._last_preempts = preempts
        self._tick += 1
        return result

    # ----------------------------------------------------------------- result
    @property
    def num_ticks(self) -> int:
        """Ticks recorded so far."""
        return self._tick

    def trace(self) -> Trace:
        """The recording so far, as an in-memory `Trace`."""
        return Trace.from_records(self.writer.records)

    def close(self) -> None:
        self.writer.close()


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------

class TraceBackend(ExecutionBackend):
    """The third `ExecutionBackend`: per-tick cost and tokens come from a
    recorded trace instead of a model or a roofline.

    strict mode (default) — asserts each tick's scheduler decision (batch
    composition, throttle budgets, KV/queue signals) matches the recording
    and returns the recorded tokens/latencies verbatim, so a full replay
    reproduces the original run bit-for-bit (requests, metrics, and a
    re-recorded trace all identical).  Divergence raises `TraceDivergence`
    with the tick index and field diff.

    timing-only mode — no assertions: the scheduler is free to decide
    differently (a *what-if* replay, e.g. after a policy change) while each
    tick still costs what the recorded tick cost.  Sampled tokens are
    placeholders when the recorded ones no longer line up.
    """

    STRICT = "strict"
    TIMING = "timing-only"

    def __init__(self, trace: Trace, mode: str = STRICT) -> None:
        if mode not in (self.STRICT, self.TIMING):
            raise ValueError(f"unknown replay mode {mode!r}")
        self.trace = trace
        self.mode = mode
        self._ticks = trace.ticks
        self._k = 0
        self._last_preempts = 0
        self._now = 0.0

    # --------------------------------------------------------------- protocol
    @property
    def depth(self) -> int:
        return self.trace.depth

    def clock(self) -> float:
        if self._k < len(self._ticks):
            return self._ticks[self._k]["now"]
        return self._now

    def reset(self, now: float) -> None:
        self._now = max(self._now, now)

    def execute(self, ring, exiting_id, now) -> ExecResult:
        self._now = max(self._now, now)
        rec = self._ticks[self._k] if self._k < len(self._ticks) else None
        k = self._k
        self._k += 1

        exiting = (self.scheduler.get_batch(exiting_id)
                   if exiting_id is not None else None)
        n_produce = (sum(1 for s in exiting.seqs if s.produces_token)
                     if exiting is not None else 0)

        if self.mode == self.STRICT:
            if rec is None:
                raise TraceDivergence(k, [
                    ("tick", "<end of trace>", "replay still has work")])
            self._check_tick(k, rec, ring, exiting_id, n_produce)
            if exiting_id is None:
                return ExecResult([], now, stage_times=rec["stage_times"],
                                  host_s=rec.get("host_s"))
            return ExecResult(list(rec["exit"]["tokens"]),
                              rec["exit"]["at"],
                              stage_times=rec["stage_times"],
                              host_s=rec.get("host_s"))

        # timing-only: recorded latency, scheduler free to diverge
        if rec is not None and rec["exit"] is not None:
            latency = max(0.0, rec["exit"]["at"] - rec["now"])
        else:
            latency = 0.0
        stage_times = rec["stage_times"] if rec is not None else None
        host_s = rec.get("host_s") if rec is not None else None
        if exiting_id is None:
            return ExecResult([], now, stage_times=stage_times, host_s=host_s)
        tokens = None
        if rec is not None and rec["exit"] is not None \
                and len(rec["exit"]["tokens"]) == n_produce:
            tokens = list(rec["exit"]["tokens"])
        return ExecResult(tokens if tokens is not None else [0] * n_produce,
                          now + latency, stage_times=stage_times,
                          host_s=host_s)

    # ------------------------------------------------------------- divergence
    def _check_tick(self, k: int, rec: Dict[str, Any], ring,
                    exiting_id: Optional[int], n_produce: int) -> None:
        sched = self.scheduler
        entering_id = ring[0][0]
        actual = _batch_summary(sched.get_batch(entering_id)
                                if entering_id is not None else None)
        preempts = sched.stats.preemptions
        diffs: List[Tuple[str, Any, Any]] = []

        def cmp(fieldname: str, want: Any, got: Any) -> None:
            if want != got:
                diffs.append((fieldname, want, got))

        want_batch = rec["batch"]
        if (want_batch is None) != (actual is None):
            cmp("batch", want_batch, actual)
        elif want_batch is not None:
            cmp("batch.id", want_batch["id"], actual["id"])
            cmp("batch.prefill", want_batch["prefill"], actual["prefill"])
            cmp("batch.decode", want_batch["decode"], actual["decode"])
        cmp("prefill_budget", rec["prefill_budget"],
            sched.stats.prefill_budgets[-1])
        cmp("decode_budget", rec["decode_budget"],
            sched.stats.decode_budgets[-1])
        cmp("kv_free", rec["kv_free"], sched.kv.kv_free_rate)
        cmp("wp", rec["wp"], sched.num_waiting_prefill_tokens)
        cmp("rd", rec["rd"], sched.num_running_decode)
        cmp("preempts", rec["preempts"], preempts - self._last_preempts)
        if "cached" in rec:                  # schema 1.4: prefix-cache adoption
            cmp("cached", rec["cached"],
                sched.stats.cached_prefill_tokens[-1])
        want_exit = rec["exit"]
        if (want_exit is None) != (exiting_id is None):
            cmp("exit", want_exit,
                None if exiting_id is None else {"id": exiting_id})
        elif want_exit is not None:
            cmp("exit.id", want_exit["id"], exiting_id)
            cmp("exit.num_tokens", len(want_exit["tokens"]), n_produce)
        self._last_preempts = preempts
        if diffs:
            raise TraceDivergence(k, diffs)


@dataclass
class ReplayReport:
    """Outcome of one replay: the requests as re-materialized by the replayed
    scheduler, plus the re-recorded trace when requested."""

    mode: str
    ticks: int
    finished: List[Request]
    scheduler: PipelineScheduler
    recorded: Optional[Trace] = None

    def request_metrics(self) -> Dict[str, Tuple[Optional[float],
                                                 Optional[float], int]]:
        """rid -> (ttft, e2el, num_output_tokens) — the comparison surface
        for determinism tests (two replays must agree exactly)."""
        return {r.request_id: (r.metrics.ttft(), r.metrics.e2el(),
                               r.num_output_tokens)
                for r in self.finished}

    def outputs(self) -> Dict[str, List[int]]:
        return {r.request_id: list(r.output_token_ids)
                for r in self.finished}

    def summary(self) -> str:
        """One-line human summary — shared by every --trace-replay CLI."""
        ttfts = [r.metrics.ttft() for r in self.finished
                 if r.metrics.ttft() is not None]
        return (f"{self.mode} replay — {self.ticks} ticks, "
                f"{len(self.finished)} requests, "
                f"{sum(r.num_output_tokens for r in self.finished)} tokens, "
                f"TTFT_mean={float(np.mean(ttfts or [0])):.4f}s")


def _sampling_from_record(rec: Dict[str, Any]) -> SamplingParams:
    """Shared by req + migrate-in records.  Pre-1.2 traces carry no
    priority/slo fields; the defaults reproduce their recorded scheduling
    exactly (all-default queues admit in FCFS order)."""
    return SamplingParams(max_new_tokens=rec["max_new"],
                          temperature=rec.get("temp", 0.0),
                          stop_token_ids=tuple(rec.get("stop", ())),
                          priority=int(rec.get("priority", 0)),
                          slo_class=rec.get("slo", "interactive"))


def request_from_record(rec: Dict[str, Any]) -> Request:
    req = Request(rec["rid"], list(rec["prompt"]), _sampling_from_record(rec))
    req.metrics.arrival_time = rec["at"]
    return req


def migrated_request_from_record(rec: Dict[str, Any]) -> Request:
    """Re-materialize a migrant exactly as it arrived: progress, outputs so
    far, and cross-replica timing metrics all come from the record."""
    req = Request(rec["rid"], list(rec["prompt"]), _sampling_from_record(rec))
    req.output_token_ids = list(rec["output"])
    req.num_prefilled = int(rec["prefilled"])
    req.state = RequestState(rec["state"])
    m = req.metrics
    m.arrival_time = rec["arrival"]
    m.first_scheduled_time = rec.get("first_sched")
    m.first_token_time = rec.get("first_token")
    m.num_preemptions = int(rec.get("preemptions", 0))
    return req


def replay_trace(trace: Trace, *, mode: str = TraceBackend.STRICT,
                 record_to: Sink = None, record: bool = False,
                 scheduler: Optional[PipelineScheduler] = None,
                 max_extra_ticks: int = 100000) -> ReplayReport:
    """Drive the recorded event stream through a fresh scheduler + TickLoop.

    Records are applied in stream order: `req` records enter the waiting
    queue (reproducing admission order), `tick` records step the loop at the
    recorded time, `reset` records abort in-flight work.  With `record=True`
    (or a `record_to` sink) the replay is itself recorded — the round-trip
    determinism check compares that re-recording against the original.

    Passing `scheduler` overrides the header-built one — the what-if knob:
    replay the recorded workload and latencies under a *different* policy
    (use timing-only mode; a changed policy will diverge under strict).
    """
    sched = scheduler or scheduler_from_header(trace.header)
    backend = TraceBackend(trace, mode=mode)
    recorder: Optional[TraceRecorder] = None
    loop_backend: ExecutionBackend = backend
    if record or record_to is not None:
        recorder = TraceRecorder(backend, record_to)
        loop_backend = recorder
    loop = TickLoop(sched, loop_backend)

    now = 0.0
    for rec in trace.records:
        kind = rec["kind"]
        if kind == "req":
            req = request_from_record(rec)
            sched.add_request(req)
            if recorder is not None:
                recorder.record_arrival(req)
        elif kind == "tick":
            now = rec["now"]
            loop.step(now)
        elif kind == "reset":
            loop.abort_inflight()
            now = rec["now"]
            loop_backend.reset(now)
        elif kind == "abort":
            # user aborts are part of the workload: re-apply at the recorded
            # stream position (in-flight ones finalize at the next retire,
            # exactly as they did live)
            req = sched.abort_request(rec["rid"], rec["now"])
            if req is not None and req.is_finished:
                loop.finished.append(req)
            if recorder is not None:
                recorder.record_abort(rec["rid"], rec["now"])
        elif kind in ("migrate", "handoff"):
            # control-plane moves are applied in stream order, exactly where
            # the recording interleaved them between ticks (§9); "handoff"
            # (schema 1.5) is the disagg prefill->decode transfer — same
            # drain/adopt semantics, re-recorded under its own kind
            if rec["op"] == "out":
                drained = sched.drain_request(rec["rid"])
                if drained is not None and sched.kv.has_request(rec["rid"]):
                    sched.kv.free(rec["rid"])
                if recorder is not None:
                    recorder.record_move_out(rec["rid"], rec["now"],
                                             kind=kind)
            else:
                req = migrated_request_from_record(rec)
                if req.num_prefilled:
                    sched.kv.allocate(req.request_id, req.num_prefilled)
                sched.adopt_request(req)
                if recorder is not None:
                    recorder.record_move_in(req, rec["now"], kind=kind)
        elif kind in ("scale_up", "drain", "retire"):
            # elastic lifecycle markers (schema 1.6): no scheduler state
            # change — the request movement a drain causes is already in
            # the stream as migrate/steal records.  Re-record verbatim so
            # elastic traces round-trip byte-identically.
            if recorder is not None:
                recorder.record_scale_event(kind, rec["now"])
        elif kind == "route":  # router streams are not tick traces
            raise TraceSchemaError(
                "route records belong to a gllm-route trace, not a replayable "
                "tick trace")

    if mode == TraceBackend.STRICT:
        if loop.has_work:
            raise TraceDivergence(backend._k, [
                ("end", "<all work retired>",
                 f"pending work after final recorded tick "
                 f"(waiting={len(sched.waiting)}, busy={loop.busy})")])
    else:
        # what-if replays may need more (or fewer) ticks than were recorded
        t = 0
        while loop.has_work and t < max_extra_ticks:
            now += 1e-3
            loop.step(now)
            t += 1

    recorded = recorder.trace() if recorder is not None else None
    if recorder is not None:
        recorder.close()
    return ReplayReport(mode=mode, ticks=backend._k, finished=loop.finished,
                        scheduler=sched, recorded=recorded)


# ---------------------------------------------------------------------------
# Calibration surface (consumed by CostModel.fit_from_trace)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TickSample:
    """Per-tick workload + observed per-stage service time, in exactly the
    terms `CostModel.stage_time` speaks."""

    prefill_tokens: int
    decode_tokens: int
    prefill_ctx: int
    decode_ctx: int
    stage_time: float       # un-straggled per-stage latency (min over stages)
    host_s: Optional[float] = None   # per-tick host overhead (schema 1.3)


def tick_samples(trace: Trace) -> List[TickSample]:
    """Non-empty ticks that recorded per-stage latencies (backends that
    cannot attribute time per stage record null and are skipped)."""
    out: List[TickSample] = []
    for rec in trace.ticks:
        batch, times = rec["batch"], rec["stage_times"]
        if batch is None or not times:
            continue
        pf, dc = batch["prefill"], batch["decode"]
        p_ctx = max((e[1] + e[2] for e in pf), default=0)
        d_ctx = int(np.mean([e[1] for e in dc])) if dc else 0
        out.append(TickSample(
            prefill_tokens=sum(e[2] for e in pf),
            decode_tokens=len(dc),
            prefill_ctx=p_ctx,
            decode_ctx=d_ctx,
            stage_time=float(min(times)),
            host_s=rec.get("host_s"),
        ))
    return out


def host_overhead_samples(trace: Trace) -> List[float]:
    """Per-tick `host_s` values of non-bubble ticks (schema ≥ 1.3).  Empty
    for traces whose backend reported no host overhead."""
    return [float(rec["host_s"]) for rec in trace.ticks
            if rec.get("host_s") is not None and rec["batch"] is not None]


def calibration_error(trace: Trace, cost) -> float:
    """Mean relative error of `cost.stage_time` against the recorded
    per-stage latencies — the sim-vs-engine closure bound."""
    samples = tick_samples(trace)
    if not samples:
        raise ValueError("trace has no ticks with stage latencies")
    errs = []
    for s in samples:
        pred = cost.stage_time(s.prefill_tokens, s.decode_tokens,
                               s.prefill_ctx, s.decode_ctx)
        errs.append(abs(pred - s.stage_time) / max(s.stage_time, 1e-12))
    return float(np.mean(errs))


# ---------------------------------------------------------------------------
# CLI — `make trace-check` replays the checked-in golden traces
# ---------------------------------------------------------------------------

def check_trace(path: str) -> ReplayReport:
    """Strict replay + re-record; raises on divergence or non-determinism.
    Compacted traces are expanded on load, so the identity is checked against
    the canonical (expanded) byte stream either way."""
    with open(path) as fh:
        raw = fh.read()
    trace = Trace.loads(raw)
    original = trace.dumps()
    report = replay_trace(trace, record=True)
    rerecorded = report.recorded.dumps()
    if rerecorded != original:
        # line-level pinpoint for the report
        for i, (a, b) in enumerate(zip(original.splitlines(),
                                       rerecorded.splitlines())):
            if a != b:
                raise TraceDivergence(i, [("line", a, b)])
        raise TraceDivergence(-1, [("length", len(original),
                                    len(rerecorded))])
    return report


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro.runtime.trace",
        description="record/replay tooling for gLLM tick traces")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_check = sub.add_parser("check", help="strict replay + round-trip "
                             "identity (golden-trace gate)")
    p_check.add_argument("paths", nargs="+")
    p_replay = sub.add_parser("replay", help="replay one trace")
    p_replay.add_argument("path")
    p_replay.add_argument("--timing-only", action="store_true",
                          help="what-if replay: recorded latencies, free "
                          "scheduler decisions")
    p_fit = sub.add_parser("fit", help="calibrate CostModel from a trace")
    p_fit.add_argument("path")
    p_fit.add_argument("--arch", default="qwen2.5-14b")
    p_fit.add_argument("--pp", type=int, default=None)
    p_compact = sub.add_parser(
        "compact", help="delta-encode a trace (lossless; replays and "
        "checks identically)")
    p_compact.add_argument("path")
    p_compact.add_argument("-o", "--out", default=None,
                           help="output path (default: PATH.compact)")
    args = ap.parse_args(argv)

    if args.cmd == "check":
        for path in args.paths:
            report = check_trace(path)
            print(f"{path}: OK — {report.ticks} ticks, "
                  f"{len(report.finished)} requests, round-trip identical")
        return 0
    if args.cmd == "replay":
        mode = TraceBackend.TIMING if args.timing_only else TraceBackend.STRICT
        report = replay_trace(Trace.load(args.path), mode=mode)
        print(f"{args.path}: {report.summary()}")
        return 0
    if args.cmd == "fit":
        from repro.configs import get_config
        from repro.runtime.simulator import CostModel, cost_model_for

        trace = Trace.load(args.path)
        base = cost_model_for(get_config(args.arch),
                              pp=args.pp or trace.depth)
        fitted = CostModel.fit_from_trace(trace, base)
        err = calibration_error(trace, fitted)
        print(f"{args.path}: fitted mfu={fitted.mfu:.4f} "
              f"hbm_eff={fitted.hbm_eff:.4f} fixed_us={fitted.fixed_us:.2f} "
              f"| mean relative error {err:.3%}")
        return 0
    if args.cmd == "compact":
        with open(args.path) as fh:
            records = [json.loads(line) for line in fh if line.strip()]
        compacted = compact_records(records)
        out_path = args.out or args.path + ".compact"
        raw = "\n".join(dumps_record(r) for r in records) + "\n"
        small = "\n".join(dumps_record(r) for r in compacted) + "\n"
        # lossless by construction — verify anyway, BEFORE any artifact
        # exists on disk
        if Trace.loads(small).dumps() != Trace.loads(raw).dumps():
            raise TraceSchemaError(
                f"compaction of {args.path} did not round-trip losslessly; "
                "refusing to write output")
        with open(out_path, "w") as fh:
            fh.write(small)
        print(f"{args.path}: {len(raw)} -> {len(small)} bytes "
              f"({len(small) / max(len(raw), 1):.1%}) -> {out_path}")
        return 0
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
