"""Adam optimizer (self-contained — no optax in this environment).

State is a pytree mirroring the parameters.  Sharding follows the parameter
PartitionSpecs, so optimizer memory scales down with model parallelism; the
step is pure jnp and runs inside the jitted train_step.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adam_init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), m=zeros,
                     v=jax.tree.map(jnp.copy, zeros))


def adam_abstract(params) -> AdamState:
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return AdamState(step=jax.ShapeDtypeStruct((), jnp.int32), m=z, v=z)


def adam_pspecs(param_specs) -> AdamState:
    from jax.sharding import PartitionSpec as P
    return AdamState(step=P(), m=param_specs, v=param_specs)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adam_update(cfg: AdamConfig, grads, params, state: AdamState,
                gnorm: jax.Array = None) -> Tuple[Any, AdamState, jax.Array]:
    if gnorm is None:
        gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, p, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new_p, new_m, new_v = [], [], []
    for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v):
        a, b, c = upd(g, p, m, v)
        new_p.append(a), new_m.append(b), new_v.append(c)
    return (jax.tree.unflatten(treedef, new_p),
            AdamState(step=step, m=jax.tree.unflatten(treedef, new_m),
                      v=jax.tree.unflatten(treedef, new_v)),
            gnorm)
