"""Explicit collectives: gradient sync (optionally compressed) and the
flash-decode partial-softmax merge.

All gradient reductions run in f32 (mixed-precision correct; also avoids an
XLA:CPU AllReducePromotion crash on bf16 shard_map-transpose psums — see
DESIGN.md §7).

Compression modes:
  None     — plain f32 psum.
  "int8"   — global-scale int8 quantization, summed exactly in int32
             (identical result on every shard; payload algebra matches a ring
             all-reduce of int8 chunks).
  "ring8"  — manual ring all-reduce via ppermute with an int8 wire format:
             reduce-scatter then all-gather, requantizing per hop.  This is
             the byte-saving variant — the HLO collective-permute payload is
             1 byte/element instead of 4 (visible in the roofline collective
             term).  Lossy (stochastic-free rounding), intended for
             cross-pod gradient sync at scale.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

Axes = Union[str, Tuple[str, ...]]


def _axes_tuple(axes: Axes) -> Tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def psum_f32(x: jax.Array, axes: Axes) -> jax.Array:
    return jax.lax.psum(x.astype(jnp.float32), _axes_tuple(axes))


def _global_absmax(x: jax.Array, axes: Axes) -> jax.Array:
    m = jnp.max(jnp.abs(x))
    return jax.lax.pmax(m, _axes_tuple(axes))


def int8_psum(x: jax.Array, axes: Axes) -> jax.Array:
    """Quantize with a shared global scale, sum exactly in int32, dequantize.

    Deterministically identical on every shard (required for replicated
    parameter updates)."""
    x = x.astype(jnp.float32)
    scale = _global_absmax(x, axes) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s = jax.lax.psum(q.astype(jnp.int32), _axes_tuple(axes))
    return s.astype(jnp.float32) * scale


def ring_psum_int8(x: jax.Array, axis: str) -> jax.Array:
    """Ring all-reduce with an int8 wire format over one mesh axis.

    reduce-scatter phase: N-1 hops, each shard forwards a quantized chunk and
    accumulates in f32; all-gather phase: N-1 hops of the final quantized
    chunks.  Wire bytes: 2·(N-1)/N·size·1B vs 4B for f32 — a 4x collective-
    term reduction at the cost of int8 rounding noise per hop.
    """
    n = jax.lax.psum(1, axis)
    if n == 1:
        return x.astype(jnp.float32)
    idx = jax.lax.axis_index(axis)
    orig_shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)                     # [n, chunk]
    scale0 = jnp.maximum(_global_absmax(flat, axis) / 127.0, 1e-30)

    def q(v, s):
        return jnp.clip(jnp.round(v / s), -127, 127).astype(jnp.int8)

    perm = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: after n-1 hops, shard i owns the full sum of chunk
    # (i+1) mod n.
    def rs_body(carry, hop):
        acc = carry                                  # [n, chunk] f32 partial
        # send chunk (idx - hop) mod n's partial to the right neighbour
        send_idx = (idx - hop) % n
        payload = q(jnp.take(acc, send_idx, axis=0), scale0 * (hop + 1.0))
        got = jax.lax.ppermute(payload, axis, perm)
        recv_idx = (idx - hop - 1) % n
        upd = jnp.take(acc, recv_idx, axis=0) + \
            got.astype(jnp.float32) * (scale0 * (hop + 1.0))
        acc = jax.lax.dynamic_update_index_in_dim(acc, upd, recv_idx, 0)
        return acc, None

    acc, _ = jax.lax.scan(rs_body, chunks, jnp.arange(n - 1))
    own = (idx + 1) % n                              # fully-reduced chunk id
    scale_f = scale0 * n

    # all-gather of the reduced chunks (int8 wire)
    def ag_body(carry, hop):
        out, cur = carry                              # cur: int8 chunk in hand
        got = jax.lax.ppermute(cur, axis, perm)
        src = (own - hop - 1) % n                     # whose chunk arrived
        out = jax.lax.dynamic_update_index_in_dim(
            out, got.astype(jnp.float32) * scale_f, src, 0)
        return (out, got), None

    out0 = jnp.zeros_like(chunks)
    mine = jnp.take(acc, own, axis=0)
    out0 = jax.lax.dynamic_update_index_in_dim(out0, mine, own, 0)
    (out, _), _ = jax.lax.scan(
        ag_body, (out0, q(mine, scale_f)), jnp.arange(n - 1))
    return out.reshape(-1)[: flat.shape[0] - pad if pad else None] \
        .reshape(orig_shape) if pad else out.reshape(orig_shape)


def compressed_psum(g: jax.Array, axes: Axes,
                    mode: Optional[str] = None) -> jax.Array:
    axes_t = _axes_tuple(axes)
    if mode is None or g.ndim == 0 or g.size < 4096:
        return psum_f32(g, axes_t)
    if mode == "int8":
        return int8_psum(g, axes_t)
    if mode == "ring8":
        out = g.astype(jnp.float32)
        for ax in axes_t:
            out = ring_psum_int8(out, ax)
        return out
    raise ValueError(f"unknown grad-compression mode {mode!r}")
