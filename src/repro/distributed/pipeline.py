"""Pipeline-parallel execution: GPipe-style training step and the gLLM
serving tick, both as `shard_map` programs over the derived mesh.

Manual axes: `stage` (+ `data`, + `pod` when present) — activations move by
`lax.ppermute`, MoE tokens by `lax.all_to_all`, data-parallel gradient
reduction happens in the shard_map transpose.  The `tensor` axis stays
auto: GSPMD shards every matmul from the parameter shardings.

The serving tick is the SPMD expression of gLLM's asynchronous runtime: all
stages execute simultaneously on *different* micro-batches; per-tick token
counts are static buckets, so a pipeline bubble is exactly the padding that
Token Throttling minimizes (DESIGN.md §2).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.optimizer import AdamConfig, AdamState, adam_update
from repro.jax_compat import ensure_jax_compat
from repro.launch.mesh import manual_axes
from repro.models import serve as serve_lib
from repro.models import transformer as tfm
from repro.models.serve import ServeDims

ensure_jax_compat()   # this module calls jax.shard_map (modern surface)


# ----------------------------------------------------------------------------
# Spec plumbing
# ----------------------------------------------------------------------------

def _filter_entry(entry, keep: frozenset):
    if entry is None:
        return None
    if isinstance(entry, tuple):
        kept = tuple(a for a in entry if a in keep)
        return kept if len(kept) > 1 else (kept[0] if kept else None)
    return entry if entry in keep else None


def manual_spec(spec: P, manual: frozenset) -> P:
    """Strip auto axes from a PartitionSpec (shard_map in_specs may only name
    manual axes; the auto part flows from argument shardings)."""
    return P(*(_filter_entry(e, manual) for e in spec))


def remap_data_axis(spec: P, mesh: Mesh) -> P:
    """In multi-pod meshes, per-replica (serve) arrays shard over
    ('pod','data') wherever single-pod specs say 'data'."""
    if "pod" not in mesh.axis_names:
        return spec

    def f(e):
        if e == "data":
            return ("pod", "data")
        if isinstance(e, tuple) and "data" in e:
            return tuple(a for a in e if a != "data") + ("pod", "data")
        return e

    return P(*(f(e) for e in spec))


def tree_specs(tree_of_specs, mesh: Mesh, *, serve: bool = False):
    """(full NamedShardings for args, manual-only specs for shard_map)."""
    man = manual_axes(mesh)

    def full(s):
        s2 = remap_data_axis(s, mesh) if serve else s
        return NamedSharding(mesh, s2)

    def man_only(s):
        s2 = remap_data_axis(s, mesh) if serve else s
        return manual_spec(s2, man)

    is_spec = lambda x: isinstance(x, P)
    return (jax.tree.map(full, tree_of_specs, is_leaf=is_spec),
            jax.tree.map(man_only, tree_of_specs, is_leaf=is_spec))


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ----------------------------------------------------------------------------
# Training: GPipe schedule + loss + grads + Adam inside ONE shard_map
# ----------------------------------------------------------------------------
#
# The whole step is manual over {stage, data(, pod)} so every cross-device
# reduction is an *explicit* collective under our control:
#   * gradient syncs are f32 psums (mixed-precision correct, and it sidesteps
#     an XLA:CPU AllReducePromotion crash on bf16 shard_map-transpose psums);
#   * the loss is computed with the lm_head vocab-sharded over
#     (stage x tensor): the last stage's hidden is broadcast once in f32 and
#     every stage computes its vocab slice — no S-fold redundant head FLOPs;
#   * this is also where gradient compression hooks in (see
#     repro.distributed.collectives).

def _pipeline_scan(cfg: ArchConfig, weights, h_local, *, enc_width: int = 0):
    """Local GPipe schedule: h_local [M_loc, mb, T, d] -> (out, aux).

    Runs inside the manual region; `weights` leaves are local [R, ...]."""
    S = cfg.plan.pp
    perm = [(i, (i + 1) % S) for i in range(S)]
    M_loc = h_local.shape[0]
    stage = jax.lax.axis_index("stage")
    state = jnp.zeros_like(h_local[0])
    outbuf = jnp.zeros_like(h_local)

    def tick(carry, t):
        st, out, aux = carry
        inp = jax.lax.dynamic_index_in_dim(
            h_local, jnp.clip(t, 0, M_loc - 1), 0, keepdims=False)
        cur = jnp.where(stage == 0, inp, st)
        y, aux_s = tfm.stage_forward_train(cfg, weights, cur,
                                           enc_width=enc_width)
        oidx = jnp.clip(t - (S - 1), 0, M_loc - 1)
        write = (stage == S - 1) & (t >= S - 1)
        prev = jax.lax.dynamic_index_in_dim(out, oidx, 0, keepdims=False)
        out = jax.lax.dynamic_update_index_in_dim(
            out, jnp.where(write, y, prev), oidx, 0)
        real = (t >= stage) & (t < stage + M_loc)   # non-bubble ticks
        aux = aux + jnp.where(real, aux_s, 0.0)
        nxt = jax.lax.ppermute(y, "stage", perm) if S > 1 else y
        return (nxt, out, aux), None

    (_, outbuf, aux), _ = jax.lax.scan(
        tick, (state, outbuf, jnp.zeros((), jnp.float32)),
        jnp.arange(M_loc + S - 1))
    return outbuf, aux


def _sharded_loss(cfg: ArchConfig, params, hid, labels):
    """Cross-entropy with lm_head vocab-sharded over the manual `stage` axis
    (plus auto `tensor`).  hid [M_loc, mb, T, d] is valid on the LAST stage
    only; it is masked+psum-broadcast in f32, then each stage computes its
    vocab slice of the logits.  Returns (sum_nll, sum_mask) local f32."""
    S = cfg.plan.pp
    stage = jax.lax.axis_index("stage")
    fn = params["final_norm"]
    w = params["embed"]["tok"].T if cfg.tie_embeddings \
        else params["lm_head"]["w"]
    V_shard = w.shape[-1]                       # local (stage) vocab slice
    v_off = stage * V_shard

    def loss_mb(hl):
        h_m, lab = hl                           # [mb, T, d], [mb, T]
        if "b" in fn:
            from repro.models.layers import layernorm
            h_m = layernorm(h_m, fn["g"], fn["b"], cfg.norm_eps)
        else:
            from repro.models.layers import rmsnorm
            h_m = rmsnorm(h_m, fn["g"], cfg.norm_eps)
        h32 = jnp.where(stage == S - 1, h_m, 0).astype(jnp.float32)
        h32 = jax.lax.psum(h32, "stage") if S > 1 else h32   # bcast (f32)
        logits = (h32.astype(w.dtype) @ w).astype(jnp.float32)  # [mb,T,Vs]
        m_loc = jnp.max(jax.lax.stop_gradient(logits), axis=-1)
        m = jax.lax.pmax(m_loc, "stage") if S > 1 else m_loc
        m = jax.lax.stop_gradient(m)   # stability shift only; lse grad exact
        se = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)
        se = jax.lax.psum(se, "stage") if S > 1 else se
        lse = m + jnp.log(se)
        lab_c = jnp.maximum(lab, 0)
        in_shard = (lab_c >= v_off) & (lab_c < v_off + V_shard)
        gold_loc = jnp.take_along_axis(
            logits, jnp.clip(lab_c - v_off, 0, V_shard - 1)[..., None],
            axis=-1)[..., 0]
        gold = jnp.where(in_shard, gold_loc, 0.0)
        gold = jax.lax.psum(gold, "stage") if S > 1 else gold
        mask = (lab >= 0).astype(jnp.float32)
        nll = (lse - gold) * mask
        return jnp.sum(nll), jnp.sum(mask)

    def scan_body(carry, hl):
        n, c = jax.checkpoint(loss_mb)(hl)
        return (carry[0] + n, carry[1] + c), None

    (nll, cnt), _ = jax.lax.scan(
        scan_body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hid, labels))
    return nll, cnt


def _grad_sync_axes(spec: P, man: frozenset) -> Tuple[str, ...]:
    """A gradient must be psum'd over every manual axis its parameter does
    NOT shard (i.e. axes over which the parameter is replicated)."""
    used = set()
    for e in spec:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None:
                used.add(a)
    return tuple(sorted(man - used))


def build_train_step(cfg: ArchConfig, mesh: Mesh, *,
                     adam: AdamConfig = AdamConfig(),
                     aux_coef: float = 0.01,
                     enc_width: int = 0,
                     grad_compression: Optional[str] = None):
    """Returns step_fn(params, opt_state, batch) -> (params, opt, metrics).

    batch = {tokens [M, mbg, T] int32 (M over `pod`, mbg over `data`),
    labels [M, mbg, T] int32 (-100 = masked), optional
    "embeds" [M, mbg, Tv, d] — the vlm/audio frontend-stub rows}.
    """
    from repro.distributed.collectives import compressed_psum

    man = manual_axes(mesh)
    has_pod = "pod" in mesh.axis_names
    pspecs = tfm.param_pspecs(cfg)
    _, p_man = tree_specs(pspecs, mesh)
    opt_man = AdamState(step=P(), m=p_man, v=p_man)
    tok_spec = P("pod", "data", None) if has_pod else P(None, "data", None)
    emb_spec = P(*(tuple(tok_spec) + (None,)))

    def _make_body(has_embeds: bool):
        def body(params, opt_state, tokens, labels, *rest):
            embeds = rest[0] if has_embeds else None

            def loss_fn(params):
                stages_w = jax.tree.map(lambda a: a[0], params["stages"])
                h = jnp.take(params["embed"]["tok"], tokens, axis=0)
                if embeds is not None:
                    Tv = embeds.shape[2]
                    h = jnp.concatenate([embeds.astype(h.dtype),
                                         h[:, :, Tv:]], axis=2)
                hid, aux = _pipeline_scan(cfg, stages_w, h,
                                          enc_width=enc_width)
                nll, cnt = _sharded_loss(cfg, params, hid, labels)
                dp = tuple(a for a in ("pod", "data") if a in man)
                if dp:
                    nll = jax.lax.psum(nll, dp)
                    cnt = jax.lax.psum(cnt, dp)
                    aux = jax.lax.psum(
                        aux, dp + (("stage",) if cfg.plan.pp > 1 else ()))
                    aux = aux / jax.lax.psum(1, dp)
                loss = nll / jnp.maximum(cnt, 1.0)
                return loss + aux_coef * aux, (loss, aux)

            (total, (loss, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)

            # explicit f32 gradient sync over replicated axes
            def sync(spec, g):
                axes = _grad_sync_axes(spec, man)
                if not axes:
                    return g.astype(jnp.float32)
                return compressed_psum(g, axes, mode=grad_compression)

            grads = jax.tree.map(sync, pspecs, grads,
                                 is_leaf=lambda x: isinstance(x, P))

            # global grad norm: shard-local squares psum'd over the axes that
            # shard each leaf (replicated leaves contribute once)
            def leaf_sq(spec, g):
                used = set()
                for e in spec:
                    for a in (e if isinstance(e, tuple) else (e,)):
                        if a in man:
                            used.add(a)
                s = jnp.sum(jnp.square(g.astype(jnp.float32)))
                return jax.lax.psum(s, tuple(sorted(used))) if used else s

            gsq = sum(jax.tree.leaves(jax.tree.map(
                leaf_sq, pspecs, grads, is_leaf=lambda x: isinstance(x, P))))
            gnorm = jnp.sqrt(gsq)
            new_params, new_opt, _ = adam_update(adam, grads, params,
                                                 opt_state, gnorm=gnorm)
            metrics = {"loss": loss, "aux": aux, "total": total,
                       "gnorm": gnorm}
            return new_params, new_opt, metrics

        extra = (emb_spec,) if has_embeds else ()
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(p_man, opt_man, tok_spec, tok_spec) + extra,
            out_specs=(p_man, opt_man, {k: P() for k in
                                        ("loss", "aux", "total", "gnorm")}),
            axis_names=man, check_vma=False)

    fns = {}

    def step(params, opt_state, batch):
        has_embeds = "embeds" in batch
        if has_embeds not in fns:
            fns[has_embeds] = _make_body(has_embeds)
        args = (params, opt_state, batch["tokens"], batch["labels"])
        if has_embeds:
            args += (batch["embeds"],)
        return fns[has_embeds](*args)

    return step


# ----------------------------------------------------------------------------
# Serving: one pipeline tick inside shard_map
# ----------------------------------------------------------------------------

def build_serve_tick(cfg: ArchConfig, mesh: Mesh, dims: ServeDims,
                     *, unroll: Optional[bool] = None,
                     carry_dims: Optional[ServeDims] = None):
    """Returns (tick_fn, specs) where

    tick_fn(params, caches, carry, meta, fresh) ->
        (new_carry, new_caches, tokens, sample_hidden)

    carry  = {"xp": [S, DSp, W, d], "xd": [S, DSd, 1, d]}
    fresh  = {"xp": [DSp, W, d], "xd": [DSd, 1, d]}  (stage-0 inputs, embedded)
    meta   = stage-stacked ServeMeta dict
    tokens = [D*(Sp+Sd)] int32 sampled ids (greedy), -1 for padding rows

    **Bucketed programs.**  When `carry_dims` is given (the FULL ladder dims,
    `dims` being a smaller bucket from `bucket_ladder`), the tick accepts and
    returns the full-shape carry but computes only the bucket region: the
    carry is sliced to `[:dims.Sp, :dims.prefill_width]` / `[:dims.Sd]`
    inside the manual region, and the permuted result is written back into
    the same slice, leaving the (never-read) out-of-bucket region untouched.
    Caches, params, and carry buffers are therefore shared — byte-compatible
    and donation-compatible — across every program in the ladder; meta and
    fresh arrive already at bucket shape.
    """
    import os
    if unroll is None:
        unroll = os.environ.get("REPRO_SERVE_UNROLL", "1") not in ("0", "")
    S = cfg.plan.pp
    man = manual_axes(mesh)
    perm = [(i, (i + 1) % S) for i in range(S)]
    Sp, Sd, W = dims.Sp, dims.Sd, dims.prefill_width
    full = carry_dims or dims
    sliced = (full.Sp, full.prefill_width, full.Sd) != (Sp, W, Sd)

    def body(stage_params, caches, xp, xd, meta, fresh_xp, fresh_xd):
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        caches = jax.tree.map(lambda a: a[0], caches)
        meta = {k: v[0] for k, v in meta.items()}
        xp_full, xd_full = xp[0], xd[0]
        if sliced:
            xp = xp_full[:Sp, :W]
            xd = xd_full[:Sd]
        else:
            xp, xd = xp_full, xd_full
        stage = jax.lax.axis_index("stage")

        if Sp:
            xp = jnp.where(stage == 0, fresh_xp, xp)
        if Sd:
            xd = jnp.where(stage == 0, fresh_xd, xd)

        xp2, xd2, new_caches = serve_lib.stage_forward_serve(
            cfg, stage_params, caches, xp, xd, meta, dims, unroll=unroll)

        # rows whose logits sample a token (outside, on the last stage's out)
        samples = []
        if Sp:
            idx = dims.Te + jnp.maximum(meta["p_chunk_lens"] - 1, 0)
            samples.append(jnp.take_along_axis(
                xp2, idx[:, None, None], axis=1)[:, 0, :])
        if Sd:
            samples.append(xd2[:, 0, :])
        sample_h = jnp.concatenate(samples, axis=0) if len(samples) > 1 \
            else samples[0]

        xp_next = jax.lax.ppermute(xp2, "stage", perm) if Sp else xp2
        xd_next = jax.lax.ppermute(xd2, "stage", perm) if Sd else xd2
        if sliced:
            xp_next = xp_full.at[:Sp, :W].set(xp_next) if Sp else xp_full
            xd_next = xd_full.at[:Sd].set(xd_next) if Sd else xd_full
        return (xp_next[None], xd_next[None],
                jax.tree.map(lambda a: a[None], new_caches),
                sample_h[None])

    # ---- specs.  Weights replicate across pods (EP stays intra-pod); all
    # per-replica runtime state (caches/carries/meta) shards over pod+data.
    pspecs = tfm.param_pspecs(cfg)
    cspecs = serve_lib.cache_pspecs(cfg, dims)
    mspecs = serve_lib.meta_pspecs(dims)
    carry_spec = P("stage", "data", None, None)
    fresh_spec = P("data", None, None)

    w_full, w_man = tree_specs(pspecs["stages"], mesh, serve=False)
    c_full, c_man = tree_specs(cspecs, mesh, serve=True)
    m_full, m_man = tree_specs(mspecs, mesh, serve=True)
    carry_full, carry_man = tree_specs(carry_spec, mesh, serve=True)
    fresh_full, fresh_man = tree_specs(fresh_spec, mesh, serve=True)
    sample_spec = manual_spec(remap_data_axis(P("stage", "data", None), mesh),
                              man)

    inner = jax.shard_map(
        body, mesh=mesh,
        in_specs=(w_man, c_man, carry_man, carry_man, m_man,
                  fresh_man, fresh_man),
        out_specs=(carry_man, carry_man, c_man, sample_spec),
        axis_names=man, check_vma=False)

    def tick(params, caches, carry, meta, fresh, sampling=None):
        """sampling (optional): {"temps": [rows] f32 (0 => greedy),
        "seed": uint32 scalar} — per-request temperature sampling for the
        micro-batch exiting this tick."""
        xp_n, xd_n, caches_n, sample = inner(
            params["stages"], caches, carry["xp"], carry["xd"], meta,
            fresh["xp"], fresh["xd"])
        h_last = sample[-1]                       # [D*(Sp+Sd), d]
        logits = tfm.head_apply(cfg, params, h_last).astype(jnp.float32)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if sampling is not None:
            temps = sampling["temps"].astype(jnp.float32)
            key = jax.random.key(sampling["seed"])
            scaled = logits / jnp.maximum(temps, 1e-3)[:, None]
            drawn = jax.random.categorical(key, scaled, axis=-1) \
                .astype(jnp.int32)
            tokens = jnp.where(temps > 0.0, drawn, greedy)
        else:
            tokens = greedy
        logprobs = jax.nn.log_softmax(logits, axis=-1)
        top = jnp.max(logprobs, axis=-1)
        return ({"xp": xp_n, "xd": xd_n}, caches_n, tokens, top)

    specs = {
        "params_stages": (w_full, w_man),
        "caches": (c_full, c_man),
        "meta": (m_full, m_man),
        "carry": (carry_full, carry_man),
        "fresh": (fresh_full, fresh_man),
    }
    return tick, specs
