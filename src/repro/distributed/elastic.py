"""Elastic re-sharding: move a checkpoint between pipeline factorings.

Parameters are stored logically (stacked [S, R, ...] per block group).  When
the pipeline grid changes (e.g. a pod shrinks from pp=16/tp=1 to pp=8/tp=2
after losing a rack), uniform-pattern architectures repartition by a pure
reshape [S*R, ...] -> [S', R', ...]; heterogeneous patterns (jamba, whisper)
keep their stage structure and only the tp factor may change (weights are
not physically tp-sharded in the checkpoint, so that is free).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import numpy as np

from repro.configs.base import ArchConfig, BlockSpec, ParallelPlan


def replan(cfg: ArchConfig, new_pp: int, new_tp: int) -> ArchConfig:
    """A config with the same architecture on a different (pp, tp) grid."""
    if len(cfg.pattern) == 1:
        total = cfg.layers_per_stage * cfg.plan.pp
        if total % new_pp:
            raise ValueError(f"{total} stacked layers don't tile pp={new_pp}")
        pattern = (BlockSpec(cfg.pattern[0].kind, total // new_pp),)
    else:
        if new_pp != cfg.plan.pp:
            raise ValueError(
                f"{cfg.name}: heterogeneous pattern is pinned to pp={cfg.plan.pp}")
        pattern = cfg.pattern
    return dataclasses.replace(
        cfg, pattern=pattern,
        plan=dataclasses.replace(cfg.plan, pp=new_pp, tp=new_tp))


def repartition_params(params: Dict[str, Any], cfg_old: ArchConfig,
                       cfg_new: ArchConfig):
    """Reshape stacked stage dims [S,R,...] -> [S',R',...] (host-side)."""
    if cfg_old.pattern != cfg_new.pattern or cfg_old.plan.pp != cfg_new.plan.pp:
        def reshape(x):
            x = np.asarray(x)
            s, r = x.shape[:2]
            total = s * r
            s2 = cfg_new.plan.pp
            assert total % s2 == 0, (total, s2)
            return x.reshape((s2, total // s2) + x.shape[2:])

        stages = {k: jax.tree.map(reshape, v)
                  for k, v in params["stages"].items()}
        params = dict(params, stages=stages)
    return params


def elastic_restore(ckpt_dir: str, cfg_old: ArchConfig, cfg_new: ArchConfig,
                    mesh_new, dtype=None):
    """Load a checkpoint saved under cfg_old onto cfg_new's mesh."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.models import transformer as tfm
    from repro.runtime.checkpoint import restore_checkpoint

    host = restore_checkpoint(ckpt_dir, tfm.abstract_params(cfg_old))
    host = repartition_params(host, cfg_old, cfg_new)
    pspecs = tfm.param_pspecs(cfg_new)
    return jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a),
                                    NamedSharding(mesh_new, s)),
        host, pspecs, is_leaf=lambda x: isinstance(x, P))
