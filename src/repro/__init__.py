"""gLLM reproduction: globally-balanced pipeline-parallel LLM serving.

Importing the package normalizes the JAX API surface across the versions we
deploy on (see jax_compat.py) so the runtime, tests, and examples can use
the modern spelling everywhere.  The shim only fires when jax is already
loaded — jax-free paths (scheduler, simulator, benchmarks) stay jax-free;
the jax-using modules (distributed/pipeline.py, launch/mesh.py) install it
themselves.
"""

import sys

from repro.jax_compat import ensure_jax_compat

if "jax" in sys.modules:
    ensure_jax_compat()
