"""Version compatibility for the JAX surface this repo is written against.

The runtime targets the post-0.6 "explicit sharding" API surface
(`jax.set_mesh`, `jax.sharding.AxisType`, `jax.make_mesh(axis_types=...)`,
`jax.shard_map`).  Some deployment containers pin an older jax (0.4.x) where
those names are missing but the underlying machinery
(`jax.experimental.shard_map`, mesh context managers) exists and — as the
engine-equivalence suite verifies — is numerically identical for our
programs.

`ensure_jax_compat()` installs forward-compatible aliases onto the jax
module when (and only when) they are missing, so every call site keeps using
the modern spelling.  It is invoked from ``repro/__init__.py`` — importing
anything under `repro` makes the surface uniform.  On a current jax it is a
no-op.
"""

from __future__ import annotations

import enum
import inspect

_installed = False
_shimmed: list = []


def is_shimmed() -> bool:
    """True when any alias was installed — i.e. the underlying jax predates
    the surface this repo targets.  Tests that need *native* newer-jax
    machinery (e.g. partial-auto shard_map lowering, which old XLA's SPMD
    partitioner rejects with 'PartitionId unsupported') gate on this."""
    ensure_jax_compat()
    return bool(_shimmed)


def ensure_jax_compat() -> None:
    global _installed
    if _installed:
        return
    _installed = True
    import jax

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType
        _shimmed.append("AxisType")

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        def make_mesh(axis_shapes, axis_names, *, axis_types=None,
                      devices=None):
            # old jax has no axis-type annotations; Auto axes are simply
            # "not named in shard_map", which the shard_map alias handles
            return _make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh
        _shimmed.append("make_mesh")

    if not hasattr(jax, "set_mesh"):
        # Mesh is itself a context manager establishing the active mesh
        jax.set_mesh = lambda mesh: mesh
        _shimmed.append("set_mesh")

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                      axis_names=None, check_vma=None, **kw):
            # new API: `axis_names` = manual axes; old API: everything
            # manual except `auto`.  `check_vma` replaced `check_rep`.
            auto = frozenset(mesh.axis_names) - frozenset(
                axis_names if axis_names is not None else mesh.axis_names)
            # a size-1 auto axis partitions nothing: treat it as manual —
            # old XLA's partial-auto SPMD path chokes on PartitionId, and
            # fully-manual lowering is semantically identical here
            auto = frozenset(a for a in auto if mesh.shape[a] > 1)
            check = bool(check_vma) if check_vma is not None else True
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check,
                              auto=auto)

        jax.shard_map = shard_map
        _shimmed.append("shard_map")
