"""Serving launcher: build a gLLM engine (or a multi-replica router) for any
--arch and serve a synthetic workload, reporting the paper's metrics.

On this CPU container, --reduced (default) builds the same-family reduced
config so the engine actually executes; on a real TPU slice, --full uses the
published config on the production mesh factoring from the arch's plan.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 12 --rate 4 [--policy gllm|sarathi|no_wt|no_ut] \
        [--replicas 2 --route balanced|rr] \
        [--rebalance-interval 0.25 [--migrate]]

With --replicas N, N data-parallel engine replicas (sharing one read-only
parameter tree) are fronted by a `ReplicaRouter` that places each request by
global balance score (DESIGN.md §1.3).  --rebalance-interval turns on the
periodic control plane (steal waiting requests off saturated replicas);
--migrate additionally allows live migration of running decode requests —
KV pages move across replicas with no recompute (DESIGN.md §9).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np


def build_engine(arch: str, *, reduced: bool = True, policy: str = "gllm",
                 seed: int = 0, replicas: int = 1, route: str = "balanced",
                 rebalance_interval: float = None, migrate: bool = False,
                 trace_out: str = None):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config, make_reduced
    from repro.core import PrefillPolicy, ThrottleConfig
    from repro.launch.mesh import derive_pipeline_mesh, make_production_mesh
    from repro.launch.shapes import serve_cell_dims
    from repro.configs.base import ASSIGNED_SHAPES
    from repro.models import transformer as tfm
    from repro.models.serve import ServeDims
    from repro.runtime.engine import PipelineEngine
    from repro.runtime.router import RebalancePolicy, ReplicaRouter

    cfg = get_config(arch)
    if reduced:
        cfg = make_reduced(cfg).with_plan(pp=1, tp=1, ep_over_data=False)
        cfg = dataclasses.replace(
            cfg, dtype="float32",
            moe_capacity_factor=float(max(cfg.num_experts, 1)))
        mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
        dims = ServeDims(Sp=1, C=32, Sd=8, pages=512, page=8, Bp=64, Bd=64,
                         slots=16, Te=16 if cfg.is_encoder_decoder else 0)
        th = ThrottleConfig(num_iters_T=4, max_prefill_tokens=32,
                            min_prefill_tokens=4, pipeline_depth=1,
                            policy=PrefillPolicy(policy))
    else:
        prod = make_production_mesh()
        mesh = derive_pipeline_mesh(prod, cfg.plan.pp, cfg.plan.tp)
        dims = serve_cell_dims(cfg, ASSIGNED_SHAPES["prefill_32k"],
                               data=mesh.shape["data"])
        th = ThrottleConfig(pipeline_depth=cfg.plan.pp,
                            policy=PrefillPolicy(policy))
    with jax.set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.key(seed),
                                 dtype=jnp.dtype(cfg.dtype))
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, tfm.param_pspecs(cfg),
            is_leaf=lambda x: isinstance(x, P))
        # replicas share the (read-only) parameter tree; each owns its KV
        # pool, caches, scheduler, and TickLoop
        n = max(replicas, 1)

        def _tp(i):
            if trace_out is None:
                return None
            return trace_out if n == 1 else f"{trace_out}.replica{i}"

        engines = [PipelineEngine(cfg, dims, params, mesh, th,
                                  trace_path=_tp(i)) for i in range(n)]
    if len(engines) == 1:
        return cfg, engines[0]
    router_trace = None if trace_out is None else f"{trace_out}.router"
    rebalance = None
    if rebalance_interval is not None:
        rebalance = RebalancePolicy(interval=rebalance_interval,
                                    migrate=migrate)
    return cfg, ReplicaRouter(engines, policy=route, rebalance=rebalance,
                              trace_path=router_trace)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--policy", default="gllm",
                    choices=["gllm", "sarathi", "no_wt", "no_ut"])
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind the router")
    ap.add_argument("--route", default="balanced", choices=["balanced", "rr"],
                    help="request placement policy across replicas")
    ap.add_argument("--rebalance-interval", type=float, default=None,
                    metavar="SECONDS",
                    help="run the periodic control plane: steal waiting "
                    "requests off saturated replicas every SECONDS")
    ap.add_argument("--migrate", action="store_true",
                    help="with --rebalance-interval: also live-migrate "
                    "running decode requests (KV moves, no recompute)")
    ap.add_argument("--full", action="store_true",
                    help="published config on the production mesh (TPU)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record a replayable tick trace of the run "
                    "(per-replica PATH.replicaN + PATH.router when N>1)")
    ap.add_argument("--trace-replay", default=None, metavar="PATH",
                    help="strict-replay a recorded trace through the "
                    "scheduler instead of serving (no accelerator needed)")
    args = ap.parse_args()

    if args.trace_replay is not None:
        # replay needs only the scheduler + the recorded events — it never
        # builds the model, so it runs on any box
        from repro.runtime.trace import Trace, replay_trace
        report = replay_trace(Trace.load(args.trace_replay))
        print(f"[replay {args.trace_replay}] {report.summary()} — "
              f"decisions match the recording")
        return

    from repro.core import SamplingParams
    from repro.runtime.router import ReplicaRouter

    cfg, engine = build_engine(args.arch, reduced=not args.full,
                               policy=args.policy, replicas=args.replicas,
                               route=args.route,
                               rebalance_interval=args.rebalance_interval,
                               migrate=args.migrate,
                               trace_out=args.trace_out)
    replicas = engine.replicas if isinstance(engine, ReplicaRouter) \
        else [engine]
    rng = np.random.default_rng(0)
    t0 = time.time()
    reqs = []
    for _ in range(args.requests):
        n = int(np.clip(rng.lognormal(3.0, 0.8), 4, 300))
        enc = None
        if cfg.is_encoder_decoder:
            enc = rng.normal(size=(replicas[0].dims.Te, cfg.d_model)) \
                .astype(np.float32) * 0.05
        reqs.append(engine.add_request(
            list(rng.integers(0, cfg.vocab_size, n)),
            SamplingParams(max_new_tokens=args.max_new), enc_embeds=enc))
    engine.drain()
    wall = time.time() - t0
    toks = sum(r.num_output_tokens for r in reqs)
    ttfts = [r.metrics.ttft() for r in reqs if r.metrics.ttft() is not None]
    ticks = sum(e.stats.ticks for e in replicas)
    preempt = sum(e.scheduler.stats.preemptions for e in replicas)
    pad = sum(e.stats.padded_prefill for e in replicas) / max(
        1, sum(e.stats.ticks * max(e.dims.Sp, 1) * max(e.dims.C, 1)
               for e in replicas))
    routed = ""
    if isinstance(engine, ReplicaRouter):
        routed = (f" routed={'/'.join(map(str, engine.routed_counts))}"
                  f" ({engine.policy.value})")
        if engine.rebalance_policy is not None:
            rs = engine.rebalance_stats
            routed += (f" rebalance[stolen={rs.stolen} "
                       f"migrated={rs.migrated}]")
    print(f"[{args.arch} | {args.policy}] {len(reqs)} requests, {toks} tokens "
          f"in {wall:.1f}s; ticks={ticks} "
          f"TTFT_mean={np.mean(ttfts)*1e3:.0f}ms "
          f"preemptions={preempt} "
          f"prefill-bucket padding={pad:.1%}{routed}")
    if args.trace_out is not None:
        if isinstance(engine, ReplicaRouter):
            engine.close_trace()
        for e in replicas:
            e.recorder.close()


if __name__ == "__main__":
    main()
