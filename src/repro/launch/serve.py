"""Serving launcher: a thin flag->`ServeSpec` translation over the public
serving API (`repro.serving`, DESIGN.md §10).

On this CPU container, --reduced (default) builds the same-family reduced
config so the engine actually executes; on a real TPU slice, --full uses the
published config on the production mesh factoring from the arch's plan.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 12 --rate 4 [--policy gllm|sarathi|no_wt|no_ut] \
        [--replicas 2 --route balanced|rr] \
        [--rebalance-interval 0.25 [--migrate]] \
        [--http 8000]

Every flag combination is exactly one `ServeSpec`: --dump-spec prints that
spec as JSON and exits, --spec FILE serves from a previously dumped spec
(flags other than the workload ones are ignored).  With --replicas N, N
data-parallel engine replicas (sharing one read-only parameter tree) are
fronted by a `ReplicaRouter`; --rebalance-interval turns on the periodic
control plane and --migrate allows live KV migration (DESIGN.md §9).

With --http PORT the launcher becomes the real frontend process: instead of
running the synthetic workload it serves the spec over HTTP
(`repro.serving.http`, DESIGN.md §11) until interrupted — generate,
streaming SSE, abort, and stats; see docs/quickstart.md for the curl
vocabulary.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def _spec(*, arch: str, reduced: bool, policy: str, seed: int, replicas: int,
          route: str, rebalance_interval: float, migrate: bool,
          trace_out: str):
    from repro.serving import (ClusterSpec, EngineSpec, RebalancePolicy,
                               ServeSpec, TraceSpec)
    cluster = None
    if replicas > 1 or rebalance_interval is not None:
        rebalance = None
        if rebalance_interval is not None:
            rebalance = RebalancePolicy(interval=rebalance_interval,
                                        migrate=migrate)
        cluster = ClusterSpec(replicas=max(replicas, 1), route=route,
                              rebalance=rebalance)
    return ServeSpec(
        backend="engine",
        engine=EngineSpec(arch=arch, reduced=reduced, policy=policy,
                          seed=seed),
        cluster=cluster,
        trace=TraceSpec(record=trace_out) if trace_out is not None else None,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--policy", default="gllm",
                    choices=["gllm", "sarathi", "no_wt", "no_ut"])
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind the router")
    ap.add_argument("--route", default="balanced", choices=["balanced", "rr"],
                    help="request placement policy across replicas")
    ap.add_argument("--rebalance-interval", type=float, default=None,
                    metavar="SECONDS",
                    help="run the periodic control plane: steal waiting "
                    "requests off saturated replicas every SECONDS")
    ap.add_argument("--migrate", action="store_true",
                    help="with --rebalance-interval: also live-migrate "
                    "running decode requests (KV moves, no recompute)")
    ap.add_argument("--full", action="store_true",
                    help="published config on the production mesh (TPU)")
    ap.add_argument("--spec", default=None, metavar="FILE",
                    help="serve from a ServeSpec JSON file instead of the "
                    "engine/cluster flags above")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the ServeSpec these flags translate to "
                    "(JSON) and exit")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record a replayable tick trace of the run "
                    "(per-replica PATH.replicaN + PATH.router when N>1)")
    ap.add_argument("--trace-replay", default=None, metavar="PATH",
                    help="strict-replay a recorded trace through the "
                    "scheduler instead of serving (no accelerator needed)")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve the spec over HTTP on PORT (0 = ephemeral) "
                    "instead of running the synthetic workload")
    args = ap.parse_args()

    from repro.serving import SamplingParams, ServeSpec, TraceSpec, build

    if args.trace_replay is not None:
        # replay needs only the scheduler + the recorded events — it never
        # builds the model, so it runs on any box
        server = build(ServeSpec(backend="trace",
                                 trace=TraceSpec(replay=args.trace_replay)))
        server.replay()
        print(f"[replay {args.trace_replay}] {server.last_report.summary()} "
              f"— decisions match the recording")
        return

    if args.spec is not None:
        with open(args.spec) as fh:
            spec = ServeSpec.from_json(fh.read())
    else:
        spec = _spec(arch=args.arch, reduced=not args.full,
                     policy=args.policy, seed=0, replicas=args.replicas,
                     route=args.route,
                     rebalance_interval=args.rebalance_interval,
                     migrate=args.migrate, trace_out=args.trace_out)
    if args.dump_spec:
        print(spec.to_json(indent=2))
        return

    if args.http is not None:
        from repro.serving.http import HTTPFrontend
        frontend = HTTPFrontend(build(spec), port=args.http)
        print(f"[{spec.engine.arch} | {spec.backend}] serving on "
              f"{frontend.url} — POST /v1/generate[?stream=1], "
              f"DELETE /v1/requests/{{rid}}, GET /v1/stats  (Ctrl-C stops)")
        try:
            frontend.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            frontend.shutdown()
        return

    server = build(spec)
    cfg = server.cfg
    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = []
    for _ in range(args.requests):
        n = int(np.clip(rng.lognormal(3.0, 0.8), 4, 300))
        kw = {}
        if cfg.is_encoder_decoder:
            kw["enc_embeds"] = rng.normal(
                size=(server.replicas[0].dims.Te, cfg.d_model)
            ).astype(np.float32) * 0.05
        rids.append(server.submit(
            list(rng.integers(0, cfg.vocab_size, n)),
            SamplingParams(max_new_tokens=args.max_new), **kw))
    server.drain()
    wall = time.time() - t0
    outs = server.outputs(rids)
    stats = server.stats()
    toks = sum(len(o.token_ids) for o in outs)
    ttfts = [o.metrics.ttft() for o in outs if o.metrics.ttft() is not None]
    ticks = sum(r.ticks for r in stats.replicas)
    preempt = sum(r.preemptions for r in stats.replicas)
    pad = 0.0
    if spec.backend == "engine":    # bucket padding is an engine-only stat
        pad = sum(e.stats.padded_prefill for e in server.replicas) / max(
            1, sum(e.stats.ticks * max(e.dims.Sp, 1) * max(e.dims.C, 1)
                   for e in server.replicas))
    routed = ""
    if stats.routed_counts is not None:
        routed = (f" routed={'/'.join(map(str, stats.routed_counts))}"
                  f" ({server.router.policy.value})")
        if stats.rebalance is not None:
            routed += (f" rebalance[stolen={stats.rebalance.stolen} "
                       f"migrated={stats.rebalance.migrated}]")
    arch = spec.engine.arch
    print(f"[{arch} | {spec.engine.policy}] {len(outs)} requests, "
          f"{toks} tokens in {wall:.1f}s; ticks={ticks} "
          f"TTFT_mean={np.mean(ttfts)*1e3:.0f}ms "
          f"preemptions={preempt} "
          f"prefill-bucket padding={pad:.1%}{routed}")
    server.close()


if __name__ == "__main__":
    main()
