"""Cell geometry: maps each assigned (architecture x input-shape) pair to the
static tick/batch layout it is lowered with, plus abstract `input_specs()`
(ShapeDtypeStruct stand-ins — weak-type-correct, shardable, zero allocation)
for the multi-pod dry-run.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.serve import ServeDims

PAGE_SIZE = 16
PAGES_PER_BLOCK = 8


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _round_mult(x: int, m: int) -> int:
    return _ceil(x, m) * m


# ----------------------------------------------------------------------------
# Serving cells
# ----------------------------------------------------------------------------

def serve_cell_dims(cfg: ArchConfig, shape: ShapeSpec, data: int = 16,
                    *, max_prefill: int = 2048) -> ServeDims:
    """Static per-replica tick geometry for a serving cell."""
    pp = cfg.plan.pp
    page = PAGE_SIZE
    Te = 1536 if cfg.is_encoder_decoder else 0
    pages_per_seq = _round_mult(_ceil(shape.seq_len, page), PAGES_PER_BLOCK)
    uses_pages = cfg.family not in ("ssm",)

    if shape.kind == "prefill":
        seqs_rep = max(1, _ceil(shape.global_batch, data))
        Sp, C = 1, max_prefill
        Sd = 8                                # decode rows forming behind prefill
        pool = seqs_rep * pages_per_seq + 16 * PAGES_PER_BLOCK if uses_pages else 8
        return ServeDims(Sp=Sp, C=C, Sd=Sd, pages=pool, page=page,
                         Bp=pages_per_seq, Bd=pages_per_seq,
                         slots=max(8, seqs_rep + Sd), Te=Te)

    # decode cells
    seqs_rep = max(1, _ceil(shape.global_batch, data))
    seq_shard = cfg.plan.seq_shard_kv and shape.global_batch < data \
        and cfg.family != "ssm"
    if seq_shard:
        # sequence-sharded KV: each replica holds an interleaved 1/data slice
        local_pages = _round_mult(_ceil(shape.seq_len, page * data),
                                  PAGES_PER_BLOCK)
        pool = local_pages + 2 * PAGES_PER_BLOCK
        Bd = local_pages
    else:
        pool = seqs_rep * pages_per_seq + 2 * PAGES_PER_BLOCK if uses_pages else 8
        Bd = pages_per_seq if uses_pages else 8
    Sd = max(1, _ceil(seqs_rep, pp))
    return ServeDims(Sp=0, C=0, Sd=Sd, pages=pool if uses_pages else 8,
                     page=page, Bp=8, Bd=Bd,
                     slots=max(1, seqs_rep), Te=Te, seq_shard=seq_shard)


# ----------------------------------------------------------------------------
# Training cells
# ----------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainDims:
    M: int               # total micro-batches (sharded over pod)
    mbg: int             # sequences per micro-batch (sharded over data)
    T: int
    enc_width: int = 0   # whisper payload split
    stub_width: int = 0  # frontend-stub embedding rows (vlm/audio)


def train_cell_dims(cfg: ArchConfig, shape: ShapeSpec, data: int = 16,
                    pods: int = 1) -> TrainDims:
    B, T = shape.global_batch, shape.seq_len
    mbg = data                                   # 1 sequence per replica per mb
    M = B // (mbg * 1)
    enc_width = T // 2 if cfg.is_encoder_decoder else 0
    stub = 0
    if cfg.family == "vlm":
        stub = 256
    elif cfg.family == "audio":
        stub = enc_width                          # precomputed frame embeddings
    return TrainDims(M=M, mbg=mbg, T=T, enc_width=enc_width, stub_width=stub)


# ----------------------------------------------------------------------------
# Abstract inputs (dry-run)
# ----------------------------------------------------------------------------

def _sds(shape, dtype, mesh: Optional[Mesh] = None, spec: Optional[P] = None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec or P()))


def train_batch_specs(cfg: ArchConfig, dims: TrainDims, mesh: Mesh):
    has_pod = "pod" in mesh.axis_names
    bspec = P("pod", "data", None) if has_pod else P(None, "data", None)
    espec = P(*(tuple(bspec) + (None,)))
    batch: Dict[str, Any] = {
        "tokens": _sds((dims.M, dims.mbg, dims.T), jnp.int32, mesh, bspec),
        "labels": _sds((dims.M, dims.mbg, dims.T), jnp.int32, mesh, bspec),
    }
    if dims.stub_width:
        batch["embeds"] = _sds((dims.M, dims.mbg, dims.stub_width, cfg.d_model),
                               jnp.dtype(cfg.dtype), mesh, espec)
    return batch


def serve_input_specs(cfg: ArchConfig, dims: ServeDims, mesh: Mesh,
                      specs: Dict[str, Tuple[Any, Any]]):
    """Abstract (caches, carry, meta, fresh) for the serve tick."""
    from repro.models import serve as serve_lib

    S = cfg.plan.pp
    repl = mesh.shape["data"] * mesh.shape.get("pod", 1)
    dt = jnp.dtype(cfg.dtype)
    defs = serve_lib.cache_defs(cfg, dims)
    shards = specs["caches"][0]
    caches = {
        gk: {name: jax.ShapeDtypeStruct(
                _scale_replica(leaf[0], shards[gk][name], repl),
                serve_lib.cache_leaf_dtype(name, dt),
                sharding=shards[gk][name])
             for name, leaf in grp.items()}
        for gk, grp in defs.items()}

    W = dims.prefill_width
    carry_sh = specs["carry"][0]
    carry = {
        "xp": jax.ShapeDtypeStruct((S, repl * dims.Sp, W, cfg.d_model), dt,
                                   sharding=carry_sh),
        "xd": jax.ShapeDtypeStruct((S, repl * dims.Sd, 1, cfg.d_model), dt,
                                   sharding=carry_sh),
    }
    fresh_sh = specs["fresh"][0]
    fresh = {
        "xp": jax.ShapeDtypeStruct((repl * dims.Sp, W, cfg.d_model), dt,
                                   sharding=fresh_sh),
        "xd": jax.ShapeDtypeStruct((repl * dims.Sd, 1, cfg.d_model), dt,
                                   sharding=fresh_sh),
    }
    meta_abs = serve_lib.abstract_meta(dims, S)
    meta = {
        k: jax.ShapeDtypeStruct(
            (v.shape[0], repl * v.shape[1]) + tuple(v.shape[2:]), v.dtype,
            sharding=specs["meta"][0][k])
        for k, v in meta_abs.items()
    }
    sampling = {
        "temps": jax.ShapeDtypeStruct(
            (repl * (dims.Sp + dims.Sd),), jnp.float32,
            sharding=NamedSharding(mesh, P(specs["fresh"][0].spec[0]))),
        "seed": jax.ShapeDtypeStruct((), jnp.uint32,
                                     sharding=NamedSharding(mesh, P())),
    }
    return caches, carry, meta, fresh, sampling


def _scale_replica(shape, sharding: NamedSharding, repl: int):
    """Cache shapes are per-replica; the global array multiplies every
    'data'/'pod'-sharded dim by the replica count."""
    spec = sharding.spec
    out = list(shape)
    for i, entry in enumerate(spec):
        names = entry if isinstance(entry, tuple) else (entry,)
        if any(n in ("data", "pod") for n in names if n):
            out[i] = shape[i] * repl
    return tuple(out)
