import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production mesh, prove it fits (memory_analysis) and extract the
roofline terms (cost_analysis + HLO collective parse).

The XLA_FLAGS line above MUST precede any jax import — jax locks the device
count at first init.  Do not set that flag anywhere global (smoke tests and
benches must see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
        --shape decode_32k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED_ARCHS, ASSIGNED_SHAPES, applicable_shapes,
                           get_config)
from repro.distributed.optimizer import adam_abstract
from repro.distributed.pipeline import build_serve_tick, build_train_step, tree_specs
from repro.launch.mesh import derive_pipeline_mesh, make_production_mesh
from repro.launch.shapes import (serve_cell_dims, serve_input_specs,
                                 train_batch_specs, train_cell_dims)
from repro.models import transformer as tfm
from repro.roofline.analysis import (RooflineCell, model_flops,
                                     parse_collective_bytes, param_count)

from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def abstract_params_sharded(cfg, mesh):
    pspecs = tfm.param_pspecs(cfg)
    abs_p = tfm.abstract_params(cfg)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                          sharding=NamedSharding(mesh, s)),
        abs_p, pspecs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False):
    """Lower + compile one cell; returns (compiled, lowered, meta dict)."""
    cfg = get_config(arch)
    pp_env, tp_env = os.environ.get("REPRO_PP"), os.environ.get("REPRO_TP")
    if pp_env and tp_env:
        from repro.distributed.elastic import replan
        cfg = replan(cfg, int(pp_env), int(tp_env))
    shape = ASSIGNED_SHAPES[shape_name]
    prod = make_production_mesh(multi_pod=multi_pod)
    mesh = derive_pipeline_mesh(prod, cfg.plan.pp, cfg.plan.tp)
    chips = int(jax.device_count())
    t0 = time.time()

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            dims = train_cell_dims(cfg, shape, data=mesh.shape["data"],
                                   pods=mesh.shape.get("pod", 1))
            gc = os.environ.get("REPRO_GRAD_COMPRESSION") or None
            step = build_train_step(cfg, mesh, enc_width=dims.enc_width,
                                    grad_compression=gc)
            params = abstract_params_sharded(cfg, mesh)
            opt = adam_abstract(params)
            batch = train_batch_specs(cfg, dims, mesh)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params, opt, batch)
        else:
            dims = serve_cell_dims(cfg, shape, data=mesh.shape["data"])
            tick, specs = build_serve_tick(cfg, mesh, dims)
            params = abstract_params_sharded(cfg, mesh)
            caches, carry, meta, fresh, sampling = serve_input_specs(
                cfg, dims, mesh, specs)
            lowered = jax.jit(tick, donate_argnums=(1, 2)).lower(
                params, caches, carry, meta, fresh, sampling)
        compiled = lowered.compile()

    t_compile = time.time() - t0
    return compiled, lowered, dict(cfg=cfg, shape=shape, chips=chips,
                                   mesh=mesh, t_compile=t_compile)


def analyse_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 verbose: bool = True) -> dict:
    compiled, lowered, info = lower_cell(arch, shape_name, multi_pod)
    cfg, shape, chips = info["cfg"], info["shape"], info["chips"]

    memstats = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # loop-aware costs: XLA's cost_analysis counts while bodies once; our
    # parser scales by the HLO's known_trip_count annotations
    from repro.roofline.hlo_cost import analyse_hlo_text
    hc = analyse_hlo_text(hlo)

    per_dev_bytes = (memstats.argument_size_in_bytes
                     + memstats.output_size_in_bytes
                     - memstats.alias_size_in_bytes
                     + memstats.temp_size_in_bytes)
    cell = RooflineCell(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16", chips=chips,
        hlo_flops=float(hc["flops"]),
        hlo_bytes=float(hc["bytes"]),
        collective_bytes=float(hc["collective_bytes"]),
        collective_breakdown={k: int(v) for k, v in hc["collectives"].items()},
        model_flops_per_chip=model_flops(cfg, shape, chips, shape.kind),
        per_device_memory_bytes=float(per_dev_bytes),
        notes=f"compile={info['t_compile']:.1f}s "
              f"params={param_count(cfg)/1e9:.1f}B "
              f"active={param_count(cfg, True)/1e9:.1f}B "
              f"raw_xla_flops={ca.get('flops', 0.0):.3g} "
              f"raw_xla_bytes={ca.get('bytes accessed', 0.0):.3g}",
    )
    if verbose:
        print(memstats)
        print({k: ca.get(k) for k in ("flops", "bytes accessed")})
        print("collectives:", hc["collectives"])
        d = cell.to_dict()
        print(json.dumps({k: d[k] for k in (
            "arch", "shape", "mesh", "t_compute", "t_memory", "t_collective",
            "bottleneck", "useful_ratio", "roofline_fraction",
            "per_device_memory_bytes", "notes")}, indent=1))
    return cell.to_dict()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        meshes = (False,) if args.single_pod_only else (False, True)
        todo = [(a, s.name, mp)
                for a in ASSIGNED_ARCHS
                for s in applicable_shapes(get_config(a))
                for mp in meshes]
    else:
        todo = [(args.arch, args.shape, args.multi_pod)]

    # order small-to-large so results stream in early
    size_order = {"qwen1.5-0.5b": 0, "whisper-small": 1, "internlm2-1.8b": 2,
                  "rwkv6-3b": 3, "minicpm3-4b": 4, "olmoe-1b-7b": 5,
                  "qwen2-vl-7b": 6, "qwen2.5-14b": 7,
                  "jamba-1.5-large-398b": 8, "kimi-k2-1t-a32b": 9}
    todo.sort(key=lambda t: (size_order.get(t[0], 99), t[2], t[1]))

    failures = []
    for arch, shape, mp in todo:
        tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
        print(f"=== {tag} ===", flush=True)
        try:
            cells.append(analyse_cell(arch, shape, mp))
        except Exception as e:  # noqa: BLE001 — report all failures at the end
            failures.append((tag, repr(e)))
            traceback.print_exc()
        if args.out:   # incremental flush: long sweeps stream results
            with open(args.out, "w") as f:
                json.dump(cells, f, indent=1)
    if failures:
        print("FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        return 1
    print(f"OK: {len(cells)} cells lowered + compiled")
    return 0


if __name__ == "__main__":
    sys.exit(main())
