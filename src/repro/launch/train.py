"""Training launcher: pipelined train loop for any --arch with async
checkpointing and elastic restart.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --steps 50 [--ckpt /tmp/ck --resume] [--grad-compression ring8]

Reduced configs on CPU (default); on a TPU slice, --full runs the published
config on the production-mesh factoring.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", default=None,
                    choices=[None, "int8", "ring8"])
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config, make_reduced
    from repro.data.tokens import batches
    from repro.distributed.optimizer import AdamConfig, adam_init
    from repro.distributed.pipeline import build_train_step
    from repro.launch.mesh import derive_pipeline_mesh, make_production_mesh
    from repro.models import transformer as tfm
    from repro.runtime.checkpoint import AsyncCheckpointer, restore_checkpoint

    cfg = get_config(args.arch)
    if args.full:
        mesh = derive_pipeline_mesh(make_production_mesh(), cfg.plan.pp,
                                    cfg.plan.tp)
    else:
        cfg = make_reduced(cfg).with_plan(pp=1, tp=1, ep_over_data=False)
        cfg = dataclasses.replace(cfg, dtype="float32")
        mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)

    M, mbg, T = 2, mesh.shape["data"], args.seq
    ew = T // 2 if cfg.is_encoder_decoder else 0
    with jax.set_mesh(mesh):
        step = jax.jit(build_train_step(
            cfg, mesh, adam=AdamConfig(lr=args.lr), enc_width=ew,
            grad_compression=args.grad_compression))
        params = tfm.init_params(cfg, jax.random.key(0),
                                 dtype=jnp.dtype(cfg.dtype))
        if args.resume and args.ckpt and os.path.exists(
                os.path.join(args.ckpt, "manifest.json")):
            params = restore_checkpoint(args.ckpt, params)
            params = jax.tree.map(jnp.asarray, params)
            print(f"resumed from {args.ckpt}")
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, tfm.param_pspecs(cfg),
            is_leaf=lambda x: isinstance(x, P))
        opt = adam_init(params)
        ck = AsyncCheckpointer() if args.ckpt else None
        data = batches(cfg.vocab_size, M, mbg, T, seed=0)
        t0 = time.time()
        for i in range(args.steps):
            b = {k: jnp.asarray(v) for k, v in next(data).items()}
            if cfg.family in ("vlm", "audio"):
                b["embeds"] = jnp.zeros((M, mbg, max(ew, 4), cfg.d_model),
                                        jnp.dtype(cfg.dtype))
            params, opt, m = step(params, opt, b)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['gnorm']):.3f} "
                      f"({(i + 1) / (time.time() - t0):.2f} it/s)", flush=True)
            if ck and i % args.ckpt_every == args.ckpt_every - 1:
                ck.submit(args.ckpt, params, extra={"step": i})
        if ck:
            ck.wait()
            ck.close()
            print(f"checkpointed to {args.ckpt}")


if __name__ == "__main__":
    main()
