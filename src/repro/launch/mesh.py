"""Production mesh definition (spec-mandated shape) and the per-architecture
derived view that factors the `model` axis into `stage x tensor`.

`make_production_mesh` is a FUNCTION (not a module constant) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.jax_compat import ensure_jax_compat

ensure_jax_compat()   # uses jax.make_mesh(axis_types=) / AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def derive_pipeline_mesh(prod_mesh: Mesh, pp: int, tp: int) -> Mesh:
    """Factor the production mesh's `model` axis into (`stage`, `tensor`).

    The same physical devices in the same order — only the logical axis names
    change, so the dry-run still exercises exactly the spec'd production mesh
    (DESIGN.md §3).  Works for both (data, model) and (pod, data, model).
    """
    devices = prod_mesh.devices
    if devices.shape[-1] != pp * tp:
        raise ValueError(f"model axis {devices.shape[-1]} != pp*tp = {pp}*{tp}")
    new_shape = devices.shape[:-1] + (pp, tp)
    names = prod_mesh.axis_names[:-1] + ("stage", "tensor")
    return Mesh(
        devices.reshape(new_shape), names,
        axis_types=(jax.sharding.AxisType.Auto,) * len(names))


def manual_axes(mesh: Mesh) -> frozenset:
    """The mesh axes handled manually inside shard_map (everything except
    `tensor`, which GSPMD auto-shards from argument shardings)."""
    return frozenset(n for n in mesh.axis_names if n != "tensor")
