"""MiniCPM3-4B — multi-head latent attention (MLA) [hf:openbmb/MiniCPM3-4B].
62 published layers padded to 64 (8 stages x 8)."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense",
    num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40, head_dim=96,
    d_ff=6400, vocab_size=73448,
    pattern=(BlockSpec(BlockKind.MLA_MLP, 8),),
    plan=ParallelPlan(pp=8, tp=2),
    mla=True, q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    rope_theta=1e4, supports_long_context=False,
)
