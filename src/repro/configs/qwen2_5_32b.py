"""Qwen2.5-32B — the paper's mid-size evaluation model [arXiv:2412.15115]."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

CONFIG = ArchConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=27648, vocab_size=152064,
    pattern=(BlockSpec(BlockKind.ATTN_MLP, 4),),
    plan=ParallelPlan(pp=16, tp=1),
    qkv_bias=True, rope_theta=1e6, supports_long_context=False,
)
