"""OLMoE-1B-7B — 64 experts top-8 [arXiv:2409.02060]."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304,
    pattern=(BlockSpec(BlockKind.ATTN_MOE, 4),),
    plan=ParallelPlan(pp=4, tp=4),
    num_experts=64, num_experts_per_tok=8, moe_d_ff=1024,
    rope_theta=1e4, supports_long_context=False,
)
