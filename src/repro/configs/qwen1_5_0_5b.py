"""Qwen1.5-0.5B — QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=2816, vocab_size=151936,
    pattern=(BlockSpec(BlockKind.ATTN_MLP, 3),),
    plan=ParallelPlan(pp=8, tp=2),
    qkv_bias=True, rope_theta=1e4, supports_long_context=False,
)
