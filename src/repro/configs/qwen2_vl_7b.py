"""Qwen2-VL-7B backbone [arXiv:2409.12191] — M-RoPE, dynamic-resolution ViT
frontend stubbed (input_specs provides precomputed patch embeddings)."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    pattern=(BlockSpec(BlockKind.ATTN_MLP, 7),),
    plan=ParallelPlan(pp=4, tp=4),
    qkv_bias=True, mrope=True, mrope_sections=(16, 24, 24),
    rope_theta=1e6, supports_long_context=False,  # full attention -> no 500k
)
