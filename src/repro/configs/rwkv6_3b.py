"""RWKV6-3B (Finch) — attention-free, data-dependent decay [arXiv:2404.05892].
O(1)-state decode: long_500k runs natively."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    pattern=(BlockSpec(BlockKind.RWKV, 4),),
    plan=ParallelPlan(pp=8, tp=2),
    rwkv_head_dim=64, norm="layernorm",
    supports_long_context=True,
)
