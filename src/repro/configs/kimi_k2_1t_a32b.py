"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 [arXiv:2501.kimi2].
61 published layers padded to 64 (8 stages x 8); pad layers are
residual-identity (zero out-projections). Experts sharded over data x tensor
(EP=32) — the only way 2 TB of bf16 weights fit a 256-chip v5e pod."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=2048, vocab_size=163840,
    pattern=(BlockSpec(BlockKind.ATTN_MOE, 8),),
    plan=ParallelPlan(pp=8, tp=2, ep_over_data=True),
    num_experts=384, num_experts_per_tok=8, moe_d_ff=2048, num_shared_experts=1,
    rope_theta=5e4, supports_long_context=False,
)
