"""Qwen2.5-14B — GQA + QKV bias [arXiv:2412.15115]. The paper's own
evaluation family; deepest pipeline (pp=16) to showcase the technique."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064,
    pattern=(BlockSpec(BlockKind.ATTN_MLP, 3),),
    plan=ParallelPlan(pp=16, tp=1),
    qkv_bias=True, rope_theta=1e6, supports_long_context=False,
)
