"""Architecture registry.  ``get_config(name)`` resolves an ``--arch`` id."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    ASSIGNED_SHAPES,
    ArchConfig,
    BlockKind,
    BlockSpec,
    ParallelPlan,
    ShapeSpec,
    applicable_shapes,
    make_reduced,
)

# Assigned architectures (the graded 10) + the paper's own evaluation models.
_MODULES: Dict[str, str] = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "internlm2-1.8b": "internlm2_1_8b",
    "whisper-small": "whisper_small",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "rwkv6-3b": "rwkv6_3b",
    # paper evaluation extras (not graded cells)
    "qwen2.5-32b": "qwen2_5_32b",
    "llama3.1-100b": "llama3_1_100b",
}

ASSIGNED_ARCHS: List[str] = list(_MODULES)[:10]


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(_MODULES)


__all__ = [
    "ASSIGNED_ARCHS",
    "ASSIGNED_SHAPES",
    "ArchConfig",
    "BlockKind",
    "BlockSpec",
    "ParallelPlan",
    "ShapeSpec",
    "applicable_shapes",
    "get_config",
    "list_archs",
    "make_reduced",
]
