"""Jamba-1.5-Large — hybrid Mamba+attention with MoE [arXiv:2403.19887].
72 layers over 8 stages (9/stage). Stage-local pattern: 4x(mamba-dense,
mamba-MoE) + 1 attn-MoE => attn:mamba = 1:8 (published 1:7 cannot tile an
SPMD-uniform 9-layer stage; DESIGN.md §7). 16 experts top-2, EP over `data`;
long_500k runs with sequence-sharded KV (flash-decode merge)."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

_pair = (BlockSpec(BlockKind.MAMBA_MLP, 1), BlockSpec(BlockKind.MAMBA_MOE, 1))
CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    pattern=_pair * 4 + (BlockSpec(BlockKind.ATTN_MOE, 1),),
    plan=ParallelPlan(pp=8, tp=2, ep_over_data=True, seq_shard_kv=True),
    num_experts=16, num_experts_per_tok=2, moe_d_ff=24576,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    rope_theta=1e6, supports_long_context=True,
)
