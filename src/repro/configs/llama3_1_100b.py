"""Llama-3.1-100B proxy — the paper's largest model is a 100B downscale of
Llama-3.1-405B (paper §4.1 footnote 2). We proxy with 96 layers x d=8192
(~84B + embeddings), same family (GQA kv=8, SwiGLU)."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

CONFIG = ArchConfig(
    name="llama3.1-100b", family="dense",
    num_layers=96, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    pattern=(BlockSpec(BlockKind.ATTN_MLP, 12),),
    plan=ParallelPlan(pp=8, tp=2),
    rope_theta=5e5, supports_long_context=False,
)
