"""InternLM2-1.8B — GQA [arXiv:2403.17297]."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

CONFIG = ArchConfig(
    name="internlm2-1.8b", family="dense",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92544,
    pattern=(BlockSpec(BlockKind.ATTN_MLP, 3),),
    plan=ParallelPlan(pp=8, tp=2),
    rope_theta=1e6, supports_long_context=False,
)
