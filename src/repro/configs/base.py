"""Architecture + shape + parallelism configuration.

Every assigned architecture gets one module in ``repro/configs`` exporting
``CONFIG: ArchConfig``.  A stage's layer structure is a *stage-local pattern*
(list of ``BlockSpec``), identical on every pipeline stage — the SPMD pipeline
requires a uniform per-stage program; heterogeneity (jamba's mamba/attn
interleave, whisper's enc/dec split) is expressed inside the pattern.
DESIGN.md §3 records where this shifts a published layer order.
"""

from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


class BlockKind(enum.Enum):
    ATTN_MLP = "attn_mlp"        # self-attention + dense MLP
    ATTN_MOE = "attn_moe"        # self-attention + MoE FFN
    MLA_MLP = "mla_mlp"          # multi-head latent attention + dense MLP
    MAMBA_MLP = "mamba_mlp"      # mamba mixer + dense MLP
    MAMBA_MOE = "mamba_moe"      # mamba mixer + MoE FFN
    RWKV = "rwkv"                # rwkv6 time-mix + channel-mix
    ENC_LAYER = "enc_layer"      # bidirectional self-attn + MLP (whisper enc)
    DEC_LAYER = "dec_layer"      # causal self-attn + cross-attn + MLP


@dataclass(frozen=True)
class BlockSpec:
    kind: BlockKind
    repeat: int                  # stacked (scanned) repetitions per stage


@dataclass(frozen=True)
class ParallelPlan:
    """How the production mesh maps onto this architecture.

    ``pp * tp`` must equal the `model` axis size (16).  ``ep_over_data`` turns
    on expert-parallelism over the `data` axis (kimi, jamba); otherwise MoE
    experts are replicated over `data` and sharded over `tensor` only.
    """

    pp: int                      # pipeline stages (paper's #PP_depth)
    tp: int                      # tensor-parallel degree inside a stage
    ep_over_data: bool = False
    # long-context decode: shard the KV sequence over `data` (flash-decode
    # partial-softmax merge).  Only used by the long_500k shape.
    seq_shard_kv: bool = False


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int              # published layer count (pre-padding)
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # stage-local structure; len == layers per stage after padding
    pattern: Tuple[BlockSpec, ...] = ()
    plan: ParallelPlan = ParallelPlan(pp=4, tp=4)

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 1e6
    mrope: bool = False                     # qwen2-vl 3-axis M-RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    causal: bool = True

    # MLA (minicpm3)
    mla: bool = False
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0                       # per-expert hidden dim
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (mamba / rwkv6)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    rwkv_head_dim: int = 64

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False

    # misc
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    act: str = "silu"                       # silu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # which assigned shapes apply (DESIGN.md §3)
    supports_long_context: bool = False     # run long_500k?

    # ------------------------------------------------------------------ derived
    @property
    def layers_per_stage(self) -> int:
        return sum(b.repeat for b in self.pattern)

    @property
    def padded_layers(self) -> int:
        return self.layers_per_stage * self.plan.pp

    @property
    def layer_padding(self) -> int:
        return self.padded_layers - self.num_layers

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the lm_head shards evenly over stage x tensor
        (e.g. whisper 51865 -> 51872).  Token ids never reach the pad rows."""
        m = max(16, self.plan.pp * self.plan.tp)
        return (self.vocab_size + m - 1) // m * m

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def kv_cache_dim_per_token(self) -> int:
        """KV bytes-per-token driver (per attention layer), in elements."""
        if self.mla:
            return self.kv_lora_rank + self.qk_rope_dim
        return 2 * self.num_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def num_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def with_plan(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, plan=dataclasses.replace(self.plan, **kw))

    def params_per_layer_estimate(self) -> Dict[str, float]:
        """Rough analytic parameter counts (used by roofline MODEL_FLOPS)."""
        d = self.d_model
        counts: Dict[str, float] = {}
        counts["attn"] = d * self.q_dim + self.q_dim * d + 2 * d * self.kv_dim
        if self.mla:
            counts["attn"] = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                + d * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                + self.num_heads * self.v_head_dim * d
            )
        counts["mlp"] = 3 * d * self.d_ff
        if self.is_moe:
            counts["moe"] = 3 * d * self.moe_d_ff * self.num_experts
            counts["moe_active"] = 3 * d * self.moe_d_ff * (
                self.num_experts_per_tok + self.num_shared_experts
            ) + d * self.num_experts
        counts["mamba"] = (
            2 * d * self.mamba_d_inner                      # in_proj (x, gate)
            + self.mamba_d_inner * self.mamba_d_conv        # conv
            + self.mamba_d_inner * (self.mamba_d_state * 2 + 1 + self.mamba_d_state)
            + self.mamba_d_inner * d                        # out_proj
        )
        counts["rwkv"] = 4 * d * d + d * d + 2 * d * self.d_ff  # tm(r,k,v,o,g) + cm
        return counts


# ----------------------------------------------------------------------------
# Input shapes (assigned; seq_len x global_batch)
# ----------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


ASSIGNED_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> List[ShapeSpec]:
    """The assigned shape cells that run for this arch (DESIGN.md §3)."""
    out = [ASSIGNED_SHAPES["train_4k"], ASSIGNED_SHAPES["prefill_32k"],
           ASSIGNED_SHAPES["decode_32k"]]
    if cfg.supports_long_context:
        out.append(ASSIGNED_SHAPES["long_500k"])
    return out


def make_reduced(cfg: ArchConfig, *, d_model: int = 64, d_ff: int = 128,
                 vocab: int = 256) -> ArchConfig:
    """A tiny same-family variant for CPU smoke tests (one block per kind)."""
    head_dim = 16
    heads = max(2, d_model // head_dim)
    kv_heads = min(cfg.num_kv_heads, heads) or heads
    while heads % kv_heads:
        kv_heads -= 1
    pattern = tuple(BlockSpec(b.kind, 1) for b in cfg.pattern)
    return dataclasses.replace(
        cfg,
        d_model=d_model,
        d_ff=d_ff,
        vocab_size=vocab,
        num_heads=heads,
        num_kv_heads=kv_heads,
        head_dim=head_dim,
        num_layers=len(pattern) * 2,
        pattern=pattern,
        plan=ParallelPlan(pp=2, tp=1, ep_over_data=cfg.plan.ep_over_data,
                          seq_shard_kv=False),
        num_experts=min(cfg.num_experts, 4),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        moe_d_ff=min(cfg.moe_d_ff, 64) if cfg.moe_d_ff else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
        mamba_d_state=8, mamba_d_conv=4, mamba_expand=2,
        rwkv_head_dim=16,
        mrope_sections=(4, 2, 2),
    )
