"""Whisper-small backbone [arXiv:2212.04356] — encoder-decoder; the conv
frontend is a STUB (input_specs provides precomputed frame embeddings).
12 enc + 12 dec layers over 4 stages: each stage runs 3 enc + 3 dec layers;
the final encoder states ride the pipeline payload for cross-attention."""
from repro.configs.base import ArchConfig, BlockKind, BlockSpec, ParallelPlan

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    num_layers=24, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    pattern=(BlockSpec(BlockKind.ENC_LAYER, 3), BlockSpec(BlockKind.DEC_LAYER, 3)),
    plan=ParallelPlan(pp=4, tp=4),
    is_encoder_decoder=True, norm="layernorm", act="gelu",
    rope_theta=1e4, supports_long_context=False,
)
