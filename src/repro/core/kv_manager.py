"""Paged KV-cache manager (vLLM-style), shared across pipeline stages.

The driver owns a single logical page table per request (the paper: "all the
workers share the page tables like vLLM").  Physical cache arrays live on the
devices, sharded over the `stage` mesh axis (each stage holds its own layers'
pages); the *page ids* are global and identical on every stage, so one host-side
allocator serves the whole pipeline.

Supports: allocation/free, copy-on-extend block tables, preemption reclaim,
optional prefix caching (hash-chained full pages with refcounts), the
KV idle-rate signal consumed by Token Throttling's UT term, and per-request
export/import for live migration across replicas (DESIGN.md §9): `export_kv`
captures a request's resident token positions, `import_kv` re-maps them onto
freshly-allocated slots of another manager (page geometries may differ —
the mapping is per token, not per page).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class KVExport:
    """Portable description of one request's resident KV (host side).

    `slots` is the source (page, slot) per resident token, in sequence
    order — exactly the index list a device-side gather needs.  The actual
    cache bytes are moved by the execution backend
    (`ExecutionBackend.export_kv_pages`/`import_kv_pages`); this object only
    carries the *addressing* so the destination can re-map slots.
    """

    request_id: str
    num_tokens: int
    page_size: int
    slots: Tuple[Tuple[int, int], ...]


def hash_page(parent_hash: int, token_ids: Tuple[int, ...]) -> int:
    """Position-dependent content hash for prefix caching (hash chain)."""
    return hash((parent_hash,) + token_ids)


@dataclass
class PageInfo:
    page_id: int
    ref_count: int = 0
    prefix_hash: Optional[int] = None  # set only for frozen full pages


class PagedKVManager:
    """Host-side allocator for the paged KV cache.

    Pages are identified by integer id in [0, num_pages).  `page_size` is in
    tokens.  A request's block table maps token position p to page
    `block_table[p // page_size]`, slot `p % page_size`.
    """

    def __init__(
        self,
        num_pages: int,
        page_size: int,
        enable_prefix_caching: bool = False,
    ) -> None:
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        self.enable_prefix_caching = enable_prefix_caching

        self._pages: List[PageInfo] = [PageInfo(i) for i in range(num_pages)]
        self._free: List[int] = list(range(num_pages - 1, -1, -1))  # LIFO
        # Evictable prefix-cache pages: hash -> page_id with ref_count == 0.
        self._prefix_index: Dict[int, int] = {}
        self._evictable: Dict[int, None] = {}  # ordered set (LRU) of page ids
        self._block_tables: Dict[str, List[int]] = {}
        # tokens with KV resident, per request (for slot computation)
        self._num_tokens: Dict[str, int] = {}

    # ------------------------------------------------------------------ state
    @property
    def num_free_pages(self) -> int:
        return len(self._free) + len(self._evictable)

    @property
    def kv_free_rate(self) -> float:
        """KV idle rate in [0,1] — the UT input of Token Throttling."""
        return self.num_free_pages / self.num_pages

    def block_table(self, request_id: str) -> List[int]:
        return self._block_tables[request_id]

    def num_tokens(self, request_id: str) -> int:
        return self._num_tokens.get(request_id, 0)

    def has_request(self, request_id: str) -> bool:
        return request_id in self._block_tables

    # ------------------------------------------------------------- allocation
    def pages_needed(self, request_id: str, new_tokens: int) -> int:
        cur = self._num_tokens.get(request_id, 0)
        cur_pages = len(self._block_tables.get(request_id, ()))
        need_pages = -(-(cur + new_tokens) // self.page_size)  # ceil div
        return max(0, need_pages - cur_pages)

    def can_allocate(self, request_id: str, new_tokens: int) -> bool:
        return self.pages_needed(request_id, new_tokens) <= self.num_free_pages

    def allocate(self, request_id: str, new_tokens: int) -> List[Tuple[int, int]]:
        """Extend a request's KV by `new_tokens`; returns (page, slot) per token.

        Raises MemoryError when out of pages — callers must check
        `can_allocate` first (the scheduler preempts instead of failing).
        """
        need = self.pages_needed(request_id, new_tokens)
        if need > self.num_free_pages:
            raise MemoryError(
                f"KV pool exhausted: need {need} pages, free {self.num_free_pages}"
            )
        table = self._block_tables.setdefault(request_id, [])
        self._num_tokens.setdefault(request_id, 0)
        for _ in range(need):
            table.append(self._take_free_page())
        start = self._num_tokens[request_id]
        slots = [
            (table[(start + i) // self.page_size], (start + i) % self.page_size)
            for i in range(new_tokens)
        ]
        self._num_tokens[request_id] += new_tokens
        return slots

    def free(self, request_id: str) -> None:
        """Release all pages of a request (finish or preemption)."""
        table = self._block_tables.pop(request_id, None)
        self._num_tokens.pop(request_id, None)
        if table is None:
            return
        for pid in table:
            self._release_page(pid)

    # -------------------------------------------------------------- migration
    def export_kv(self, request_id: str) -> KVExport:
        """Addressing of a resident request's KV, for live migration."""
        if request_id not in self._block_tables:
            raise KeyError(f"request {request_id} has no resident KV")
        table = self._block_tables[request_id]
        n = self._num_tokens[request_id]
        slots = tuple((table[i // self.page_size], i % self.page_size)
                      for i in range(n))
        return KVExport(request_id=request_id, num_tokens=n,
                        page_size=self.page_size, slots=slots)

    def import_kv(self, export: KVExport) -> List[Tuple[int, int]]:
        """Allocate fresh pages for a migrated-in request and return the
        destination (page, slot) per token — the scatter addresses matching
        `export.slots` gather addresses one-to-one.  Raises MemoryError when
        the pool cannot hold the request (callers should `can_allocate`
        first and fall back to recompute)."""
        rid = export.request_id
        if self.has_request(rid):
            raise ValueError(f"request {rid} already resident here")
        return self.allocate(rid, export.num_tokens)

    # ---------------------------------------------------------- prefix caching
    def match_prefix(self, token_ids: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest cached prefix in *full pages*: (num_cached_tokens, page_ids).

        Matched pages get their refcount bumped; caller must attach them via
        `adopt_prefix` or release with `release_pages`.
        """
        if not self.enable_prefix_caching:
            return 0, []
        matched: List[int] = []
        parent = 0
        for i in range(0, len(token_ids) - self.page_size + 1, self.page_size):
            chunk = tuple(token_ids[i : i + self.page_size])
            h = hash_page(parent, chunk)
            pid = self._prefix_index.get(h)
            if pid is None:
                break
            self._pages[pid].ref_count += 1
            self._evictable.pop(pid, None)
            matched.append(pid)
            parent = h
        return len(matched) * self.page_size, matched

    def peek_prefix(self, token_ids: Sequence[int]) -> int:
        """Length (in tokens) of the longest cached prefix, without side
        effects: refcounts and the LRU order are untouched.  The router's
        cache-affinity probe — safe to call on every candidate replica per
        routing decision."""
        if not self.enable_prefix_caching:
            return 0
        matched = 0
        parent = 0
        for i in range(0, len(token_ids) - self.page_size + 1, self.page_size):
            chunk = tuple(token_ids[i : i + self.page_size])
            h = hash_page(parent, chunk)
            if h not in self._prefix_index:
                break
            matched += 1
            parent = h
        return matched * self.page_size

    def adopt_prefix(self, request_id: str, num_tokens: int, page_ids: List[int]) -> None:
        """Attach matched prefix pages as the head of a fresh block table."""
        assert request_id not in self._block_tables, "adopt before first allocate"
        self._block_tables[request_id] = list(page_ids)
        self._num_tokens[request_id] = num_tokens

    def freeze_full_pages(self, request_id: str, token_ids: Sequence[int]) -> None:
        """Register the request's full pages in the prefix index (post-prefill)."""
        if not self.enable_prefix_caching:
            return
        table = self._block_tables.get(request_id, [])
        parent = 0
        for idx in range(len(token_ids) // self.page_size):
            chunk = tuple(token_ids[idx * self.page_size : (idx + 1) * self.page_size])
            h = hash_page(parent, chunk)
            pid = table[idx]
            info = self._pages[pid]
            if info.prefix_hash is None and h not in self._prefix_index:
                info.prefix_hash = h
                self._prefix_index[h] = pid
            parent = h

    def release_pages(self, page_ids: Sequence[int]) -> None:
        for pid in page_ids:
            self._release_page(pid)

    # -------------------------------------------------------------- internals
    def _take_free_page(self) -> int:
        if self._free:
            pid = self._free.pop()
        else:
            # Evict the least-recently-freed cached prefix page.
            pid, _ = next(iter(self._evictable.items()))
            del self._evictable[pid]
            info = self._pages[pid]
            if info.prefix_hash is not None:
                self._prefix_index.pop(info.prefix_hash, None)
                info.prefix_hash = None
        info = self._pages[pid]
        assert info.ref_count == 0, f"allocating referenced page {pid}"
        info.ref_count = 1
        return pid

    def _release_page(self, pid: int) -> None:
        info = self._pages[pid]
        assert info.ref_count > 0, f"double free of page {pid}"
        info.ref_count -= 1
        if info.ref_count == 0:
            if info.prefix_hash is not None:
                self._evictable[pid] = None  # cached: evictable, not free
            else:
                self._free.append(pid)

    # ------------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Debug/property-test hook: global page accounting must balance."""
        referenced = sum(1 for p in self._pages if p.ref_count > 0)
        in_tables = {pid for t in self._block_tables.values() for pid in t}
        assert len(self._free) + len(self._evictable) + referenced == self.num_pages, (
            len(self._free), len(self._evictable), referenced, self.num_pages
        )
        for pid in in_tables:
            assert self._pages[pid].ref_count > 0, f"page {pid} in table but free"
        free_set = set(self._free) | set(self._evictable)
        assert not (free_set & in_tables), "page simultaneously free and mapped"
