"""Token Throttling — the paper's core contribution (gLLM §3.1–§3.2).

Pure, side-effect-free policy functions mapping *global system state* to
per-micro-batch token budgets.  All equations are from the paper:

  eq. (1)  WT:  #P = min(max(#WP / #T, #MinP), #MaxP)
  eq. (2)  UT:  #P = max(#MaxP * KV_free, #MinP)
  eq. (3)  combined (+ threshold):
           #P = max(min(#WP / #T, #MaxP * (KV_free - KV_th)/(1 - KV_th)), #MinP)
           with prefill suspended entirely when KV_free <= KV_th (§3.1.3)
  eq. (4)  decode: #D = #RD / #PP_depth

The functions return *token* budgets; the scheduler (`scheduler.py`) turns
budgets into concrete request selections and KV allocations.
"""

from __future__ import annotations

import enum
import math
from dataclasses import dataclass


class PrefillPolicy(enum.Enum):
    """Which prefill-throttling terms are active (for the paper's ablations)."""

    GLLM = "gllm"          # eq. (3): WT + UT + threshold (the full technique)
    NO_WT = "no_wt"        # ablation "gLLM w/o WT": eq. (2) + threshold
    NO_UT = "no_ut"        # ablation "gLLM w/o UT": eq. (1) only
    SARATHI = "sarathi"    # "gLLM w/ CK": fixed-budget chunked-prefill policy


@dataclass(frozen=True)
class ThrottleConfig:
    """Hyperparameters; defaults are the paper's evaluation settings (§4.1)."""

    num_iters_T: int = 8            # #T    — horizon to drain the waiting pool
    max_prefill_tokens: int = 2048  # #MaxP — also Sarathi's token budget
    min_prefill_tokens: int = 32    # #MinP
    kv_threshold: float = 0.05      # KV_thresh — idle-rate floor (§3.1.3)
    pipeline_depth: int = 4         # #PP_depth — micro-batches in flight
    policy: PrefillPolicy = PrefillPolicy.GLLM

    def __post_init__(self) -> None:
        if not (0.0 <= self.kv_threshold < 1.0):
            raise ValueError(f"kv_threshold must be in [0,1): {self.kv_threshold}")
        if self.num_iters_T < 1 or self.pipeline_depth < 1:
            raise ValueError("num_iters_T and pipeline_depth must be >= 1")
        if self.min_prefill_tokens > self.max_prefill_tokens:
            raise ValueError("min_prefill_tokens > max_prefill_tokens")


# --------------------------------------------------------------------------
# Prefill throttling
# --------------------------------------------------------------------------

def prefill_budget_wt(waiting_tokens: int, cfg: ThrottleConfig) -> int:
    """eq. (1): throttle by tokens awaiting prefill (WT)."""
    if waiting_tokens <= 0:
        return 0
    spread = math.ceil(waiting_tokens / cfg.num_iters_T)
    return min(max(spread, cfg.min_prefill_tokens), cfg.max_prefill_tokens)


def prefill_budget_ut(kv_free: float, cfg: ThrottleConfig) -> int:
    """eq. (2): throttle by KV-cache idle rate (UT)."""
    kv_free = min(max(kv_free, 0.0), 1.0)
    return max(int(cfg.max_prefill_tokens * kv_free), cfg.min_prefill_tokens)


def _ut_scale(kv_free: float, cfg: ThrottleConfig) -> float:
    """UT budget with the threshold safeguard of §3.1.3 folded in (eq. 3)."""
    if kv_free <= cfg.kv_threshold:
        return 0.0
    return cfg.max_prefill_tokens * (kv_free - cfg.kv_threshold) / (1.0 - cfg.kv_threshold)


def prefill_budget(waiting_tokens: int, kv_free: float, cfg: ThrottleConfig) -> int:
    """eq. (3): combined WT + UT + threshold prefill token budget.

    Hard guards (both from §3.1): zero pending tokens => nothing to schedule;
    KV idle rate at/below the threshold => prefill suspended.
    """
    if waiting_tokens <= 0:
        return 0
    kv_free = min(max(kv_free, 0.0), 1.0)

    if cfg.policy is PrefillPolicy.NO_UT:
        budget = float(prefill_budget_wt(waiting_tokens, cfg))
    elif cfg.policy is PrefillPolicy.NO_WT:
        if kv_free <= cfg.kv_threshold:
            return 0
        budget = max(_ut_scale(kv_free, cfg), cfg.min_prefill_tokens)
    else:  # GLLM (eq. 3) — SARATHI never calls this function
        if kv_free <= cfg.kv_threshold:
            return 0
        wt = math.ceil(waiting_tokens / cfg.num_iters_T)
        budget = max(min(float(wt), _ut_scale(kv_free, cfg)), cfg.min_prefill_tokens)

    # Never schedule more than exists, never exceed #MaxP.
    return int(min(budget, cfg.max_prefill_tokens, waiting_tokens))


# --------------------------------------------------------------------------
# Decode throttling
# --------------------------------------------------------------------------

def decode_budget(running_decode: int, cfg: ThrottleConfig) -> int:
    """eq. (4): spread decode tokens evenly over the in-flight micro-batches.

    One decode request contributes exactly one token per iteration, so the
    budget is in requests == tokens.  Ceil so the pool drains without a
    trailing remainder micro-batch.
    """
    if running_decode <= 0:
        return 0
    return math.ceil(running_decode / cfg.pipeline_depth)
