"""Request lifecycle primitives for the gLLM serving engine.

A request moves through:  WAITING -> PREFILLING (possibly chunked over several
micro-batches) -> DECODING -> FINISHED.  It may be PREEMPTED while decoding
(KV pages reclaimed); preempted requests re-enter the waiting queue and are
recovered by recompute (prompt + generated tokens are re-prefilled), matching
vLLM/gLLM recompute semantics.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional, Sequence


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    PREEMPTED = "preempted"
    FINISHED_STOPPED = "finished_stopped"      # hit eos
    FINISHED_LENGTH = "finished_length"        # hit max_new_tokens
    FINISHED_ABORTED = "finished_aborted"      # user / fault abort

    @property
    def is_finished(self) -> bool:
        return self in (
            RequestState.FINISHED_STOPPED,
            RequestState.FINISHED_LENGTH,
            RequestState.FINISHED_ABORTED,
        )


# Public finish-reason vocabulary of the serving API (repro.serving): every
# finished request maps to exactly one of these strings.
FINISH_REASONS = {
    RequestState.FINISHED_STOPPED: "stop",
    RequestState.FINISHED_LENGTH: "length",
    RequestState.FINISHED_ABORTED: "abort",
}


# SLO-class vocabulary.  A request's class picks its point on the
# throughput-latency tradeoff (Sarathi-Serve, arXiv:2403.02310): interactive
# requests are admitted ahead of batch ones when the Token Throttling prefill
# budget (eq. 3) is contended, and batch requests are preferred as preemption
# victims when the KV pool saturates.  Within a class, higher `priority`
# wins; within a priority, FCFS order is preserved.
SLO_INTERACTIVE = "interactive"
SLO_BATCH = "batch"
SLO_CLASSES = (SLO_INTERACTIVE, SLO_BATCH)
# admission rank: lower admits first / is victimized last
SLO_RANK = {cls: i for i, cls in enumerate(SLO_CLASSES)}


@dataclass
class SamplingParams:
    max_new_tokens: int = 128
    temperature: float = 0.0          # 0.0 => greedy
    top_k: int = 0                    # 0 => disabled
    top_p: float = 1.0
    stop_token_ids: Sequence[int] = ()
    # Scheduling class (not sampling, but per-request like everything here —
    # the one bag of knobs a client attaches to a request, vLLM-style).
    priority: int = 0                 # higher admits first within a class
    slo_class: str = SLO_INTERACTIVE  # "interactive" | "batch"

    def __post_init__(self) -> None:
        if self.slo_class not in SLO_CLASSES:
            raise ValueError(
                f"unknown slo_class {self.slo_class!r}; expected one of "
                f"{SLO_CLASSES}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


@dataclass
class RequestMetrics:
    arrival_time: float = 0.0
    first_scheduled_time: Optional[float] = None
    first_token_time: Optional[float] = None   # TTFT = first_token - arrival
    finish_time: Optional[float] = None
    num_preemptions: int = 0

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def e2el(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def tpot(self, num_output_tokens: int) -> Optional[float]:
        """Mean time-per-output-token after the first token."""
        if self.finish_time is None or self.first_token_time is None:
            return None
        if num_output_tokens <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) / (num_output_tokens - 1)


@dataclass
class Request:
    request_id: str
    prompt_token_ids: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    state: RequestState = RequestState.WAITING
    output_token_ids: List[int] = field(default_factory=list)
    # Chunked-prefill progress over the *effective* prompt (see below).  After a
    # preemption the generated tokens are folded into the effective prompt and
    # recomputed, so num_prefilled always counts tokens whose KV is resident.
    num_prefilled: int = 0
    metrics: RequestMetrics = field(default_factory=RequestMetrics)

    # ----------------------------------------------------------------- class
    @property
    def slo_class(self) -> str:
        return self.sampling.slo_class

    @property
    def priority(self) -> int:
        return self.sampling.priority

    @property
    def slo_rank(self) -> int:
        """Admission rank (lower admits first); unknown classes sort last."""
        return SLO_RANK.get(self.sampling.slo_class, len(SLO_CLASSES))

    # ------------------------------------------------------------------ sizes
    @property
    def num_prompt_tokens(self) -> int:
        return len(self.prompt_token_ids)

    @property
    def num_output_tokens(self) -> int:
        return len(self.output_token_ids)

    @property
    def effective_prompt(self) -> List[int]:
        """Tokens that must have resident KV before the next decode step.

        After preemption-by-recompute the already-generated tokens are treated
        as prompt (they are re-prefilled).
        """
        return self.prompt_token_ids + self.output_token_ids

    @property
    def num_effective_prompt_tokens(self) -> int:
        return len(self.prompt_token_ids) + len(self.output_token_ids)

    @property
    def remaining_prefill_tokens(self) -> int:
        return max(0, self.num_effective_prompt_tokens - self.num_prefilled)

    @property
    def prefill_done(self) -> bool:
        return self.remaining_prefill_tokens == 0

    @property
    def seq_len(self) -> int:
        """Tokens with resident KV (context length for attention)."""
        return self.num_prefilled

    # ------------------------------------------------------------- transitions
    def record_new_token(self, token_id: int, now: float) -> None:
        """Append a sampled token.  KV accounting (num_prefilled) is advanced
        by the scheduler from the ScheduledSeq that produced the token, not
        here — decode steps write the *consumed* token's KV, while a final
        prefill chunk has already written KV for the whole chunk."""
        self.output_token_ids.append(token_id)
        if self.metrics.first_token_time is None:
            self.metrics.first_token_time = now
        if token_id in tuple(self.sampling.stop_token_ids):
            self.state = RequestState.FINISHED_STOPPED
            self.metrics.finish_time = now
        elif self.num_output_tokens >= self.sampling.max_new_tokens:
            self.state = RequestState.FINISHED_LENGTH
            self.metrics.finish_time = now

    def preempt(self) -> None:
        """Reset for recompute: generated tokens fold into the prompt."""
        self.state = RequestState.PREEMPTED
        self.num_prefilled = 0
        self.metrics.num_preemptions += 1

    @property
    def is_finished(self) -> bool:
        return self.state.is_finished

    @property
    def finish_reason(self) -> Optional[str]:
        """"stop" / "length" / "abort" once finished, else None."""
        return FINISH_REASONS.get(self.state)
