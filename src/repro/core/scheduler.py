"""Iteration-level pipeline scheduler with Token Throttling (gLLM §3).

One `schedule()` call forms one micro-batch (= one pipeline tick's worth of
work for the first stage).  The scheduler is policy-parameterized:

  * ``PrefillPolicy.GLLM``    — Token Throttling (the paper's technique):
        decode:  #D = ceil(#RD / #PP_depth)                       (eq. 4)
        prefill: #P from eq. (3) (WT + UT + threshold)
  * ``PrefillPolicy.SARATHI`` — the baseline (Sarathi-Serve / vLLM policy):
        all available decode tokens first, then chunked prefill up to the
        fixed token budget (#MaxP).
  * ``NO_WT`` / ``NO_UT``     — the paper's ablations (Fig. 15).

Pipeline-parallel correctness constraint: a request may be resident in at most
one in-flight micro-batch (its KV pages are appended in sequence order), so
requests scheduled into batch *t* are unavailable until `complete(t)`.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.kv_manager import PagedKVManager
from repro.core.request import Request, RequestState
from repro.core.throttle import (
    PrefillPolicy,
    ThrottleConfig,
    decode_budget,
    prefill_budget,
)


@dataclass
class ScheduledSeq:
    """One sequence's contribution to a micro-batch."""

    request: Request
    start_pos: int          # context length before this step (tokens with KV)
    num_tokens: int         # chunk length (prefill) or 1 (decode)
    is_prefill: bool
    # (page, slot) per new token — where this step writes KV.
    slots: List[Tuple[int, int]] = field(default_factory=list)

    @property
    def produces_token(self) -> bool:
        """True if this entry emits a sampled token (decode, or final chunk)."""
        if not self.is_prefill:
            return True
        return self.start_pos + self.num_tokens == self.request.num_effective_prompt_tokens


@dataclass
class ScheduledBatch:
    batch_id: int
    prefill: List[ScheduledSeq]
    decode: List[ScheduledSeq]

    @property
    def num_prefill_tokens(self) -> int:
        return sum(s.num_tokens for s in self.prefill)

    @property
    def num_decode_tokens(self) -> int:
        return len(self.decode)

    @property
    def num_tokens(self) -> int:
        return self.num_prefill_tokens + self.num_decode_tokens

    @property
    def seqs(self) -> List[ScheduledSeq]:
        return self.prefill + self.decode

    @property
    def is_empty(self) -> bool:
        return not self.prefill and not self.decode


@dataclass
class SchedulerStats:
    """Per-tick observability (drives Fig. 1/4-style benchmarks)."""

    ticks: int = 0
    scheduled_prefill_tokens: List[int] = field(default_factory=list)
    scheduled_decode_tokens: List[int] = field(default_factory=list)
    kv_free_rate: List[float] = field(default_factory=list)
    # Raw throttle decisions per tick (eqs. 3/4 outputs, or the Sarathi
    # equivalents), before capacity clamps — the golden-trace regression
    # surface for core/throttle.py + this scheduler (tests/test_trace.py).
    prefill_budgets: List[int] = field(default_factory=list)
    decode_budgets: List[int] = field(default_factory=list)
    preemptions: int = 0
    # Service rate: tokens retired per second, EWMA over retire-to-retire
    # windows on the replica's own clock (wall or virtual).  This is the
    # *discovered* per-replica throughput signal the router can divide
    # balance scores by instead of static `ReplicaCapacity` hints.
    tokens_retired: int = 0
    service_rate: Optional[float] = None
    service_rate_alpha: float = 0.1
    _rate_clock: Optional[float] = None
    _rate_tokens: int = 0
    # Prefix caching (DESIGN.md §13): admission probes the pool's prefix
    # index for every first chunk; hits adopt the cached head and skip its
    # prefill entirely.  `cached_prefill_tokens` is the per-tick series —
    # the trace's optional `cached` field (schema 1.4) and the surface
    # benchmarks/fig_prefix_cache.py plots.
    prefix_lookups: int = 0
    prefix_hits: int = 0
    prefix_tokens_avoided: int = 0
    cached_prefill_tokens: List[int] = field(default_factory=list)

    def note_retire(self, num_tokens: int, now: float) -> None:
        """Fold one batch completion into the service-rate EWMA.  Tokens
        accumulate until the clock advances (virtual time can retire several
        batches at one instant), so every sample has a positive window."""
        self.tokens_retired += num_tokens
        self._rate_tokens += num_tokens
        if self._rate_clock is None:
            self._rate_clock = now
            return
        dt = now - self._rate_clock
        if dt <= 0.0:
            return
        rate = self._rate_tokens / dt
        if self.service_rate is None:
            self.service_rate = rate
        else:
            self.service_rate += self.service_rate_alpha * (
                rate - self.service_rate)
        self._rate_clock = now
        self._rate_tokens = 0


class PipelineScheduler:
    """Global scheduler owned by the driver worker."""

    def __init__(
        self,
        cfg: ThrottleConfig,
        kv: PagedKVManager,
        max_model_len: int = 1 << 20,
        max_batch_seqs: int = 4096,
        max_prefill_seqs: int = 4096,   # static tick bucket Sp
        max_chunk_tokens: int = 1 << 20,  # static tick bucket C
        max_decode_seqs: int = 4096,    # static tick bucket Sd
    ) -> None:
        self.cfg = cfg
        self.kv = kv
        self.max_model_len = max_model_len
        self.max_batch_seqs = max_batch_seqs
        self.max_prefill_seqs = max_prefill_seqs
        self.max_chunk_tokens = max_chunk_tokens
        self.max_decode_seqs = max_decode_seqs

        # Admission queue in arrival order; `admission_order()` derives the
        # SLO-class-aware order eq. 3's budget is actually spent in.
        self.waiting: Deque[Request] = deque()
        self.running_prefill: List[Request] = []         # partially prefilled
        self.running_decode: List[Request] = []          # decoding (FCFS order)
        self._in_flight: Dict[str, int] = {}             # request_id -> batch_id
        self._aborting: set = set()                      # in-flight, abort pending
        self._batches: Dict[int, ScheduledBatch] = {}
        self._batch_counter = itertools.count()
        self.stats = SchedulerStats()
        self._last_prefill_budget = 0
        self._last_decode_budget = 0
        self._last_cached_tokens = 0
        # Notified whenever a request loses its resident state (preemption or
        # batch abort) so the execution layer can release per-request
        # resources (state slots, caches) tied to residency.
        self.on_preempt: Optional[Callable[[Request], None]] = None

    # ---------------------------------------------------------------- intake
    def add_request(self, req: Request) -> None:
        total = req.num_prompt_tokens + req.sampling.max_new_tokens
        if total > self.max_model_len:
            raise ValueError(
                f"request {req.request_id}: {total} tokens > max_model_len {self.max_model_len}"
            )
        pool = self.kv.num_pages * self.kv.page_size
        if total > pool:
            # would livelock on preempt/recompute: reject at admission
            raise ValueError(
                f"request {req.request_id}: {total} tokens exceed the KV pool "
                f"({pool} token slots) — unservable on this replica")
        req.state = RequestState.WAITING
        self.waiting.append(req)

    # ------------------------------------------------------------- accounting
    @property
    def num_waiting_prefill_tokens(self) -> int:
        """#WP — global pending prefill work (waiting + partially prefilled)."""
        wp = sum(r.remaining_prefill_tokens for r in self.waiting)
        wp += sum(r.remaining_prefill_tokens for r in self.running_prefill)
        return wp

    @property
    def num_running_decode(self) -> int:
        """#RD — all decode-state requests, in flight or not."""
        return len(self.running_decode)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running_prefill or self.running_decode
                    or self._in_flight)

    # ----------------------------------------------------------- batch lookup
    def get_batch(self, batch_id: int) -> Optional[ScheduledBatch]:
        """In-flight micro-batch by id; None once completed or aborted.

        This is the public API the execution layer uses to resolve ring
        entries back to their sequences — batches stay resolvable from
        `schedule()` until the matching `complete()`/`abort_batch()`."""
        return self._batches.get(batch_id)

    def active_batch_ids(self) -> List[int]:
        """Ids of all in-flight micro-batches, in scheduling order."""
        return list(self._batches)

    # ---------------------------------------------------------------- schedule
    def schedule(self, now: float = 0.0) -> ScheduledBatch:
        batch_id = next(self._batch_counter)
        decode_seqs = self._schedule_decode(now)
        prefill_seqs = self._schedule_prefill(now, len(decode_seqs))
        batch = ScheduledBatch(batch_id, prefill_seqs, decode_seqs)
        for seq in batch.seqs:
            self._in_flight[seq.request.request_id] = batch_id
        self._batches[batch_id] = batch

        self.stats.ticks += 1
        self.stats.scheduled_prefill_tokens.append(batch.num_prefill_tokens)
        self.stats.scheduled_decode_tokens.append(batch.num_decode_tokens)
        self.stats.kv_free_rate.append(self.kv.kv_free_rate)
        self.stats.prefill_budgets.append(self._last_prefill_budget)
        self.stats.decode_budgets.append(self._last_decode_budget)
        self.stats.cached_prefill_tokens.append(self._last_cached_tokens)
        return batch

    # ----------------------------------------------------------------- decode
    def _schedule_decode(self, now: float) -> List[ScheduledSeq]:
        available = [r for r in self.running_decode
                     if r.request_id not in self._in_flight]
        if self.cfg.policy is PrefillPolicy.SARATHI:
            quota = len(available)                     # decode-first, all of it
        else:
            quota = decode_budget(self.num_running_decode, self.cfg)
        self._last_decode_budget = quota               # raw eq. 4 decision
        quota = min(quota, len(available), self.max_batch_seqs,
                    self.max_decode_seqs)

        out: List[ScheduledSeq] = []
        scheduled: set = set()
        for req in available:
            if len(out) >= quota:
                break
            if req.state is not RequestState.DECODING:
                # victimized by an earlier iteration's page hunt this very
                # tick: its KV is gone and it is back in the waiting queue —
                # scheduling it now would resurrect a zero-context decode
                continue
            if not self._ensure_decode_page(req, protected=scheduled):
                continue  # could not allocate even after preemption: defer
            slots = self.kv.allocate(req.request_id, 1)
            out.append(ScheduledSeq(req, req.seq_len, 1, False, slots))
            scheduled.add(req.request_id)
        return out

    def _ensure_decode_page(self, req: Request,
                            protected: frozenset = frozenset()) -> bool:
        """Make room for one decode token, preempting if necessary (§3.1.3).
        `protected` requests (already in the batch being formed, with slots
        allocated) must not be victimized — freeing their pages would tear
        the very slots this tick is about to write."""
        while not self.kv.can_allocate(req.request_id, 1):
            victim = self._pick_preemption_victim(
                exclude={req.request_id} | set(protected))
            if victim is None:
                return False
            self._preempt(victim)
        return True

    def _victim_order(self, group: List[Request]) -> List[Request]:
        """Preemption order within one residency group: batch-class requests
        are victimized before interactive ones, lower `priority` before
        higher, and within a tie the latest arrival goes first (vLLM
        recompute policy).  With every request at the defaults this reduces
        to plain latest-arrival-first."""
        return sorted(reversed(group),
                      key=lambda r: (-r.slo_rank, r.priority))

    def _pick_preemption_victim(self, exclude) -> Optional[Request]:
        """Resident request to evict, SLO-class-aware (batch-first).

        Partially-prefilled requests are victims *first*: a stalled chunked
        prefill holding pages while decode is starved is otherwise a
        deadlock (decode can only preempt decode, prefill can only shrink).
        Then decode requests — in both groups batch-class before
        interactive, then latest arrival (`_victim_order`)."""
        if isinstance(exclude, str):
            exclude = {exclude}
        for group in (self.running_prefill, self.running_decode):
            for req in self._victim_order(group):
                if req.request_id in exclude \
                        or req.request_id in self._in_flight:
                    continue
                return req
        return None

    def _preempt(self, req: Request) -> None:
        self.kv.free(req.request_id)
        if req in self.running_decode:
            self.running_decode.remove(req)
        if req in self.running_prefill:
            self.running_prefill.remove(req)
        req.preempt()
        req.state = RequestState.WAITING
        self.waiting.appendleft(req)   # recompute with priority
        self.stats.preemptions += 1
        if self.on_preempt is not None:
            self.on_preempt(req)

    # ---------------------------------------------------------------- prefill
    def admission_order(self) -> List[Request]:
        """Waiting requests in the order eq. 3's prefill budget admits them:
        interactive class before batch, higher `priority` first within a
        class, queue position (FCFS, with preempted requests re-queued at
        the front) within a priority.  The sort is stable, so a queue of
        all-default requests admits in exactly the pre-SLO FCFS order —
        which keeps recorded traces replaying bit-identically."""
        return sorted(self.waiting,
                      key=lambda r: (r.slo_rank, -r.priority))

    def _schedule_prefill(self, now: float, num_decode: int) -> List[ScheduledSeq]:
        if self.cfg.policy is PrefillPolicy.SARATHI:
            budget = max(0, self.cfg.max_prefill_tokens - num_decode)
        else:
            budget = prefill_budget(
                self.num_waiting_prefill_tokens, self.kv.kv_free_rate, self.cfg
            )
        self._last_prefill_budget = budget             # raw eq. 3 decision
        self._last_cached_tokens = 0
        if budget <= 0:
            return []

        out: List[ScheduledSeq] = []

        # 1) continue chunked prefills already in progress (not in flight)
        for req in self.running_prefill:
            if budget <= 0 or len(out) >= self.max_prefill_seqs:
                break
            if req.request_id in self._in_flight:
                continue
            took = self._take_prefill_chunk(req, budget, now)
            if took is None:
                break  # KV exhausted: stop prefill scheduling entirely
            out.append(took)
            budget -= took.num_tokens

        # 2) admit new requests from the waiting queue, SLO-class order
        admitted: set = set()
        for req in self.admission_order():
            if budget <= 0 or len(out) >= min(
                    self.max_batch_seqs, self.max_prefill_seqs):
                break
            if self.cfg.policy is not PrefillPolicy.SARATHI:
                # UT guard: don't admit when below the KV idle threshold.
                if self.kv.kv_free_rate <= self.cfg.kv_threshold:
                    break
            # prefix-cache reuse on first chunk
            adopted = 0
            if req.num_prefilled == 0 and self.kv.enable_prefix_caching \
                    and not self.kv.has_request(req.request_id):
                self.stats.prefix_lookups += 1
                cached, pages = self.kv.match_prefix(req.effective_prompt[:-1])
                if cached:
                    self.kv.adopt_prefix(req.request_id, cached, pages)
                    req.num_prefilled = cached
                    adopted = cached
            took = self._take_prefill_chunk(req, budget, now)
            if took is None:
                if adopted:
                    # Release-on-stall: the chunk could not take even one
                    # token (KV exhausted), so the request stays WAITING —
                    # it must not pin the adopted head under the very KV
                    # pressure that stalled it.  The pages return to the
                    # evictable LRU still hashed, so a later admission
                    # re-matches them for free.  Invariant restored: a
                    # WAITING request never holds KV.
                    self.kv.free(req.request_id)
                    req.num_prefilled = 0
                break
            if adopted:
                self.stats.prefix_hits += 1
                self.stats.prefix_tokens_avoided += adopted
                self._last_cached_tokens += adopted
            admitted.add(req.request_id)
            req.state = RequestState.PREFILLING
            if req.metrics.first_scheduled_time is None:
                req.metrics.first_scheduled_time = now
            if not took.produces_token:
                self.running_prefill.append(req)
            out.append(took)
            budget -= took.num_tokens
        if admitted:
            # one O(n) rebuild instead of an O(n) deque.remove per admission
            # — the tick loop stays linear in queue depth
            self.waiting = deque(r for r in self.waiting
                                 if r.request_id not in admitted)
        return out

    def _take_prefill_chunk(
        self, req: Request, budget: int, now: float
    ) -> Optional[ScheduledSeq]:
        chunk = min(req.remaining_prefill_tokens, budget,
                    self.max_chunk_tokens)
        if chunk <= 0:
            return None
        if not self.kv.can_allocate(req.request_id, chunk):
            # Shrink to what fits rather than stalling completely.
            cur = self.kv.num_tokens(req.request_id)
            slack = (self.kv.page_size - cur % self.kv.page_size) % self.kv.page_size
            headroom = slack + self.kv.num_free_pages * self.kv.page_size
            chunk = min(chunk, headroom)
            if chunk <= 0:
                return None
        slots = self.kv.allocate(req.request_id, chunk)
        seq = ScheduledSeq(req, req.num_prefilled, chunk, True, slots)
        if req in self.running_prefill and seq.produces_token:
            self.running_prefill.remove(req)
        return seq

    # ---------------------------------------------------------------- complete
    def complete(
        self,
        batch_id: int,
        sampled_tokens: Sequence[int],
        now: float = 0.0,
    ) -> List[Request]:
        """Apply results of a finished micro-batch.

        `sampled_tokens` has one token per token-producing seq, in batch order
        (prefill entries first, then decode), matching `produces_token`.
        Returns requests that finished this tick.
        """
        batch = self._batches.pop(batch_id)
        finished: List[Request] = []
        it = iter(sampled_tokens)
        for seq in batch.seqs:
            req = seq.request
            self._in_flight.pop(req.request_id, None)
            # The step wrote KV for every token it consumed (prefill chunk, or
            # the single consumed token of a decode step).
            req.num_prefilled = seq.start_pos + seq.num_tokens
            if req.request_id in self._aborting:
                # aborted while this micro-batch was in flight: consume the
                # sampled token (alignment), but discard it — the user asked
                # for the request to stop, so nothing is recorded
                self._aborting.discard(req.request_id)
                if seq.produces_token:
                    next(it)
                for group in (self.running_prefill, self.running_decode):
                    if req in group:
                        group.remove(req)
                self._finalize_abort(req, now)
                finished.append(req)
                continue
            if not seq.produces_token:
                continue
            if seq.is_prefill and self.kv.enable_prefix_caching:
                # chunk completed the (effective) prompt -> freeze full pages
                self.kv.freeze_full_pages(req.request_id, req.effective_prompt)
            token = int(next(it))
            req.record_new_token(token, now)
            if req.is_finished:
                self.kv.free(req.request_id)
                if req in self.running_decode:
                    self.running_decode.remove(req)
                finished.append(req)
            elif seq.is_prefill:
                req.state = RequestState.DECODING
                self.running_decode.append(req)
        remaining = sum(1 for _ in it)
        assert remaining == 0, f"{remaining} unconsumed sampled tokens"
        self.stats.note_retire(len(sampled_tokens), now)
        return finished

    # ------------------------------------------------------------------ abort
    def abort_request(self, request_id: str, now: float = 0.0
                      ) -> Optional[Request]:
        """User-initiated abort, wherever the request stands.

        Waiting and running (not-in-flight) requests finalize immediately:
        KV pages freed, state -> FINISHED_ABORTED.  A request inside an
        in-flight micro-batch cannot be torn down mid-tick (its KV writes are
        still materializing on device); it is flagged and finalized by
        `complete()` when the batch retires, appearing in that tick's
        finished list.  Returns the request (check `is_finished` to tell
        immediate from deferred), or None when unknown / already finished.

        Callers owning backend state must release it for immediately-
        finalized requests (`ExecutionBackend.finish_request`); deferred ones
        flow through the TickLoop's normal retire path.
        """
        if request_id in self._aborting:
            return None
        if request_id in self._in_flight:
            batch = self._batches[self._in_flight[request_id]]
            for seq in batch.seqs:
                if seq.request.request_id == request_id:
                    self._aborting.add(request_id)
                    return seq.request
            return None
        for req in self.waiting:
            if req.request_id == request_id:
                self.waiting.remove(req)
                self._finalize_abort(req, now)
                return req
        for group in (self.running_prefill, self.running_decode):
            for req in group:
                if req.request_id == request_id:
                    group.remove(req)
                    self._finalize_abort(req, now)
                    return req
        return None

    def _finalize_abort(self, req: Request, now: float) -> None:
        """KV pages released (a waiting request may still hold an adopted
        prefix-cache head), terminal state + finish time stamped."""
        self.kv.free(req.request_id)
        req.state = RequestState.FINISHED_ABORTED
        req.metrics.finish_time = now

    # -------------------------------------------------------------- migration
    def drain_request(self, request_id: str) -> Optional[Request]:
        """Remove a request from this scheduler for live migration.

        Only requests *not* in an in-flight micro-batch can be drained (a
        resident micro-batch's KV writes are still materializing on device);
        returns None for those — the control plane retries next pass.  The
        request's KV stays resident: the migrator exports/frees it
        explicitly (`PagedKVManager.export_kv`), so a failed transfer can
        re-adopt locally without losing state.
        """
        if request_id in self._in_flight:
            return None
        for group in (self.running_decode, self.running_prefill):
            for req in group:
                if req.request_id == request_id:
                    group.remove(req)
                    return req
        for req in self.waiting:
            if req.request_id == request_id:
                self.waiting.remove(req)
                # A WAITING request owns no migratable state: if it holds an
                # adopted prefix-cache head, release it here (the pages stay
                # hashed in the evictable LRU) and let the destination
                # re-match against *its* cache at admission.  Without this
                # the steal path strands the source block table and the
                # destination's `adopt_request` rejects the orphaned
                # `num_prefilled` count.
                if self.kv.has_request(request_id):
                    self.kv.free(request_id)
                    req.num_prefilled = 0
                return req
        return None

    def adopt_request(self, req: Request) -> None:
        """Admit a drained request at its *current position* (no recompute).

        The caller must have imported the request's KV first
        (`PagedKVManager.import_kv`): every token counted by
        `req.num_prefilled` needs resident KV here.  The request resumes in
        the queue its progress implies — decoding, mid-prefill, or waiting.
        """
        rid = req.request_id
        if req.is_finished:
            raise ValueError(f"request {rid} already finished")
        resident = self.kv.num_tokens(rid)
        if resident != req.num_prefilled:
            raise ValueError(
                f"request {rid}: {req.num_prefilled} prefilled tokens but "
                f"{resident} with resident KV — import_kv before adopt")
        # Placement follows the drained *state*, not progress counters: a
        # DECODING request keeps one KV slot unwritten (its next decode step
        # consumes the newest sampled token), so counters alone cannot
        # distinguish it from a nearly-done prefill — and a WAITING request
        # with an adopted prefix head has num_prefilled > 0 without ever
        # having been admitted.  Only requests that were already admitted
        # (PREFILLING mid-chunk) may bypass the UT guard and SLO-class
        # admission order; everything else re-enters through `waiting`.
        if req.state is RequestState.DECODING:
            self.running_decode.append(req)
        elif req.state is RequestState.PREFILLING and req.num_prefilled > 0:
            self.running_prefill.append(req)
        else:
            req.state = RequestState.WAITING
            self.waiting.append(req)

    def steal_candidates(self) -> List[Request]:
        """Waiting requests a rebalancer may take, cheapest-first: stolen
        from the *tail* (last arrivals — FCFS order of the remainder is
        preserved).  Requests that already hold KV here (an adopted prefix-
        cache head) are skipped: stealing them would strand pages."""
        return [r for r in reversed(self.waiting)
                if not self.kv.has_request(r.request_id)]

    # ----------------------------------------------------------- fault paths
    def abort_batch(self, batch_id: int, now: float = 0.0) -> List[Request]:
        """A worker died mid-flight: the micro-batch's results never arrive.
        Affected requests recover by recompute — decode/partial-prefill
        requests are preempted (KV freed, re-queued with priority); their
        already-generated tokens are preserved (recompute re-prefills them).
        Requests with a pending user abort finalize it instead of requeuing.
        Returns the affected requests (check `is_finished` for the aborted
        ones — they need backend release, not recompute)."""
        batch = self._batches.pop(batch_id, None)
        if batch is None:
            return []
        affected = []
        for seq in batch.seqs:
            req = seq.request
            self._in_flight.pop(req.request_id, None)
            if req.is_finished:
                continue
            if req.request_id in self._aborting:
                # the user had already asked for this request to stop: the
                # fault finalizes the abort instead of queueing a recompute
                self._aborting.discard(req.request_id)
                for group in (self.running_prefill, self.running_decode):
                    if req in group:
                        group.remove(req)
                self._finalize_abort(req, now)
                affected.append(req)
                continue
            self.kv.free(req.request_id)
            if req in self.running_decode:
                self.running_decode.remove(req)
            if req in self.running_prefill:
                self.running_prefill.remove(req)
            req.preempt()
            req.state = RequestState.WAITING
            if req not in self.waiting:
                self.waiting.appendleft(req)
            self.stats.preemptions += 1
            if self.on_preempt is not None:
                self.on_preempt(req)
            affected.append(req)
        return affected

    # ------------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        self.kv.check_invariants()
        ids = [r.request_id for r in self.running_decode]
        assert len(ids) == len(set(ids)), "duplicate request in running_decode"
        for rid in self._in_flight:
            assert self._in_flight[rid] in self._batches
