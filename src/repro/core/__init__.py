"""gLLM core: Token Throttling scheduling + paged KV management."""

from repro.core.kv_manager import KVExport, PagedKVManager
from repro.core.request import (
    SLO_BATCH,
    SLO_CLASSES,
    SLO_INTERACTIVE,
    Request,
    RequestMetrics,
    RequestState,
    SamplingParams,
)
from repro.core.scheduler import (
    PipelineScheduler,
    ScheduledBatch,
    ScheduledSeq,
    SchedulerStats,
)
from repro.core.throttle import (
    PrefillPolicy,
    ThrottleConfig,
    decode_budget,
    prefill_budget,
    prefill_budget_ut,
    prefill_budget_wt,
)

__all__ = [
    "KVExport",
    "PagedKVManager",
    "Request",
    "RequestMetrics",
    "RequestState",
    "SamplingParams",
    "SLO_BATCH",
    "SLO_CLASSES",
    "SLO_INTERACTIVE",
    "PipelineScheduler",
    "ScheduledBatch",
    "ScheduledSeq",
    "SchedulerStats",
    "PrefillPolicy",
    "ThrottleConfig",
    "decode_budget",
    "prefill_budget",
    "prefill_budget_ut",
    "prefill_budget_wt",
]
