"""Fused grouped-expert SwiGLU — the MoE hot spot (kimi-k2: 384 experts).

Computes, per expert e over its capacity-padded token buffer:
    out[e] = (silu(x[e] @ w_gate[e]) * (x[e] @ w_up[e])) @ w_down[e]

The grid walks (expert, token-block, ff-block) with the ff dim minor: each
step computes one [Ct, ffb] hidden tile in VMEM and immediately contracts it
into the [Ct, d] accumulator — the [C, ff] hidden never exists in HBM (on
GPU this is the megablocks-style fusion; on TPU the MXU consumes the tile
straight from VMEM).  Tiles are MXU-aligned: Ct, ffb multiples of 128 ideal.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref,        # [1, Ct, d]
            wg_ref,       # [1, d, ffb]
            wu_ref,       # [1, d, ffb]
            wd_ref,       # [1, ffb, d]
            o_ref,        # [1, Ct, d]
            acc_ref,      # [Ct, d] f32
            *, num_ff_blocks: int):
    fb = pl.program_id(2)

    @pl.when(fb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                     # [Ct, d]
    g = jax.lax.dot_general(x, wg_ref[0], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu_ref[0], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)         # [Ct, ffb]
    acc_ref[...] += jax.lax.dot_general(
        h, wd_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(fb == num_ff_blocks - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("token_block", "ff_block",
                                              "interpret"))
def fused_moe_ffn(
    x: jax.Array,         # [E, C, d] capacity-padded per-expert buffers
    w_gate: jax.Array,    # [E, d, ff]
    w_up: jax.Array,      # [E, d, ff]
    w_down: jax.Array,    # [E, ff, d]
    *,
    token_block: int = 128,
    ff_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    E, C, d = x.shape
    ff = w_gate.shape[-1]
    Ct = min(token_block, C)
    ffb = min(ff_block, ff)
    assert C % Ct == 0 and ff % ffb == 0, (C, Ct, ff, ffb)
    grid = (E * (C // Ct), 1, ff // ffb)

    def x_index(ec, _, fb):
        return (ec // (C // Ct), ec % (C // Ct), 0)

    def wg_index(ec, _, fb):
        return (ec // (C // Ct), 0, fb)

    def wd_index(ec, _, fb):
        return (ec // (C // Ct), fb, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, num_ff_blocks=ff // ffb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Ct, d), x_index),
            pl.BlockSpec((1, d, ffb), wg_index),
            pl.BlockSpec((1, d, ffb), wg_index),
            pl.BlockSpec((1, ffb, d), wd_index),
        ],
        out_specs=pl.BlockSpec((1, Ct, d), x_index),
        scratch_shapes=[pltpu.VMEM((Ct, d), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((E, C, d), x.dtype),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
    return out
