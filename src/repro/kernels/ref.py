"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_flash_attention_ref(
    q: jax.Array,             # [S, TQ, H, D]
    kv_pages: jax.Array,      # [P, page, 2, KH, D]
    block_tables: jax.Array,  # [S, B]
    context_lens: jax.Array,  # [S]
    q_positions: jax.Array,   # [S, TQ]
) -> jax.Array:
    S, TQ, H, D = q.shape
    _, page, _, KH, _ = kv_pages.shape
    B = block_tables.shape[1]
    G = H // KH
    gathered = kv_pages[block_tables]                  # [S, B, page, 2, KH, D]
    kv = gathered.reshape(S, B * page, 2, KH, D).astype(jnp.float32)
    k, v = kv[:, :, 0], kv[:, :, 1]
    kpos = jnp.arange(B * page)
    mask = (kpos[None, None, :] < context_lens[:, None, None]) & \
           (kpos[None, None, :] <= q_positions[:, :, None])     # [S, TQ, Bp]
    qf = q.astype(jnp.float32).reshape(S, TQ, KH, G, D)
    scores = jnp.einsum("sqhgd,skhd->sqhgk", qf, k) * (D ** -0.5)
    scores = jnp.where(mask[:, :, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("sqhgk,skhd->sqhgd", p, v)
    return out.reshape(S, TQ, H, D).astype(q.dtype)


def rwkv6_scan_ref(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,  # [B, T, H, D]
    u: jax.Array,                                            # [H, D]
) -> jax.Array:
    B, T, H, D = r.shape

    def step(S, inp):
        r_t, k_t, v_t, w_t = (x.astype(jnp.float32) for x in inp)
        kv = k_t[..., :, None] * v_t[..., None, :]    # [B, H, D, D]
        o = jnp.einsum("bhk,bhkv->bhv", r_t,
                       S + u[None].astype(jnp.float32)[..., :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, o

    S0 = jnp.zeros((B, H, D, D), jnp.float32)
    _, os = jax.lax.scan(step, S0,
                         tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, w)))
    return jnp.moveaxis(os, 0, 1).astype(r.dtype)


def fused_moe_ffn_ref(x, w_gate, w_up, w_down):
    """x [E, C, d]; weights [E, d, ff] / [E, ff, d]."""
    g = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w_gate.astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w_up.astype(jnp.float32))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(jnp.float32))
    return out.astype(x.dtype)


def mamba_scan_ref(dA, dBx, C):
    """Sequential oracle: h_t = dA_t*h + dBx_t ; y_t = C_t . h_t.
    dA/dBx [B, T, di, ds]; C [B, T, ds] -> y [B, T, di]."""
    B, T, di, ds = dA.shape

    def step(h, inp):
        dA_t, dBx_t, C_t = (x.astype(jnp.float32) for x in inp)
        h = dA_t * h + dBx_t
        return h, jnp.einsum("bcs,bs->bc", h, C_t)

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         tuple(jnp.moveaxis(x, 1, 0) for x in (dA, dBx, C)))
    return jnp.moveaxis(ys, 0, 1).astype(dA.dtype)
