"""Pallas TPU kernels for the serving hot spots (DESIGN.md §6).

Each kernel: pl.pallas_call + explicit BlockSpec VMEM tiling; ops.py is
the dispatch layer (TPU kernel / CPU interpret / jnp oracle) and ref.py
holds the pure-jnp oracles the tests sweep against."""

from repro.kernels.mamba_scan import mamba_chunked_scan
from repro.kernels.moe_gemm import fused_moe_ffn
from repro.kernels.paged_attention import paged_flash_attention
from repro.kernels.rwkv6_scan import rwkv6_chunked_scan

__all__ = ["fused_moe_ffn", "mamba_chunked_scan",
           "paged_flash_attention", "rwkv6_chunked_scan"]
