"""Dispatch layer: Pallas kernels on TPU, interpret-mode on CPU, jnp oracle
as the portable fallback.

The model code (`repro.models.attention` / `repro.models.ssm`) uses the pure
jnp path by default — identical math, XLA-fused — and flips to these kernels
on real TPU via `use_kernels()`.  The dry-run always lowers the jnp path
(Pallas TPU kernels cannot lower for the CPU backend); kernels are validated
in interpret mode by the test suite.
"""

from __future__ import annotations

import os

import jax

from repro.kernels.paged_attention import paged_flash_attention
from repro.kernels.rwkv6_scan import rwkv6_chunked_scan
from repro.kernels import ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_kernels() -> bool:
    env = os.environ.get("REPRO_USE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "")
    return on_tpu()


def paged_attention(q, kv_pages, block_tables, context_lens, q_positions,
                    *, interpret: bool = False):
    """Decode/prefill paged attention: kernel on TPU, oracle elsewhere."""
    if use_kernels() or interpret:
        return paged_flash_attention(
            q, kv_pages, block_tables, context_lens, q_positions,
            interpret=interpret or not on_tpu())
    return ref.paged_flash_attention_ref(
        q, kv_pages, block_tables, context_lens, q_positions)


def rwkv6_scan(r, k, v, w, u, *, chunk: int = 64, interpret: bool = False):
    if use_kernels() or interpret:
        return rwkv6_chunked_scan(r, k, v, w, u, chunk=chunk,
                                  interpret=interpret or not on_tpu())
    return ref.rwkv6_scan_ref(r, k, v, w, u)
