"""RWKV6 (Finch) chunked linear-recurrence kernel — the ssm-family hot spot.

Recurrence (per head, f32 state):
    o_t = r_t · (S_{t-1} + diag(u) k_t vᵀ_t)
    S_t = diag(w_t) S_{t-1} + k_t vᵀ_t          (w_t: data-dependent decay)

Chunked form (length-L chunk; P_t = prod_{i<=t} w_i, cumulative within the
chunk): intra-chunk work becomes two MXU matmuls plus a causal mask,
inter-chunk state carries as one rank-Dk update —

    o = (r ⊙ P_prev) @ S_0 + tril(A) @ V + diag-term
    A = (r ⊙ P_prev) @ (k / P)ᵀ,  diag = (r · (u ⊙ k)) per row
    S_L = P_L ⊙ S_0 + (k ⊙ P_L/P)ᵀ @ V

The grid walks (batch, head, chunk) with the chunk dim minor so the state
scratch persists across chunks in VMEM (sequential-grid carry — the TPU
analogue of the GPU kernel's inter-block state in L2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref,      # [1, Tc, 1, D] / [1, D]
            o_ref,                                   # [1, Tc, 1, D]
            state_ref,                               # [D, D] f32 scratch
            *, num_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)        # [Tc, Dk]
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)        # [Tc, Dv]
    w = w_ref[0, :, 0, :].astype(jnp.float32)        # [Tc, Dk] decay in (0,1]
    u = u_ref[0].astype(jnp.float32)                 # [Dk]
    Tc = r.shape[0]

    logw = jnp.log(jnp.maximum(w, 1e-30))
    logP = jnp.cumsum(logw, axis=0)                  # inclusive  [Tc, Dk]
    P = jnp.exp(logP)
    P_prev = jnp.exp(logP - logw)                    # exclusive prefix
    P_last = jnp.exp(logP[-1])[None, :]              # [1, Dk]

    rP = r * P_prev                                  # [Tc, Dk]
    kQ = k * jnp.exp(-logP)                          # k / P
    S0 = state_ref[...]

    A = jax.lax.dot_general(rP, kQ, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Tc, Tc]
    row = jax.lax.broadcasted_iota(jnp.int32, (Tc, Tc), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Tc, Tc), 1)
    A = jnp.where(row > col, A, 0.0)                 # strictly causal (j < t)
    diag = jnp.sum(r * (u[None, :] * k), axis=-1)    # [Tc]

    o = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o = o + diag[:, None] * v
    o = o + jax.lax.dot_general(rP, S0, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    kS = k * jnp.exp(logP[-1][None, :] - logP)       # k * P_L / P
    S_new = P_last.T * S0 + jax.lax.dot_general(
        kS, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_ref[...] = S_new
    o_ref[0, :, 0, :] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunked_scan(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,  # [B, T, H, D]
    u: jax.Array,                                            # [H, D]
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    B, T, H, D = r.shape
    Tc = min(chunk, T)
    assert T % Tc == 0, (T, Tc)
    grid = (B, H, T // Tc)

    def seq_index(b, h, c):
        return (b, c, h, 0)

    def u_index(b, h, c):
        return (h, 0)

    spec = pl.BlockSpec((1, Tc, 1, D), seq_index)
    out = pl.pallas_call(
        functools.partial(_kernel, num_chunks=T // Tc),
        grid=grid,
        in_specs=[spec, spec, spec, spec, pl.BlockSpec((1, D), u_index)],
        out_specs=spec,
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((B, T, H, D), r.dtype),
        interpret=interpret,
    )(r, k, v, w, u)
    return out
