"""Mamba (S6) blocked selective scan — jamba's recurrent hot spot.

Recurrence (per channel c, state dim s):
    h_t = dA_t ⊙ h_{t-1} + dBx_t ;   y_t = Σ_s C_t[s] · h_t[c, s]

The grid walks (batch, channel-block, chunk) with the chunk dim minor: the
[Cb, ds] state persists in VMEM scratch across chunks, and within a chunk the
recurrence is evaluated by a log-depth Blelloch-style doubling scan on the
(dA, dBx) pairs held entirely in VMEM — the TPU analogue of mamba's CUDA
parallel scan (warp shuffles → in-register vector ops on [L, Cb, ds] tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dA_ref,       # [1, L, Cb, ds]
            dBx_ref,      # [1, L, Cb, ds]
            C_ref,        # [1, L, ds]
            o_ref,        # [1, L, Cb]
            h_ref,        # [Cb, ds] f32 scratch (carried across chunks)
            *, num_chunks: int, chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    dA = dA_ref[0].astype(jnp.float32)               # [L, Cb, ds]
    dBx = dBx_ref[0].astype(jnp.float32)
    Cm = C_ref[0].astype(jnp.float32)                # [L, ds]
    L = dA.shape[0]

    # in-chunk inclusive scan by doubling: (a, b) ∘ (a', b') = (aa', a'b + b')
    a, b = dA, dBx
    shift = 1
    while shift < L:
        a_prev = jnp.pad(a, ((shift, 0), (0, 0), (0, 0)),
                         constant_values=1.0)[:L]
        b_prev = jnp.pad(b, ((shift, 0), (0, 0), (0, 0)))[:L]
        b = a * b_prev + b
        a = a * a_prev
        shift *= 2

    h0 = h_ref[...]                                  # [Cb, ds]
    hs = a * h0[None] + b                            # [L, Cb, ds]
    y = jnp.einsum("lcs,ls->lc", hs, Cm)
    o_ref[0] = y.astype(o_ref.dtype)
    h_ref[...] = hs[-1]


@functools.partial(jax.jit, static_argnames=("chunk", "channel_block",
                                              "interpret"))
def mamba_chunked_scan(
    dA: jax.Array,        # [B, T, di, ds] discretized decay
    dBx: jax.Array,       # [B, T, di, ds] input contribution
    C: jax.Array,         # [B, T, ds]     read-out
    *,
    chunk: int = 128,
    channel_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Returns y [B, T, di] = C_t · h_t with h the selective-scan state."""
    B, T, di, ds = dA.shape
    L = min(chunk, T)
    Cb = min(channel_block, di)
    assert T % L == 0 and di % Cb == 0, (T, L, di, Cb)
    grid = (B, di // Cb, T // L)

    def x_index(b, cb, c):
        return (b, c, cb, 0)

    def c_index(b, cb, c):
        return (b, c, 0)

    def o_index(b, cb, c):
        return (b, c, cb)

    spec = pl.BlockSpec((1, L, Cb, ds), x_index)
    out = pl.pallas_call(
        functools.partial(_kernel, num_chunks=T // L, chunk=L),
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec((1, L, ds), c_index)],
        out_specs=pl.BlockSpec((1, L, Cb), o_index),
        scratch_shapes=[pltpu.VMEM((Cb, ds), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((B, T, di), dA.dtype),
        interpret=interpret,
    )(dA, dBx, C)
    return out
