"""Paged flash attention — the serving hot spot, TPU-native.

One kernel serves both phases (the gLLM merged micro-batch):
  * decode:  q [S, 1, H, D]   — one new token against a 32k-page context
  * prefill: q [S, C, H, D]   — a throttled chunk, causal vs. its positions

TPU adaptation of the vLLM GPU kernel (DESIGN.md §6): the block-table
indirection moves into the BlockSpec index_map via scalar prefetch — the
grid walks (seq, q-block, page) and the KV BlockSpec *fetches page
`tables[s, b]` from HBM into VMEM* while the previous page is being
consumed (hardware double-buffering replaces the GPU's manual smem staging).
Online softmax state lives in VMEM scratch across the minor (page) grid dim.
All tiles are (8,128)-aligned: D = head_dim = 128/96/64, page >= 8.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # scalar prefetch
    tables_ref,            # [S * B] int32 (flattened block tables)
    ctx_ref,               # [S] int32 context lens
    live_ref,              # [S] int32 live pages per sequence
    # inputs
    q_ref,                 # [1, TQ, H, D]
    qpos_ref,              # [1, TQ] int32 global positions
    kv_ref,                # [1, page, 2, KH, D] — page tables[s, b]
    # outputs
    o_ref,                 # [1, TQ, H, D]
    # scratch
    acc_ref,               # [TQ, H, D] f32
    m_ref,                 # [TQ, H] f32
    l_ref,                 # [TQ, H] f32
    *,
    kv_heads: int,
    page: int,
    num_pages: int,
):
    s, qb, b = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(b == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Dead-page skip: pages at or past live_ref[s] hold no in-context keys,
    # so their masked contribution is exactly zero (every score is NEG_INF,
    # which after the running-max subtraction underflows to p == 0.0 and
    # alpha == 1.0).  Skipping the whole update is therefore bit-identical
    # while saving the MXU work; the index_map already clamps the DMA to the
    # last live page so no extra HBM traffic happens either.
    @pl.when(b < live_ref[s])
    def _update():
        q = q_ref[0].astype(jnp.float32)                # [TQ, H, D]
        TQ, H, D = q.shape
        KH = kv_heads
        G = H // KH
        kv = kv_ref[0].astype(jnp.float32)              # [page, 2, KH, D]
        k, v = kv[:, 0], kv[:, 1]                       # [page, KH, D]

        kpos = b * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
        ctx = ctx_ref[s]
        qpos = qpos_ref[0]                              # [TQ]
        mask = (kpos[None, :] < ctx) & (kpos[None, :] <= qpos[:, None])

        scale = D ** -0.5
        parts = []
        for kh in range(KH):
            qg = q[:, kh * G:(kh + 1) * G, :].reshape(TQ * G, D)
            sc = jax.lax.dot_general(qg, k[:, kh, :],
                                     (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            parts.append(sc.reshape(TQ, G, page))
        scores = jnp.concatenate(parts, axis=1) * scale  # [TQ, H, page]
        scores = jnp.where(mask[:, None, :], scores, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])          # [TQ, H, page]
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = m_new

        pv_parts = []
        for kh in range(KH):
            pg = p[:, kh * G:(kh + 1) * G, :].reshape(TQ * G, page)
            pv = jax.lax.dot_general(pg, v[:, kh, :],
                                     (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            pv_parts.append(pv.reshape(TQ, G, D))
        pv = jnp.concatenate(pv_parts, axis=1)          # [TQ, H, D]
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    @pl.when(b == num_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "q_block"))
def paged_flash_attention(
    q: jax.Array,            # [S, TQ, H, D]
    kv_pages: jax.Array,     # [P, page, 2, KH, D]
    block_tables: jax.Array, # [S, B] int32
    context_lens: jax.Array, # [S] int32
    q_positions: jax.Array,  # [S, TQ] int32
    *,
    q_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    S, TQ, H, D = q.shape
    P, page, _, KH, _ = kv_pages.shape
    B = block_tables.shape[1]
    tq = min(q_block, TQ)
    assert TQ % tq == 0, (TQ, tq)

    grid = (S, TQ // tq, B)

    # Pages >= ceil(ctx / page) hold no in-context keys; the kernel skips
    # them (bit-identically — see _kernel) and the index_map re-fetches the
    # last live page instead of streaming dead ones from HBM.
    live_pages = jnp.minimum(
        jax.lax.div(context_lens + (page - 1), page), B).astype(jnp.int32)

    def q_index(s, qb, b, tables, ctx, live):
        return (s, qb, 0, 0)

    def pos_index(s, qb, b, tables, ctx, live):
        return (s, qb)

    def kv_index(s, qb, b, tables, ctx, live):
        bb = jnp.minimum(b, jnp.maximum(live[s] - 1, 0))
        return (tables[s * B + bb], 0, 0, 0, 0)

    kernel = functools.partial(_kernel, kv_heads=KH, page=page, num_pages=B)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, tq, H, D), q_index),
                pl.BlockSpec((1, tq), pos_index),
                pl.BlockSpec((1, page, 2, KH, D), kv_index),
            ],
            out_specs=pl.BlockSpec((1, tq, H, D), q_index),
            scratch_shapes=[
                pltpu.VMEM((tq, H, D), jnp.float32),
                pltpu.VMEM((tq, H), jnp.float32),
                pltpu.VMEM((tq, H), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((S, TQ, H, D), q.dtype),
        interpret=interpret,
    )(block_tables.reshape(-1), context_lens, live_pages, q, q_positions,
      kv_pages)
    return out
