"""repro.serving — the public serving API (DESIGN.md §10).

One declarative spec (`ServeSpec`), one factory (`build`), one client
surface (`LLMServer` with `generate` / `generate_stream` / `abort`),
whatever the execution substrate: live engine, roofline simulator,
recorded-trace replay, or a globally-balanced multi-replica cluster.

    from repro.serving import ServeSpec, SamplingParams, build

    server = build(ServeSpec())                    # a reduced engine
    out = server.generate([1, 2, 3], SamplingParams(max_new_tokens=8))
    print(out.token_ids, out.finish_reason)
"""

from repro.core import SLO_BATCH, SLO_CLASSES, SLO_INTERACTIVE, SamplingParams
from repro.runtime.disagg import ROLES, HandoffPolicy
from repro.runtime.router import RebalancePolicy, ReplicaCapacity
from repro.serving.build import build
from repro.serving.http import HTTPFrontend
from repro.serving.server import (
    EVENT_PREEMPT,
    EVENT_PREEMPT_RESUMED,
    FINISH_ABORT,
    FINISH_LENGTH,
    FINISH_STOP,
    LLMServer,
    ReplicaStats,
    RequestOutput,
    ServerStats,
    TokenDelta,
)
from repro.serving.spec import (
    ClusterSpec,
    EngineSpec,
    ServeSpec,
    SimSpec,
    TraceSpec,
)

__all__ = [
    "SamplingParams",
    "SLO_BATCH",
    "SLO_CLASSES",
    "SLO_INTERACTIVE",
    "RebalancePolicy",
    "ReplicaCapacity",
    "HandoffPolicy",
    "ROLES",
    "build",
    "HTTPFrontend",
    "LLMServer",
    "RequestOutput",
    "TokenDelta",
    "ReplicaStats",
    "ServerStats",
    "FINISH_STOP",
    "FINISH_LENGTH",
    "FINISH_ABORT",
    "EVENT_PREEMPT",
    "EVENT_PREEMPT_RESUMED",
    "ClusterSpec",
    "EngineSpec",
    "SimSpec",
    "TraceSpec",
    "ServeSpec",
]
