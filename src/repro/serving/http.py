"""HTTP serving frontend: the real frontend *process* over `LLMServer`
(DESIGN.md §11).

Stdlib-only (`http.server.ThreadingHTTPServer` — no new dependencies): one
handler thread per connection, all of them driving the one `LLMServer`
underneath.  Steps serialize on the server's lock, so N concurrent clients
interleave safely on any substrate a `ServeSpec` can build — the reduced
engine, the roofline simulator, or a multi-replica cluster (including
spec-declared heterogeneous ones via `ClusterSpec.sim_overrides`).

Endpoints (all bodies JSON):

  POST   /v1/generate            sync: {"prompt": [ids], ...} -> the
                                 finished request (token_ids, finish_reason,
                                 ttft/e2el metrics)
  POST   /v1/generate?stream=1   chunked SSE: one ``data:`` frame per
                                 `TokenDelta`, including ``event="preempt"``
                                 lifecycle frames; the last frame carries
                                 `finish_reason`
  DELETE /v1/requests/{rid}      abort a request anywhere in its life
  GET    /v1/stats               the `LLMServer.stats()` snapshot: per-replica
                                 scheduler/KV signals incl. the service-rate
                                 EWMA and waiting-queue SLO-class composition

Request fields beyond ``prompt`` map 1:1 onto `SamplingParams` —
``max_new_tokens``, ``temperature``, ``top_k``, ``top_p``,
``stop_token_ids``, and the scheduling class: ``priority`` (int, higher
admits first within a class) and ``slo_class`` (``"interactive"`` |
``"batch"``) — which Token Throttling's admission and preemption honor
(core/scheduler.py, DESIGN.md §11).  OpenAI-compatible spellings are
accepted as aliases: ``max_tokens`` (= max_new_tokens), ``stop`` (= stop
token ids — prompts are token-id lists, so stops are too), and a
``"stream": true`` body field (= ``?stream=1``); non-streaming responses
carry an OpenAI-completions ``choices``/``usage`` shape alongside the
native fields.

Serve from the launcher::

    PYTHONPATH=src python -m repro.launch.serve --http 8000 \
        --spec examples/specs/sim.json        # or any flag combination

    curl -s localhost:8000/v1/generate -d '{"prompt": [1,2,3]}'
    curl -sN 'localhost:8000/v1/generate?stream=1' \
        -d '{"prompt": [1,2,3], "slo_class": "batch"}'
    curl -s -X DELETE localhost:8000/v1/requests/llm-0
    curl -s localhost:8000/v1/stats

or programmatically (`port=0` binds an ephemeral port — the test path)::

    frontend = HTTPFrontend(build(spec), port=0).start()
    ... requests against f"http://127.0.0.1:{frontend.port}" ...
    frontend.shutdown()
"""

from __future__ import annotations

import dataclasses
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional
from urllib.parse import parse_qs, urlparse

from repro.core import SamplingParams
from repro.serving.server import LLMServer, RequestOutput, TokenDelta

# SamplingParams fields settable over the wire, with their coercions.
_SAMPLING_FIELDS = {
    "max_new_tokens": int,
    "temperature": float,
    "top_k": int,
    "top_p": float,
    "stop_token_ids": lambda v: tuple(int(t) for t in v),
    "priority": int,
    "slo_class": str,
}

# OpenAI-compatible field names, accepted as aliases of the native ones
# (prompts stay token-id lists; `stop` is therefore a list of stop token
# ids, not strings).  `stream` may also arrive as a body field instead of
# the `?stream=1` query parameter.
_OPENAI_ALIASES = {
    "max_tokens": "max_new_tokens",
    "stop": "stop_token_ids",
}


class BadRequest(ValueError):
    """Client error: reported as a 400 with the message in the body."""


def sampling_from_json(body: Dict[str, Any]) -> SamplingParams:
    """`SamplingParams` from a request body's non-``prompt`` fields.
    OpenAI-style aliases (`max_tokens`, `stop`) map onto the native
    names; unknown fields are rejected (same contract as the spec layer:
    a typo'd knob must not silently serve a different request)."""
    kw = {}
    for name, value in body.items():
        if name in ("prompt", "request_id", "stream"):
            continue
        native = _OPENAI_ALIASES.get(name, name)
        co = _SAMPLING_FIELDS.get(native)
        if co is None:
            raise BadRequest(
                f"unknown request field {name!r}; expected prompt, "
                f"request_id, stream, one of {sorted(_SAMPLING_FIELDS)}, "
                f"or an alias {sorted(_OPENAI_ALIASES)}")
        if native in kw:
            raise BadRequest(
                f"field {name!r} duplicates {native!r}; send one or the "
                "other")
        try:
            kw[native] = co(value)
        except (TypeError, ValueError) as e:
            raise BadRequest(f"bad value for {name!r}: {e}")
    try:
        return SamplingParams(**kw)
    except ValueError as e:         # e.g. unknown slo_class
        raise BadRequest(str(e))


def stream_requested(body: Dict[str, Any], query: Dict[str, Any]) -> bool:
    """``?stream=1`` or an OpenAI-style ``"stream": true`` body field."""
    if query.get("stream", ["0"])[0] in ("1", "true"):
        return True
    flag = body.get("stream", False)
    if not isinstance(flag, bool):
        raise BadRequest('"stream" must be a JSON boolean')
    return flag


def _prompt_from_json(body: Dict[str, Any]) -> list:
    prompt = body.get("prompt")
    if not isinstance(prompt, list) or not prompt \
            or not all(isinstance(t, int) and not isinstance(t, bool)
                       for t in prompt):
        raise BadRequest('"prompt" must be a non-empty list of token ids')
    return prompt


def output_to_json(out: RequestOutput) -> Dict[str, Any]:
    """Finished-request body: OpenAI-completions-shaped (`choices` +
    `usage`) with the repo-native fields kept alongside, so both client
    generations read one response."""
    m = out.metrics
    return {
        "id": out.request_id,
        "object": "completion",
        "request_id": out.request_id,
        "prompt_tokens": len(out.prompt_token_ids),
        "token_ids": list(out.token_ids),
        "finish_reason": out.finish_reason,
        "choices": [{
            "index": 0,
            "token_ids": list(out.token_ids),
            "finish_reason": out.finish_reason,
        }],
        "usage": {
            "prompt_tokens": len(out.prompt_token_ids),
            "completion_tokens": len(out.token_ids),
            "total_tokens": len(out.prompt_token_ids) + len(out.token_ids),
        },
        "metrics": {
            "ttft": m.ttft(),
            "e2el": m.e2el(),
            "num_preemptions": m.num_preemptions,
        },
    }


def delta_to_json(delta: TokenDelta) -> Dict[str, Any]:
    return {
        "request_id": delta.request_id,
        "token": delta.token,
        "index": delta.index,
        "finish_reason": delta.finish_reason,
        "event": delta.event,
    }


def stats_to_json(stats) -> Dict[str, Any]:
    out: Dict[str, Any] = {
        "replicas": [dataclasses.asdict(r) for r in stats.replicas],
        "tokens_retired": stats.tokens_retired,
    }
    if stats.routed_counts is not None:
        out["routed_counts"] = list(stats.routed_counts)
    if stats.replica_ordinals is not None:
        out["replica_ordinals"] = list(stats.replica_ordinals)
    if stats.rebalance is not None:
        out["rebalance"] = dataclasses.asdict(stats.rebalance)
    if stats.disagg is not None:
        # disaggregated deployments (DESIGN.md §15): handoff counters plus
        # the per-role queue split operators watch to size the role ratio
        out["disagg"] = dataclasses.asdict(stats.disagg)
        out["queue_depth_by_role"] = stats.queue_depth_by_role
    if stats.fleet_size is not None:
        # elastic fleets (DESIGN.md §16): serving size, active drains,
        # retirements — plus the scaling event log when the autoscaler runs
        out["fleet_size"] = stats.fleet_size
        out["draining"] = stats.draining
        out["retired"] = stats.retired
    if stats.autoscale is not None:
        auto = dataclasses.asdict(stats.autoscale)
        auto["events"] = [list(e) for e in auto["events"]]
        out["autoscale"] = auto
    if stats.attainment_by_class is not None:
        out["attainment_by_class"] = stats.attainment_by_class
    return out


class _Handler(BaseHTTPRequestHandler):
    """One instance per connection; `llm` is set on the subclass by
    `HTTPFrontend`.  HTTP/1.1 so SSE can use chunked transfer encoding."""

    llm: LLMServer = None           # type: ignore[assignment]
    protocol_version = "HTTP/1.1"

    # ------------------------------------------------------------- plumbing
    def log_message(self, fmt, *args):  # quiet by default; tests and the
        pass                            # launcher print their own lines

    def _read_json(self) -> Dict[str, Any]:
        length = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(length) if length else b""
        if not raw:
            raise BadRequest("empty body; expected a JSON object")
        try:
            body = json.loads(raw)
        except json.JSONDecodeError as e:
            raise BadRequest(f"body is not valid JSON: {e}")
        if not isinstance(body, dict):
            raise BadRequest("body must be a JSON object")
        return body

    def _send_json(self, obj: Any, status: int = 200) -> None:
        data = json.dumps(obj).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _send_error_json(self, status: int, message: str) -> None:
        self._send_json({"error": message}, status=status)

    # --------------------------------------------------------------- routes
    def do_POST(self) -> None:
        url = urlparse(self.path)
        if url.path != "/v1/generate":
            self._send_error_json(404, f"no such endpoint: POST {url.path}")
            return
        try:
            body = self._read_json()
            prompt = _prompt_from_json(body)
            sampling = sampling_from_json(body)
            rid = body.get("request_id")
            stream = stream_requested(body, parse_qs(url.query))
            if stream:
                self._stream_generate(prompt, sampling, rid)
            else:
                out = self.llm.generate(prompt, sampling, request_id=rid)
                self._send_json(output_to_json(out))
        except BadRequest as e:
            self._send_error_json(400, str(e))
        except ValueError as e:     # substrate admission errors (too long…)
            self._send_error_json(400, str(e))

    def do_DELETE(self) -> None:
        url = urlparse(self.path)
        prefix = "/v1/requests/"
        if not url.path.startswith(prefix) or url.path == prefix:
            self._send_error_json(404, f"no such endpoint: DELETE {url.path}")
            return
        rid = url.path[len(prefix):]
        found = self.llm.abort(rid)
        if not found:
            self._send_error_json(404, f"unknown request id {rid!r}")
            return
        self._send_json({"request_id": rid, "aborted": True})

    def do_GET(self) -> None:
        url = urlparse(self.path)
        if url.path == "/v1/stats":
            self._send_json(stats_to_json(self.llm.stats()))
            return
        if url.path == "/healthz":
            self._send_json({"ok": True})
            return
        self._send_error_json(404, f"no such endpoint: GET {url.path}")

    # ------------------------------------------------------------ streaming
    def _stream_generate(self, prompt, sampling,
                         rid: Optional[str]) -> None:
        """Chunked SSE: one ``data:`` frame per `TokenDelta`.  The handler
        thread itself steps the substrate (`LLMServer.stream`), so a lone
        streaming client makes progress without any background runner;
        concurrent handlers interleave on the step lock."""
        # submit happens here, eagerly — admission errors become a 400
        # (raised to do_POST) instead of a truncated event stream
        deltas = self.llm.stream(prompt, sampling, request_id=rid)
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()
        try:
            for delta in deltas:
                frame = ("data: " + json.dumps(delta_to_json(delta))
                         + "\n\n").encode()
                self._write_chunk(frame)
            self._write_chunk(b"")          # terminating 0-length chunk
        except (BrokenPipeError, ConnectionResetError):
            pass                            # client went away mid-stream

    def _write_chunk(self, data: bytes) -> None:
        self.wfile.write(f"{len(data):X}\r\n".encode() + data + b"\r\n")
        self.wfile.flush()


class HTTPFrontend:
    """The frontend process: a `ThreadingHTTPServer` over one `LLMServer`.

    `port=0` binds an ephemeral port (read it back from `.port`).  `start()`
    serves on a daemon thread and returns self — the programmatic/test
    path; `serve_forever()` blocks — the launcher path."""

    def __init__(self, server: LLMServer, host: str = "127.0.0.1",
                 port: int = 8000) -> None:
        self.llm = server
        handler = type("BoundHandler", (_Handler,), {"llm": server})
        self.httpd = ThreadingHTTPServer((host, port), handler)
        self.host, self.port = self.httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "HTTPFrontend":
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self.httpd.serve_forever()

    def shutdown(self) -> None:
        self.httpd.shutdown()
        if self._thread is not None:
            self._thread.join(timeout=5)
        self.httpd.server_close()
        self.llm.close()
