"""Declarative serving specs — the single construction language of the
public API (DESIGN.md §10).

Every serving scenario in this repo — a live engine on the mesh, the
calibrated discrete-event simulator, a recorded-trace replay, a
multi-replica cluster of either — is described by one `ServeSpec` value and
materialized by `repro.serving.build(spec)`.  Launchers, benchmarks, and
examples translate their flags into a spec instead of wiring
scheduler/KV/backend kwargs by hand, and a spec round-trips through JSON
(`to_json`/`from_json`) so a scenario can be checked in, diffed, and
reproduced byte-for-byte.

The spec layer is *pure data*: nothing here imports jax or touches a
device; all construction lives in `repro.serving.build`.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple, Union

from repro.runtime.autoscale import AutoscalePolicy
from repro.runtime.disagg import HandoffPolicy, validate_roles
from repro.runtime.router import RebalancePolicy, ReplicaCapacity

BACKENDS = ("engine", "sim", "trace")


@dataclass(frozen=True)
class EngineSpec:
    """What model to serve and under which throttle policy.

    `reduced=True` builds the same-family reduced config (the CPU-sized
    model every test and example runs); `reduced=False` uses the published
    config on the production mesh factoring from the arch's plan (TPU).
    `throttle` / `dims` are sparse overrides onto the backend's defaults
    (`ThrottleConfig` fields, `ServeDims` fields); `reduced_overrides` is
    passed to `make_reduced` (e.g. ``{"d_model": 128}``).

    `dispatch` selects the tick driving mode: ``"sync"`` (retire each
    batch the tick it exits — required for trace recording) or ``"async"``
    (double-buffered: retirement lags one tick so host prep overlaps
    device execution, DESIGN.md §12).  `bucketed=True` compiles the
    static-shape ladder and pads each tick to the smallest covering
    bucket instead of the full serve cell.
    """

    arch: str = "qwen1.5-0.5b"
    reduced: bool = True
    policy: str = "gllm"            # gllm | sarathi | no_wt | no_ut
    seed: int = 0
    throttle: Optional[Dict[str, Any]] = None
    dims: Optional[Dict[str, Any]] = None
    reduced_overrides: Optional[Dict[str, Any]] = None
    dispatch: str = "sync"          # sync | async (double-buffered ticks)
    bucketed: bool = False
    # Hash-chained full-page prefix caching (DESIGN.md §13): admission
    # adopts the longest cached prefix of each new request, skipping its
    # prefill; freed full pages stay matchable (LRU-evicted on pressure).
    enable_prefix_caching: bool = False

    def __post_init__(self) -> None:
        if self.dispatch not in ("sync", "async"):
            raise ValueError(
                f"unknown dispatch {self.dispatch!r}; expected 'sync' or "
                "'async'")


@dataclass(frozen=True)
class SimSpec:
    """Simulator geometry: the roofline cost model comes from
    `EngineSpec.arch`; these are the per-replica pipeline/KV shapes."""

    pp: int = 4
    pages: int = 2048
    page_size: int = 16
    runtime: str = "gllm"           # gllm | vllm (driver-overhead model)
    straggler_stage: Optional[int] = None
    straggler_factor: float = 1.0
    chips_per_stage: int = 1
    # Per-replica prefix caching (overridable via ClusterSpec.sim_overrides,
    # so a cluster can mix caching and non-caching replicas).
    enable_prefix_caching: bool = False


@dataclass(frozen=True)
class ClusterSpec:
    """Multi-replica layout: how many replicas, how requests are placed,
    whether the periodic control plane runs, optional static capacity
    hints (`ReplicaCapacity` or bare throughput scalars, one per replica),
    and — for sim clusters — per-replica `SimSpec` overrides.

    `sim_overrides` declares a heterogeneous cluster in the spec itself:
    one entry per replica, each either None (use the base `ServeSpec.sim`)
    or a sparse dict of `SimSpec` fields replacing the base values for that
    replica (e.g. ``({"pp": 8}, {"straggler_stage": 1,
    "straggler_factor": 2.0})``).  Unknown field names are rejected at
    construction — the same no-silent-typo contract as the JSON decoder.
    """

    replicas: int = 1
    route: str = "balanced"         # balanced | rr
    rebalance: Optional[RebalancePolicy] = None
    capacities: Optional[Tuple[Union[ReplicaCapacity, float], ...]] = None
    sim_overrides: Optional[Tuple[Optional[Dict[str, Any]], ...]] = None
    # Cache-aware routing strength: prefill-token credit per cached prompt
    # token when scoring a candidate replica (BalanceWeights.cache_affinity).
    # None keeps the router default (1.0); 0.0 routes load-only.  Inert
    # unless prefix caching is enabled on the replicas.
    cache_affinity: Optional[float] = None
    # Disaggregated serving (DESIGN.md §15): one role per replica —
    # "prefill" / "decode" / "mixed".  None means all mixed (the hybrid
    # throttled baseline).  Admission goes to prefill-capable replicas
    # only; `handoff` runs the first-decode KV transfer control plane
    # that ships freshly-prefilled requests to decode replicas.
    roles: Optional[Tuple[str, ...]] = None
    handoff: Optional[HandoffPolicy] = None
    # Cluster-scale elasticity (DESIGN.md §16): when set, the router runs
    # the autoscaler pass — `replicas` is the *initial* fleet size, and the
    # fleet grows/shrinks within [min_replicas, max_replicas].  New
    # replicas are built from the base `ServeSpec.sim` geometry (elastic
    # replicas are the homogeneous pool; sim_overrides shape only the
    # initial fleet).  Sim backend only: an engine cannot conjure devices.
    autoscale: Optional[AutoscalePolicy] = None

    def __post_init__(self) -> None:
        if self.replicas < 1:
            raise ValueError("ClusterSpec.replicas must be >= 1")
        if self.autoscale is not None and not (
                self.autoscale.min_replicas <= self.replicas
                <= self.autoscale.max_replicas):
            raise ValueError(
                f"ClusterSpec.replicas={self.replicas} must start inside "
                f"the autoscale range [{self.autoscale.min_replicas}, "
                f"{self.autoscale.max_replicas}]")
        if self.roles is not None:
            object.__setattr__(self, "roles",
                               validate_roles(self.roles, self.replicas))
        if self.capacities is not None:
            object.__setattr__(self, "capacities", tuple(self.capacities))
            if len(self.capacities) != self.replicas:
                raise ValueError("one capacity per replica")
        if self.sim_overrides is not None:
            object.__setattr__(self, "sim_overrides",
                               tuple(self.sim_overrides))
            if len(self.sim_overrides) != self.replicas:
                raise ValueError("one sim_overrides entry (dict or None) "
                                 "per replica")
            valid = {f.name for f in dataclasses.fields(SimSpec)}
            for i, ov in enumerate(self.sim_overrides):
                if ov is None:
                    continue
                unknown = sorted(set(ov) - valid)
                if unknown:
                    raise ValueError(
                        f"sim_overrides[{i}]: unknown SimSpec fields "
                        f"{unknown}")


@dataclass(frozen=True)
class TraceSpec:
    """Recording / replay of the run (DESIGN.md §8).

    `record` — path to record a replayable tick trace to (multi-replica
    engine runs write ``PATH.replicaN`` + ``PATH.router``; sim clusters
    treat it as a directory).  `replay` — path of a recorded trace to drive
    instead of a model: strict mode reproduces the recorded run
    bit-for-bit via `LLMServer.replay()`; `timing_only=True` serves *new*
    requests with the recorded per-tick costs (the what-if server).
    """

    record: Optional[str] = None
    replay: Optional[str] = None
    timing_only: bool = False


@dataclass(frozen=True)
class ServeSpec:
    """One serving scenario, fully specified.

    `backend` selects the execution substrate: ``"engine"`` (exact jitted
    SPMD tick), ``"sim"`` (calibrated roofline), ``"trace"`` (a recording).
    `cluster=None` means one replica.  All four acceptance shapes are
    spellable:

        ServeSpec()                                            # one engine
        ServeSpec(backend="sim")                               # one sim
        ServeSpec(cluster=ClusterSpec(replicas=4))             # engine cluster
        ServeSpec(backend="trace",
                  trace=TraceSpec(replay="run.jsonl"))         # replay
    """

    backend: str = "engine"
    engine: EngineSpec = field(default_factory=EngineSpec)
    sim: SimSpec = field(default_factory=SimSpec)
    cluster: Optional[ClusterSpec] = None
    trace: Optional[TraceSpec] = None

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of "
                f"{BACKENDS}")
        if self.backend == "trace":
            if self.trace is None or self.trace.replay is None:
                raise ValueError(
                    'backend="trace" needs trace=TraceSpec(replay=...)')
            if self.cluster is not None:
                raise ValueError("trace replay is per-replica; replay each "
                                 "recorded trace with its own spec")
        if (self.backend != "sim" and self.cluster is not None
                and self.cluster.sim_overrides is not None):
            raise ValueError(
                'ClusterSpec.sim_overrides applies to backend="sim" only '
                "(engine replicas take their geometry from EngineSpec)")
        if (self.backend != "sim" and self.cluster is not None
                and self.cluster.autoscale is not None):
            raise ValueError(
                'ClusterSpec.autoscale applies to backend="sim" only '
                "(an engine fleet cannot conjure replicas; drive elastic "
                "studies in sim)")

    @property
    def num_replicas(self) -> int:
        return self.cluster.replicas if self.cluster is not None else 1

    # ------------------------------------------------------------------- json
    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(_encode(self), indent=indent,
                          separators=None if indent else (",", ":"))

    @staticmethod
    def from_json(text: str) -> "ServeSpec":
        return spec_from_dict(json.loads(text))


# ---------------------------------------------------------------------------
# JSON (de)serialization — the round trip is exact: from_json(to_json(s)) == s
# ---------------------------------------------------------------------------

def _encode(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _encode(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, (list, tuple)):
        return [_encode(v) for v in obj]
    if isinstance(obj, dict):
        return {k: _encode(v) for k, v in obj.items()}
    return obj


def _decode_capacity(c: Any) -> Union[ReplicaCapacity, float]:
    if isinstance(c, dict):
        return ReplicaCapacity(**c)
    return float(c)


def spec_from_dict(d: Dict[str, Any]) -> ServeSpec:
    """Rebuild a `ServeSpec` from its JSON object form.  Unknown keys raise
    (a spec is a contract — silently dropping a typo'd field would serve a
    different scenario than the one written down)."""
    d = dict(d)
    kw: Dict[str, Any] = {}
    if "backend" in d:
        kw["backend"] = d.pop("backend")
    if d.get("engine") is not None:
        kw["engine"] = EngineSpec(**d.pop("engine"))
    else:
        d.pop("engine", None)
    if d.get("sim") is not None:
        kw["sim"] = SimSpec(**d.pop("sim"))
    else:
        d.pop("sim", None)
    cluster = d.pop("cluster", None)
    if cluster is not None:
        cluster = dict(cluster)
        if cluster.get("rebalance") is not None:
            cluster["rebalance"] = RebalancePolicy(**cluster["rebalance"])
        if cluster.get("handoff") is not None:
            cluster["handoff"] = HandoffPolicy(**cluster["handoff"])
        if cluster.get("autoscale") is not None:
            cluster["autoscale"] = AutoscalePolicy(**cluster["autoscale"])
        if cluster.get("capacities") is not None:
            cluster["capacities"] = tuple(
                _decode_capacity(c) for c in cluster["capacities"])
        if cluster.get("roles") is not None:
            cluster["roles"] = tuple(cluster["roles"])
        kw["cluster"] = ClusterSpec(**cluster)
    trace = d.pop("trace", None)
    if trace is not None:
        kw["trace"] = TraceSpec(**trace)
    if d:
        raise ValueError(f"unknown ServeSpec fields: {sorted(d)}")
    return ServeSpec(**kw)
