"""`build(spec) -> LLMServer`: materialize a `ServeSpec` (DESIGN.md §10).

The four shapes, one factory:

  * engine, 1 replica      -> `PipelineEngine` (exact jitted SPMD tick)
  * engine, N replicas     -> `ReplicaRouter` over N engines sharing one
                              read-only parameter tree
  * sim, 1 or N replicas   -> `PipelineSimulator` / `SimCluster` on the
                              calibrated roofline cost model
  * trace replay           -> the recorded stream (strict bit-identity via
                              `LLMServer.replay()`, or a timing-only engine
                              that serves new requests at recorded costs)

This module owns all construction; the spec layer stays pure data and the
launchers/benchmarks/examples stay thin flag->spec translations.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, List, Optional, Tuple

from repro.serving.server import LLMServer
from repro.serving.spec import ServeSpec, TraceSpec

# Reduced-mode defaults: small enough that the exact engine executes on a
# CPU container, throttle horizons scaled to the toy bucket (the same
# numbers every example and integration test has been using).
_REDUCED_THROTTLE = dict(num_iters_T=4, max_prefill_tokens=32,
                         min_prefill_tokens=4)
_REDUCED_DIMS = dict(Sp=1, C=32, Sd=8, pages=512, page=8, Bp=64, Bd=64,
                     slots=16)


def build(spec: ServeSpec) -> LLMServer:
    """The one public entry point: every serving scenario starts here."""
    if spec.backend == "trace":
        return _build_trace_server(spec)
    if spec.backend == "sim":
        engine, cfg = _build_sim(spec)
    else:
        engine, cfg = _build_engine(spec)
    return LLMServer(engine, spec=spec, cfg=cfg)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _throttle_config(spec: ServeSpec, pipeline_depth: int, *,
                     reduced: bool):
    from repro.core import PrefillPolicy, ThrottleConfig
    kw = dict(_REDUCED_THROTTLE) if reduced else {}
    kw.update(pipeline_depth=pipeline_depth,
              policy=PrefillPolicy(spec.engine.policy))
    kw.update(spec.engine.throttle or {})
    return ThrottleConfig(**kw)


def _build_engine(spec: ServeSpec) -> Tuple[Any, Any]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config, make_reduced
    from repro.configs.base import ASSIGNED_SHAPES
    from repro.launch.mesh import derive_pipeline_mesh, make_production_mesh
    from repro.launch.shapes import serve_cell_dims
    from repro.models import transformer as tfm
    from repro.models.serve import ServeDims
    from repro.runtime.engine import PipelineEngine

    es = spec.engine
    cfg = get_config(es.arch)
    if es.reduced:
        cfg = make_reduced(cfg, **(es.reduced_overrides or {})).with_plan(
            pp=1, tp=1, ep_over_data=False)
        cfg = dataclasses.replace(
            cfg, dtype="float32",
            moe_capacity_factor=float(max(cfg.num_experts, 1)))
        mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
        dims_kw = dict(_REDUCED_DIMS,
                       Te=16 if cfg.is_encoder_decoder else 0)
        dims_kw.update(es.dims or {})
        dims = ServeDims(**dims_kw)
        th = _throttle_config(spec, 1, reduced=True)
    else:
        if es.reduced_overrides:
            raise ValueError(
                "EngineSpec.reduced_overrides only applies to reduced mode")
        prod = make_production_mesh()
        mesh = derive_pipeline_mesh(prod, cfg.plan.pp, cfg.plan.tp)
        dims = serve_cell_dims(cfg, ASSIGNED_SHAPES["prefill_32k"],
                               data=mesh.shape["data"])
        if es.dims:
            dims = dataclasses.replace(dims, **es.dims)
        th = _throttle_config(spec, cfg.plan.pp, reduced=False)

    n = spec.num_replicas
    record = spec.trace.record if spec.trace is not None else None
    with jax.set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.key(es.seed),
                                 dtype=jnp.dtype(cfg.dtype))
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, tfm.param_pspecs(cfg),
            is_leaf=lambda x: isinstance(x, P))
        # replicas share the (read-only) parameter tree; each owns its KV
        # pool, caches, scheduler, and TickLoop
        engines = [PipelineEngine(cfg, dims, params, mesh, th,
                                  trace_path=_replica_trace(record, i, n),
                                  async_dispatch=es.dispatch == "async",
                                  bucketed=es.bucketed,
                                  enable_prefix_caching=
                                  es.enable_prefix_caching)
                   for i in range(n)]
    if spec.cluster is None and n == 1:
        return engines[0], cfg
    return _wrap_router(spec, engines, record), cfg


def _replica_trace(record: Optional[str], i: int, n: int) -> Optional[str]:
    if record is None:
        return None
    return record if n == 1 else f"{record}.replica{i}"


def _wrap_router(spec: ServeSpec, replicas: List[Any],
                 record: Optional[str],
                 replica_factory: Optional[Any] = None):
    from repro.runtime.router import BalanceWeights, ReplicaRouter
    cl = spec.cluster
    weights = None
    if cl.cache_affinity is not None:
        weights = BalanceWeights(cache_affinity=cl.cache_affinity)
    return ReplicaRouter(
        replicas,
        policy=cl.route,
        weights=weights,
        rebalance=cl.rebalance,
        capacities=cl.capacities,
        roles=cl.roles,
        handoff=cl.handoff,
        autoscale=cl.autoscale,
        replica_factory=replica_factory,
        trace_path=None if record is None else f"{record}.router",
    )


# ---------------------------------------------------------------------------
# sim
# ---------------------------------------------------------------------------

def _build_sim(spec: ServeSpec) -> Tuple[Any, Any]:
    from repro.configs import get_config
    from repro.core import PagedKVManager, PipelineScheduler
    from repro.runtime.router import ReplicaRouter, SimCluster
    from repro.runtime.simulator import (PipelineSimulator, RuntimeModel,
                                         cost_model_for)

    cfg = get_config(spec.engine.arch)
    n = spec.num_replicas
    record = spec.trace.record if spec.trace is not None else None
    overrides = (spec.cluster.sim_overrides
                 if spec.cluster is not None else None)

    def replica_sim_spec(i: int):
        """The i-th replica's geometry: the base `SimSpec` with that
        replica's sparse overrides applied (spec-declared heterogeneity)."""
        ov = overrides[i] if overrides is not None else None
        return dataclasses.replace(spec.sim, **ov) if ov else spec.sim

    def one(i: int) -> PipelineSimulator:
        # ordinals >= the initial fleet size are autoscaler-added replicas:
        # they take the base geometry (sim_overrides shape the initial
        # fleet only — the elastic pool is homogeneous)
        ss = replica_sim_spec(i) if i < n else spec.sim
        th = _throttle_config(spec, ss.pp, reduced=False)
        runtime = (RuntimeModel.vllm_like() if ss.runtime == "vllm"
                   else RuntimeModel.gllm())
        kv = PagedKVManager(num_pages=ss.pages, page_size=ss.page_size,
                            enable_prefix_caching=ss.enable_prefix_caching)
        sched = PipelineScheduler(th, kv,
                                  max_model_len=ss.pages * ss.page_size)
        return PipelineSimulator(
            sched, ss.pp,
            cost_model_for(cfg, chips_per_stage=ss.chips_per_stage,
                           pp=ss.pp),
            runtime,
            straggler_stage=ss.straggler_stage,
            straggler_factor=ss.straggler_factor,
            # clusters record via SimCluster's trace_dir layout instead
            trace_path=record if spec.cluster is None else None)

    sims = [one(i) for i in range(n)]
    if spec.cluster is None and n == 1:
        return sims[0], cfg
    router = _wrap_router(spec, sims, None, replica_factory=one)
    # SimCluster owns cluster trace layout: one tick trace per replica plus
    # the router placement stream, under `record` as a directory
    return SimCluster(sims, router, trace_dir=record), cfg


# ---------------------------------------------------------------------------
# trace replay
# ---------------------------------------------------------------------------

class TraceReplayEngine:
    """Engine-surface adapter over a recorded trace in *timing-only* mode:
    new requests are welcome, the scheduler decides freely, and each tick
    costs what the recorded tick cost — the what-if serving substrate.
    Once the recording's ticks are exhausted, further ticks advance a
    fixed 1ms synthetic clock (matching `replay_trace`)."""

    def __init__(self, trace) -> None:
        from repro.runtime.core import TickLoop
        from repro.runtime.trace import TraceBackend, scheduler_from_header

        self.trace = trace
        self.scheduler = scheduler_from_header(trace.header)
        self.backend = TraceBackend(trace, TraceBackend.TIMING)
        self.loop = TickLoop(self.scheduler, self.backend)
        self._now = 0.0
        self._seq = itertools.count()
        self.recorder = None

    # ------------------------------------------------------- engine surface
    @property
    def finished(self):
        return self.loop.finished

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    @property
    def busy(self) -> bool:
        return self.loop.busy

    @property
    def on_token(self):
        return self.loop.on_token

    @on_token.setter
    def on_token(self, fn) -> None:
        self.loop.on_token = fn

    def add_request(self, prompt, sampling=None, request_id=None):
        from repro.core import Request, SamplingParams
        rid = request_id or f"replay-{next(self._seq)}"
        req = Request(rid, list(prompt), sampling or SamplingParams())
        req.metrics.arrival_time = self._clock()
        self.scheduler.add_request(req)
        return req

    def step(self):
        now = self._clock()
        if self.backend._k >= len(self.backend._ticks):
            now = self._now = self._now + 1e-3
        self._now = max(self._now, now)
        return self.loop.step(now)

    def abort_request(self, request_id: str) -> bool:
        req = self.scheduler.abort_request(request_id, self._clock())
        if req is None:
            return False
        if req.is_finished:
            self.loop.finished.append(req)
        return True

    def _clock(self) -> float:
        return max(self._now, self.backend.clock())


def _build_trace_server(spec: ServeSpec) -> LLMServer:
    from repro.runtime.trace import Trace, TraceBackend

    trace = Trace.load(spec.trace.replay)
    if spec.trace.timing_only:
        engine = TraceReplayEngine(trace)
        return LLMServer(engine, spec=spec, replay=trace,
                         replay_mode=TraceBackend.TIMING)
    # strict replay: the workload IS the recording; LLMServer.replay()
    # reproduces it bit-for-bit (no interactive substrate to submit into)
    return LLMServer(None, spec=spec, replay=trace,
                     replay_mode=TraceBackend.STRICT)
