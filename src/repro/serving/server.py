"""`LLMServer`: the one client surface over every execution substrate
(DESIGN.md §10).

Whatever a `ServeSpec` resolved to — a `PipelineEngine`, a
`PipelineSimulator`, a `TraceBackend` replay, a `ReplicaRouter` or
`SimCluster` fronting N of them — the handle you get back speaks the same
request lifecycle:

  * `submit()` / `generate()`        — enqueue, or enqueue-and-wait
  * `stream()` / `generate_stream()` — incremental `TokenDelta`s (sync
    generator stepping from the calling thread — the HTTP frontend's path —
    or the async variant with a shared background runner)
  * `abort()`                        — stop a request anywhere in its life:
    waiting (including a stolen request in a destination queue), mid-decode,
    inside an in-flight micro-batch, or mid-KV-migration between replicas —
    slots and KV pages are freed in every case and the stream ends with
    ``finish_reason="abort"``
  * `stats()`                        — per-replica scheduler/KV signals incl.
    the discovered service-rate EWMA, plus routing/rebalance counters

Preemption-by-recompute is surfaced, not hidden: the stream carries an
``event="preempt"`` delta when a request loses residency and tags the first
token after recovery ``event="preempt-resumed"``.

The server is synchronous at its core (`step()` advances the substrate one
tick/event); `generate_stream` lazily spawns one asyncio runner task that
steps the engine on a worker thread while any work is pending — the
decoupled-frontend design of gLLM §3.3 without a separate class.
"""

from __future__ import annotations

import asyncio
import itertools
import queue
import threading
from dataclasses import dataclass, field
from typing import (Any, AsyncIterator, Callable, Dict, Iterator, List,
                    Optional, Sequence, Set)

from repro.core import Request, RequestMetrics, SamplingParams
from repro.core.request import RequestState

# Public finish-reason vocabulary (TokenDelta.finish_reason /
# RequestOutput.finish_reason)
FINISH_STOP = "stop"        # hit a stop token id
FINISH_LENGTH = "length"    # hit max_new_tokens
FINISH_ABORT = "abort"      # abort() — user or operator

# Stream event vocabulary (TokenDelta.event)
EVENT_PREEMPT = "preempt"                   # lost residency; will recompute
EVENT_PREEMPT_RESUMED = "preempt-resumed"   # first token after recovery


@dataclass(frozen=True)
class TokenDelta:
    """One increment of a request's output stream.

    `token` is None for pure lifecycle events (preemption, abort).  `index`
    is the number of output tokens the request has after this delta — for
    token-bearing deltas, consecutive and 1-based.  Exactly one delta per
    stream carries a non-None `finish_reason`, and it is the last.
    """

    request_id: str
    token: Optional[int]
    index: int
    finish_reason: Optional[str] = None
    event: Optional[str] = None


@dataclass
class RequestOutput:
    """Terminal (or in-progress) view of one request."""

    request_id: str
    prompt_token_ids: List[int]
    token_ids: List[int]
    finish_reason: Optional[str]
    metrics: RequestMetrics

    @staticmethod
    def of(req: Request) -> "RequestOutput":
        return RequestOutput(
            request_id=req.request_id,
            prompt_token_ids=list(req.prompt_token_ids),
            token_ids=list(req.output_token_ids),
            finish_reason=req.finish_reason,
            metrics=req.metrics,
        )

    @property
    def finished(self) -> bool:
        return self.finish_reason is not None


@dataclass
class ReplicaStats:
    """One replica's scheduler/KV signals at a stats() instant."""

    index: int
    ticks: int
    tokens_retired: int
    service_rate: Optional[float]   # tokens retired/sec EWMA (discovered)
    kv_free_rate: float
    waiting: int
    running_decode: int
    preemptions: int
    # Disaggregation role of this replica ("prefill" / "decode" / "mixed",
    # DESIGN.md §15) — "mixed" for single replicas and role-less clusters.
    role: str = "mixed"
    # Waiting-queue composition by SLO class ({"interactive": n, "batch": m},
    # absent classes omitted) — the signal an operator reads to tell "loaded
    # with latency-sensitive work" from "deep but all-batch" (docs/operations.md)
    waiting_by_class: Dict[str, int] = field(default_factory=dict)
    # Prefix-cache effectiveness (all zero with caching disabled):
    # admission-time lookups, hits (lookups that adopted a cached head),
    # and prefill tokens skipped because their KV was already resident.
    prefix_lookups: int = 0
    prefix_hits: int = 0
    prefix_tokens_avoided: int = 0
    # Bucketed-engine attention-depth signals (None/zero for sim replicas or
    # unbucketed engines): the last tick's selected serve shape
    # ({"Sp", "C", "Sd", "Bp", "Bd"}, DESIGN.md §14) and the cumulative KV
    # pages the attention scan walked vs. those actually holding context.
    bucket: Optional[Dict[str, int]] = None
    scanned_pages: int = 0
    live_pages: int = 0


@dataclass
class ServerStats:
    replicas: List[ReplicaStats] = field(default_factory=list)
    routed_counts: Optional[List[int]] = None     # clusters only
    # Stable per-replica ordinals, position-aligned with `replicas` /
    # `routed_counts` (clusters only).  On an elastic fleet the ordinal —
    # not the list position — identifies a replica across scale events:
    # retired ordinals leave the list, newborns get fresh ones.
    replica_ordinals: Optional[List[int]] = None
    rebalance: Optional[Any] = None               # RebalanceStats, if enabled
    disagg: Optional[Any] = None                  # DisaggStats, if handoff on
    autoscale: Optional[Any] = None               # AutoscaleStats, if elastic
    # Elastic fleets (DESIGN.md §16): serving replica count (draining
    # replicas excluded), active drains, and replicas already retired.
    fleet_size: Optional[int] = None
    draining: Optional[int] = None
    retired: Optional[int] = None
    # Per-class SLO attainment over finished requests (the shared
    # `attainment_by_class` definition — same numbers fig_autoscale and
    # fig_disagg report); None until something finished.
    attainment_by_class: Optional[Dict[str, Dict[str, float]]] = None

    @property
    def tokens_retired(self) -> int:
        return sum(r.tokens_retired for r in self.replicas)

    @property
    def queue_depth_by_role(self) -> Dict[str, Dict[str, int]]:
        """Per-role aggregate queue signals: how deep the prefill-side
        admission backlog runs vs. how much decode work the decode side
        carries — the two queues a disaggregated deployment balances."""
        out: Dict[str, Dict[str, int]] = {}
        for r in self.replicas:
            agg = out.setdefault(r.role, {"replicas": 0, "waiting": 0,
                                          "running_decode": 0})
            agg["replicas"] += 1
            agg["waiting"] += r.waiting
            agg["running_decode"] += r.running_decode
        return out


def _replicas_of(engine: Any) -> List[Any]:
    """The per-replica objects behind any engine-surface target."""
    sims = getattr(engine, "sims", None)           # SimCluster
    if sims is not None:
        return list(sims)
    replicas = getattr(engine, "replicas", None)   # ReplicaRouter
    if replicas is not None:
        return list(replicas)
    return [engine]


def _router_of(engine: Any) -> Optional[Any]:
    router = getattr(engine, "router", None)       # SimCluster
    if router is not None:
        return router
    if getattr(engine, "replicas", None) is not None:   # ReplicaRouter
        return engine
    return None


class LLMServer:
    """The serving facade.  Construct via `repro.serving.build(spec)`.

    `engine` is anything speaking the engine surface: ``add_request(prompt,
    sampling, request_id)`` / ``step()`` / ``abort_request(rid)`` /
    ``has_work`` / ``busy`` — a `PipelineEngine`, `PipelineSimulator`,
    `ReplicaRouter`, `SimCluster`, or the trace-replay engine.
    """

    _rid_counter = itertools.count()    # process-wide: unique across servers

    def __init__(self, engine: Any, *, spec: Any = None, cfg: Any = None,
                 replay: Any = None, replay_mode: str = "strict") -> None:
        self.engine = engine
        self.spec = spec
        self.cfg = cfg                  # ArchConfig for model-backed servers
        self._replay_trace = replay
        self._replay_mode = replay_mode
        self.last_report = None
        self._requests: Dict[str, Request] = {}
        self._sinks: Dict[str, List[Callable[[TokenDelta], None]]] = {}
        self._final_emitted: Set[str] = set()
        self._resume_pending: Set[str] = set()
        self._step_lock = threading.Lock()
        self._runner_task: Optional[asyncio.Task] = None
        self._closed = False
        if engine is not None:
            for replica in _replicas_of(engine):
                self._wire_replica(replica)
            router = _router_of(engine)
            if router is not None \
                    and hasattr(router, "add_replica_hook"):
                # elastic fleets: replicas added later need the same wiring
                router.add_replica_hook(
                    lambda replica, ordinal, now: self._wire_replica(replica))

    def _wire_replica(self, replica: Any) -> None:
        replica.on_token = self._on_token
        sched = replica.scheduler
        sched.on_preempt = self._chain_preempt(sched.on_preempt)

    # ------------------------------------------------------------ enumeration
    @property
    def replicas(self) -> List[Any]:
        return _replicas_of(self.engine) if self.engine is not None else []

    @property
    def router(self) -> Optional[Any]:
        return _router_of(self.engine) if self.engine is not None else None

    @property
    def has_work(self) -> bool:
        return bool(self.engine is not None
                    and (self.engine.has_work or self.engine.busy))

    # ---------------------------------------------------------------- lifecycle
    def submit(self, prompt: Sequence[int],
               sampling: Optional[SamplingParams] = None,
               request_id: Optional[str] = None, **kw) -> str:
        """Enqueue a request; returns its id.  Extra kwargs (e.g.
        `enc_embeds` for encoder-decoder archs) pass through to the
        substrate."""
        self._require_interactive("submit")
        rid = request_id or f"llm-{next(LLMServer._rid_counter)}"
        # intake serializes against ticks: schedulers iterate their waiting
        # queue inside schedule(), so a concurrent add_request from another
        # client thread (HTTP handler, asyncio submitter) must not mutate it
        # mid-step
        with self._step_lock:
            req = self.engine.add_request(list(prompt), sampling, rid, **kw)
        self._requests[rid] = req
        return rid

    def step(self) -> List[RequestOutput]:
        """Advance the substrate one tick/event; returns requests that
        finished during it (server-submitted or not)."""
        self._require_interactive("step")
        with self._step_lock:
            # the sweep dispatches terminal deltas INSIDE the lock: the lock
            # is the dispatch barrier streaming threads rely on — once idle
            # is observed under it, every terminal delta has been queued
            finished = self.engine.step()
            self._sweep_finished(finished)
        return [RequestOutput.of(r) for r in finished]

    def drain(self, max_steps: int = 1_000_000) -> List[RequestOutput]:
        """Run until idle; returns everything that finished on the way."""
        self._require_interactive("drain")
        out: List[RequestOutput] = []
        for _ in range(max_steps):
            if not self.has_work:
                break
            out.extend(self.step())
        return out

    def generate(self, prompt: Sequence[int],
                 sampling: Optional[SamplingParams] = None,
                 max_steps: int = 1_000_000, **kw) -> RequestOutput:
        """Submit one request and run the substrate until it finishes.
        Other in-flight work keeps progressing — this is a wait, not an
        exclusive lease on the server."""
        rid = self.submit(prompt, sampling, **kw)
        req = self._requests[rid]
        for _ in range(max_steps):
            if req.is_finished or not self.has_work:
                break
            self.step()
        return RequestOutput.of(req)

    def abort(self, request_id: str) -> bool:
        """Stop a request wherever it stands; frees its KV pages and state
        slot.  Returns True when the request was found (the final
        ``finish_reason="abort"`` delta may arrive a tick later for requests
        inside an in-flight micro-batch)."""
        self._require_interactive("abort")
        with self._step_lock:
            found = self.engine.abort_request(request_id)
            req = self._requests.get(request_id)
            if req is not None and req.is_finished:
                # dispatch the terminal abort delta under the lock (see
                # step()): a stream observing an idle substrate must find
                # this delta already queued
                self._sweep_finished([req])
        return bool(found)

    def get(self, request_id: str) -> RequestOutput:
        return RequestOutput.of(self._requests[request_id])

    def outputs(self, request_ids: Optional[Sequence[str]] = None
                ) -> List[RequestOutput]:
        """Current view of the given (default: all) submitted requests."""
        rids = list(request_ids) if request_ids is not None \
            else list(self._requests)
        return [RequestOutput.of(self._requests[r]) for r in rids]

    # ------------------------------------------------------------- streaming
    def subscribe(self, request_id: str,
                  sink: Callable[[TokenDelta], None]) -> None:
        """Register `sink` for every `TokenDelta` of `request_id`.  Called
        from whichever thread steps the substrate — sinks must be
        thread-safe (e.g. `queue.Queue.put`).  Subscribe BEFORE submitting
        under that id, or deltas produced by an in-progress step are lost."""
        self._sinks.setdefault(request_id, []).append(sink)

    def unsubscribe(self, request_id: str, sink: Callable) -> None:
        subs = self._sinks.get(request_id)
        if subs is None:
            return
        if sink in subs:
            subs.remove(sink)
        if not subs:
            self._sinks.pop(request_id, None)

    def stream(self, prompt: Sequence[int],
               sampling: Optional[SamplingParams] = None,
               request_id: Optional[str] = None,
               max_steps: int = 1_000_000, **kw) -> Iterator[TokenDelta]:
        """Synchronous streaming: submit one request and yield its
        `TokenDelta`s as the substrate produces them, stepping it from the
        calling thread.  The last delta carries `finish_reason`.  Safe under
        concurrent callers (HTTP handler threads): steps serialize on the
        server's lock, and deltas produced by *another* thread's step are
        delivered here through the sink queue.

        The submit happens eagerly — admission errors (oversized request,
        unknown kwargs) raise *here*, before any delta exists, so callers
        that must commit to a response format first (HTTP) can still turn
        them into a clean client error."""
        self._require_interactive("stream")
        q: queue.Queue = queue.Queue()
        rid = request_id or f"llm-{next(LLMServer._rid_counter)}"
        self.subscribe(rid, q.put)
        try:
            self.submit(prompt, sampling, request_id=rid, **kw)
        except Exception:
            self.unsubscribe(rid, q.put)
            raise
        return self._stream_deltas(rid, q, max_steps)

    def _stream_deltas(self, rid: str, q: "queue.Queue",
                       max_steps: int) -> Iterator[TokenDelta]:
        try:
            for _ in range(max_steps):
                try:
                    delta = q.get_nowait()
                except queue.Empty:
                    if not self.has_work:
                        # another thread's step/abort may be mid-flight with
                        # our terminal delta not yet dispatched; all
                        # dispatches happen under the step lock, so taking
                        # it once is the barrier that makes emptiness final
                        with self._step_lock:
                            pass
                        if not self.has_work and q.empty():
                            break   # drained — whatever is queued is final
                        continue
                    self.step()
                    continue
                yield delta
                if delta.finish_reason is not None:
                    return
            while True:             # the terminal delta may already be queued
                try:
                    delta = q.get_nowait()
                except queue.Empty:
                    return
                yield delta
                if delta.finish_reason is not None:
                    return
        finally:
            self.unsubscribe(rid, q.put)

    async def generate_stream(self, prompt: Sequence[int],
                              sampling: Optional[SamplingParams] = None,
                              request_id: Optional[str] = None, **kw
                              ) -> AsyncIterator[TokenDelta]:
        """Submit and stream `TokenDelta`s as they materialize.  The last
        delta carries `finish_reason`.  A background runner task (shared by
        all concurrent streams) steps the substrate on a worker thread."""
        self._require_interactive("generate_stream")
        loop = asyncio.get_running_loop()
        q: asyncio.Queue = asyncio.Queue()

        def sink(delta: TokenDelta) -> None:
            loop.call_soon_threadsafe(q.put_nowait, delta)

        rid = request_id or f"llm-{next(LLMServer._rid_counter)}"
        # subscribe BEFORE the engine can see the request: the runner thread
        # may produce tokens the moment add_request lands
        self.subscribe(rid, sink)
        try:
            self.submit(prompt, sampling, request_id=rid, **kw)
        except Exception:
            self.unsubscribe(rid, sink)
            raise
        self._ensure_runner(loop)
        try:
            while True:
                delta = await q.get()
                yield delta
                if delta.finish_reason is not None:
                    return
        finally:
            self.unsubscribe(rid, sink)

    def _ensure_runner(self, loop: asyncio.AbstractEventLoop) -> None:
        if self._runner_task is not None and not self._runner_task.done():
            return

        async def run() -> None:
            # blocking device steps on a worker thread; intake and token
            # streaming stay responsive on the event loop (gLLM §3.3)
            while not self._closed and self.has_work:
                await asyncio.to_thread(self.step)

        self._runner_task = loop.create_task(run())

    # -------------------------------------------------------------- replay
    def replay(self) -> List[RequestOutput]:
        """Trace-replay servers: drive the recorded stream (requests,
        aborts, migrations, ticks) through a fresh scheduler and return the
        re-materialized outputs.  Strict mode asserts every scheduler
        decision matches the recording (`TraceDivergence` otherwise);
        timing-only replays the costs but lets decisions drift.  The full
        `ReplayReport` is kept on `self.last_report`."""
        if self._replay_trace is None:
            raise RuntimeError("not a trace-replay server: build with "
                               'ServeSpec(backend="trace", ...)')
        from repro.runtime.trace import replay_trace
        report = replay_trace(self._replay_trace, mode=self._replay_mode)
        self.last_report = report
        for req in report.finished:
            self._requests.setdefault(req.request_id, req)
        return [RequestOutput.of(r) for r in report.finished]

    # ---------------------------------------------------------------- stats
    def stats(self) -> ServerStats:
        out = ServerStats()
        roles = getattr(self.router, "roles", None)
        for i, replica in enumerate(self.replicas):
            sched = replica.scheduler
            # iterating the waiting deque must not race a concurrent
            # submit/step mutating it (same reason intake serializes)
            with self._step_lock:
                by_class: Dict[str, int] = {}
                for req in sched.waiting:
                    cls = req.sampling.slo_class
                    by_class[cls] = by_class.get(cls, 0) + 1
            # engine replicas expose per-tick attention-depth stats on their
            # backend; sim/trace replicas have no EngineStats — leave defaults
            eng_stats = getattr(getattr(replica, "backend", None), "stats",
                                None)
            out.replicas.append(ReplicaStats(
                index=i,
                ticks=sched.stats.ticks,
                tokens_retired=sched.stats.tokens_retired,
                service_rate=sched.stats.service_rate,
                kv_free_rate=sched.kv.kv_free_rate,
                waiting=len(sched.waiting),
                running_decode=sched.num_running_decode,
                preemptions=sched.stats.preemptions,
                role=roles[i] if roles is not None else "mixed",
                waiting_by_class=by_class,
                prefix_lookups=sched.stats.prefix_lookups,
                prefix_hits=sched.stats.prefix_hits,
                prefix_tokens_avoided=sched.stats.prefix_tokens_avoided,
                bucket=getattr(eng_stats, "last_bucket", None),
                scanned_pages=getattr(eng_stats, "scanned_pages", 0),
                live_pages=getattr(eng_stats, "live_pages", 0),
            ))
        router = self.router
        if router is not None:
            out.routed_counts = list(router.routed_counts)
            out.replica_ordinals = list(router.replica_ids)
            if router.rebalance_policy is not None:
                out.rebalance = router.rebalance_stats
            if router.handoff_policy is not None:
                out.disagg = router.disagg_stats
            out.fleet_size = len(router._serving())
            out.draining = len(router._draining)
            out.retired = len(router.retired)
            if router.autoscale_policy is not None:
                out.autoscale = router.autoscale_stats
        finished = self._finished_requests()
        if finished:
            from repro.runtime.autoscale import attainment_by_class
            out.attainment_by_class = attainment_by_class(finished)
        return out

    def _finished_requests(self) -> List[Request]:
        """Everything the substrate has retired (cluster-wide, including
        work that finished on since-retired replicas)."""
        if self.engine is None:
            return [r for r in self._requests.values() if r.is_finished]
        fin = getattr(self.engine, "finished", None)
        if fin is None:
            fin = self.engine.metrics.finished
        return list(fin)

    def close(self) -> None:
        """Flush and close any attached trace recorders/streams."""
        self._closed = True
        router = self.router
        if router is not None and getattr(router, "_trace", None) is not None:
            router.close_trace()
        for replica in self.replicas:
            rec = getattr(replica, "recorder", None)
            if rec is not None:
                rec.close()

    def __enter__(self) -> "LLMServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- internals
    def _require_interactive(self, what: str) -> None:
        if self.engine is None:
            raise RuntimeError(
                f"{what}() needs a live substrate; this is a strict "
                "trace-replay server — call replay(), or build with "
                "TraceSpec(timing_only=True) to serve new requests")

    def _chain_preempt(self, prev: Optional[Callable[[Request], None]]
                       ) -> Callable[[Request], None]:
        def hook(req: Request) -> None:
            if prev is not None:
                prev(req)
            self._on_preempt(req)
        return hook

    def _on_preempt(self, req: Request) -> None:
        rid = req.request_id
        if req.is_finished:
            return      # abort finalization under a fault path, not a pause
        self._resume_pending.add(rid)
        self._dispatch(TokenDelta(rid, None, req.num_output_tokens,
                                  event=EVENT_PREEMPT))

    def _on_token(self, req: Request, token: int) -> None:
        rid = req.request_id
        if req.state is RequestState.FINISHED_ABORTED:
            # the retiring tick produced a token for a request that was
            # aborted while in flight: it was discarded, not recorded — the
            # stream ends with the abort delta from the finished sweep
            return
        event = None
        if rid in self._resume_pending:
            self._resume_pending.discard(rid)
            event = EVENT_PREEMPT_RESUMED
        finish = req.finish_reason if req.is_finished else None
        self._dispatch(TokenDelta(rid, int(token), req.num_output_tokens,
                                  finish_reason=finish, event=event))
        if finish is not None:
            self._final_emitted.add(rid)

    def _sweep_finished(self, finished: Sequence[Request]) -> None:
        """Emit the terminal delta for requests that finished without a
        final token of their own (aborts, in-transit aborts)."""
        for req in finished:
            rid = req.request_id
            if rid in self._final_emitted:
                continue
            self._final_emitted.add(rid)
            self._resume_pending.discard(rid)
            self._dispatch(TokenDelta(rid, None, req.num_output_tokens,
                                      finish_reason=req.finish_reason))

    def _dispatch(self, delta: TokenDelta) -> None:
        subs = self._sinks.get(delta.request_id)
        if not subs:
            return
        for sink in list(subs):
            sink(delta)
