"""docs-check: the documentation is executable, and its links resolve.

Two gates over README.md + docs/*.md (wired into `make ci` as
`make docs-check`):

  1. **Fenced ``python`` blocks run.**  Per file, every block fenced exactly
     ```` ```python ```` is concatenated (in order — later blocks may use
     names an earlier block defined, exactly as a reader works through the
     page) and executed as one script with ``PYTHONPATH=src``, cwd a fresh
     temp directory (so examples may write scratch files without littering
     the repo).  Doc examples target the sim / trace substrates, so this
     gate needs no jax and runs in seconds.  A block fenced with any other
     info string (```` ```bash ````, ```` ```text ````, ```` ```json ````,
     or ```` ```python no-run ```` for genuinely illustrative fragments) is
     not executed.

  2. **Relative links resolve.**  Every markdown link target that is not a
     URL or a pure fragment must exist on disk, relative to the file that
     links it.

Usage:  python tools/docs_check.py [FILE.md ...]   (default: README + docs/)
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
import tempfile
from typing import List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FENCE_RE = re.compile(r"^```(\S*)[ \t]*(.*)$")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def python_blocks(text: str) -> List[Tuple[int, str]]:
    """(first line number, code) for every block fenced exactly ```python."""
    out: List[Tuple[int, str]] = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m and m.group(1):                      # an opening fence
            lang, extra = m.group(1), m.group(2).strip()
            body: List[str] = []
            start = i + 1
            i += 1
            while i < len(lines) and lines[i].strip() != "```":
                body.append(lines[i])
                i += 1
            if lang == "python" and not extra:    # "python no-run" skipped
                out.append((start + 1, "\n".join(body)))
        i += 1
    return out


def check_blocks(path: str) -> List[str]:
    with open(path) as fh:
        text = fh.read()
    blocks = python_blocks(text)
    if not blocks:
        return []
    rel = os.path.relpath(path, REPO)
    script = "\n\n".join(
        f"# --- {rel} block at line {line}\n{code}"
        for line, code in blocks)
    env = dict(os.environ)
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    with tempfile.TemporaryDirectory(prefix="docs-check-") as tmp:
        proc = subprocess.run([sys.executable, "-c", script], cwd=tmp,
                              env=env, capture_output=True, text=True,
                              timeout=600)
    if proc.returncode != 0:
        tail = (proc.stderr or proc.stdout).strip().splitlines()[-12:]
        return [f"{rel}: python blocks failed "
                f"(exit {proc.returncode}):\n    " + "\n    ".join(tail)]
    return []


def check_links(path: str) -> List[str]:
    errors: List[str] = []
    rel = os.path.relpath(path, REPO)
    base = os.path.dirname(path)
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:",
                                      "#")):
                    continue
                target_path = target.split("#", 1)[0]
                if not target_path:
                    continue
                if not os.path.exists(os.path.join(base, target_path)):
                    errors.append(f"{rel}:{lineno}: broken relative link "
                                  f"-> {target}")
    return errors


def main(argv: List[str]) -> int:
    paths = argv or [os.path.join(REPO, "README.md")] + sorted(
        os.path.join(REPO, "docs", f)
        for f in os.listdir(os.path.join(REPO, "docs"))
        if f.endswith(".md"))
    errors: List[str] = []
    for path in paths:
        errs = check_links(path) + check_blocks(path)
        rel = os.path.relpath(path, REPO)
        with open(path) as fh:
            n = len(python_blocks(fh.read()))
        if errs:
            errors.extend(errs)
            print(f"docs-check: {rel} — FAILED")
        else:
            print(f"docs-check: {rel} — {n} python block(s) ran, links OK")
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
