"""Pipelined training end-to-end: a small LM for a few hundred steps on the
GPipe-in-shard_map path, with async checkpointing and restart-from-checkpoint
(the fault-tolerance drill).

    PYTHONPATH=src python examples/train_tiny.py [steps]
"""
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, make_reduced
from repro.data.tokens import batches
from repro.distributed.optimizer import AdamConfig, adam_init
from repro.distributed.pipeline import build_train_step
from repro.models import transformer as tfm
from repro.runtime.checkpoint import AsyncCheckpointer, restore_checkpoint


def main(steps: int = 200):
    cfg = make_reduced(get_config("qwen1.5-0.5b"), d_model=128, d_ff=256,
                       vocab=512).with_plan(pp=1, tp=1, ep_over_data=False)
    cfg = dataclasses.replace(cfg, dtype="float32")
    mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    M, mbg, T = 2, 1, 64
    with jax.set_mesh(mesh):
        step = jax.jit(build_train_step(cfg, mesh,
                                        adam=AdamConfig(lr=1e-3)))
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, tfm.param_pspecs(cfg),
            is_leaf=lambda x: isinstance(x, P))
        opt = adam_init(params)
        ck = AsyncCheckpointer()
        data = batches(cfg.vocab_size, M, mbg, T, seed=0)
        t0 = time.time()
        for i in range(steps):
            b = next(data)
            params, opt, m = step(params, opt,
                                  {k: jnp.asarray(v) for k, v in b.items()})
            if i % 25 == 0 or i == steps - 1:
                print(f"step {i:4d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['gnorm']):.3f} "
                      f"({(i+1)/(time.time()-t0):.1f} it/s)")
            if i % 100 == 99:
                ck.submit(f"/tmp/gllm_ck/{i}", params,
                          extra={"step": i})
        ck.wait()
        # restart drill: reload the last checkpoint and take one more step
        last = f"/tmp/gllm_ck/{max(0, steps - 100) // 100 * 100 + 99}"
        try:
            restored = restore_checkpoint(last, params)
            params2 = jax.tree.map(lambda a: jnp.asarray(a), restored)
            _, _, m2 = step(params2, opt, {k: jnp.asarray(v)
                                           for k, v in next(data).items()})
            print(f"restart-from-checkpoint OK: loss={float(m2['loss']):.4f}")
        except FileNotFoundError:
            print("(no checkpoint taken — run with steps >= 100 for the "
                  "restart drill)")
        ck.close()


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 200)
