"""Online serving: asyncio frontend + Poisson arrivals + streaming tokens +
SLO report — the paper's cloud scenario end-to-end (decoupled frontend,
non-blocking engine; paper §3.3).

Runs TWO data-parallel engine replicas behind the globally-balanced
`ReplicaRouter` (DESIGN.md §1.3): the frontend submits by balance score and
steps both replicas from one worker thread.  Set REPLICAS=1 for the
single-engine layout.

    PYTHONPATH=src python examples/serve_online.py

With --trace-out every replica's ticks and the router's placements are
recorded to replayable JSONL traces (DESIGN.md §8) — re-examine the run
offline, with no accelerator, via:

    PYTHONPATH=src python examples/serve_online.py --trace-out /tmp/online
    PYTHONPATH=src python -m repro.runtime.trace replay /tmp/online.replica0
"""
import argparse
import asyncio
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, make_reduced
from repro.core import SamplingParams, ThrottleConfig
from repro.models import transformer as tfm
from repro.models.serve import ServeDims
from repro.runtime.engine import PipelineEngine
from repro.runtime.frontend import AsyncFrontend
from repro.runtime.router import ReplicaRouter

REPLICAS = 2


async def client(fe, rng, cfg, results, i):
    prompt = list(rng.integers(0, cfg.vocab_size, int(rng.integers(5, 40))))
    t0 = time.monotonic()
    rid = await fe.submit(prompt, SamplingParams(max_new_tokens=6))
    first, n = None, 0
    async for _ in fe.stream(rid):
        if first is None:
            first = time.monotonic() - t0
        n += 1
    results.append((first, time.monotonic() - t0, n))


async def main(trace_out=None):
    cfg = make_reduced(get_config("qwen1.5-0.5b")).with_plan(
        pp=1, tp=1, ep_over_data=False)
    cfg = dataclasses.replace(cfg, dtype="float32")
    mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    dims = ServeDims(Sp=1, C=16, Sd=8, pages=512, page=8, Bp=32, Bd=32,
                     slots=16)
    th = ThrottleConfig(num_iters_T=2, max_prefill_tokens=16,
                        min_prefill_tokens=4, pipeline_depth=cfg.plan.pp)
    with jax.set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, tfm.param_pspecs(cfg),
            is_leaf=lambda x: isinstance(x, P))
        # replicas share the read-only parameter tree; with --trace-out each
        # records its own replayable tick trace
        engines = [
            PipelineEngine(
                cfg, dims, params, mesh, th,
                trace_path=None if trace_out is None
                else f"{trace_out}.replica{i}")
            for i in range(REPLICAS)]
    router_trace = None if trace_out is None else f"{trace_out}.router"
    target = engines[0] if len(engines) == 1 \
        else ReplicaRouter(engines, policy="balanced",
                           trace_path=router_trace)
    fe = AsyncFrontend(target)
    runner = asyncio.create_task(fe.run())

    rng = np.random.default_rng(0)
    results = []
    tasks = []
    for i in range(10):                       # Poisson arrivals
        await asyncio.sleep(float(rng.exponential(0.05)))
        tasks.append(asyncio.create_task(client(fe, rng, cfg, results, i)))
    await asyncio.gather(*tasks)
    fe.stop()
    await runner

    ttft = np.array([r[0] for r in results])
    e2e = np.array([r[1] for r in results])
    print(f"{len(results)} streamed requests | TTFT p50={np.median(ttft)*1e3:.0f}ms "
          f"p99={np.quantile(ttft, 0.99)*1e3:.0f}ms | "
          f"E2E p50={np.median(e2e)*1e3:.0f}ms")
    if isinstance(target, ReplicaRouter):
        print(f"routing ({target.policy.value}): "
              f"{'/'.join(map(str, target.routed_counts))} across "
              f"{len(engines)} replicas")
    slo = np.mean((ttft < 2.0) & (e2e < 10.0))
    print(f"SLO attainment (TTFT<2s, E2E<10s): {slo:.0%}")
    if trace_out is not None:
        if isinstance(target, ReplicaRouter):
            target.close_trace()
        for i, eng in enumerate(engines):
            eng.recorder.close()
            print(f"trace: {trace_out}.replica{i} "
                  f"({eng.recorder.num_ticks} ticks)")
        print(f"replay with: python -m repro.runtime.trace replay "
              f"{trace_out}.replica0")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record per-replica tick traces (PATH.replicaN) "
                    "plus the router's placement stream (PATH.router)")
    asyncio.run(main(trace_out=ap.parse_args().trace_out))
