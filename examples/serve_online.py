"""Online serving through the public API: Poisson arrivals, streamed
`TokenDelta`s, a mid-stream abort, and an SLO report — the paper's cloud
scenario end-to-end (decoupled frontend, non-blocking engine; paper §3.3).

Runs TWO data-parallel engine replicas behind the globally-balanced
`ReplicaRouter` (DESIGN.md §1.3): `LLMServer.generate_stream` submits by
balance score and steps all replicas from one worker thread.  Set
REPLICAS=1 for the single-engine layout — the client code is identical.

    PYTHONPATH=src python examples/serve_online.py

With --trace-out every replica's ticks and the router's placements are
recorded to replayable JSONL traces (DESIGN.md §8) — re-examine the run
offline, with no accelerator, via:

    PYTHONPATH=src python examples/serve_online.py --trace-out /tmp/online
    PYTHONPATH=src python -m repro.runtime.trace replay /tmp/online.replica0
"""
import argparse
import asyncio
import time

import numpy as np

from repro.serving import (ClusterSpec, EngineSpec, SamplingParams,
                           ServeSpec, TraceSpec, build)

REPLICAS = 2


async def client(server, rng, results):
    prompt = list(rng.integers(0, server.cfg.vocab_size,
                               int(rng.integers(5, 40))))
    t0 = time.monotonic()
    first, n = None, 0
    async for delta in server.generate_stream(
            prompt, SamplingParams(max_new_tokens=6)):
        if delta.token is not None:
            if first is None:
                first = time.monotonic() - t0
            n += 1
    results.append((first, time.monotonic() - t0, n))


async def impatient_client(server, rng):
    """Streams two tokens, then cancels: the abort path exercised live —
    slots and KV pages free immediately, the stream ends with
    finish_reason="abort"."""
    prompt = list(rng.integers(0, server.cfg.vocab_size, 12))
    reason = None
    async for delta in server.generate_stream(
            prompt, SamplingParams(max_new_tokens=64)):
        reason = delta.finish_reason
        if delta.index >= 2 and reason is None:
            server.abort(delta.request_id)
    return reason


async def main(trace_out=None):
    spec = ServeSpec(
        backend="engine",
        engine=EngineSpec(
            arch="qwen1.5-0.5b",
            throttle=dict(num_iters_T=2, max_prefill_tokens=16,
                          min_prefill_tokens=4),
            dims=dict(C=16, Bp=32, Bd=32),
        ),
        cluster=ClusterSpec(replicas=REPLICAS) if REPLICAS > 1 else None,
        trace=TraceSpec(record=trace_out) if trace_out else None,
    )
    server = build(spec)

    rng = np.random.default_rng(0)
    results = []
    tasks = []
    for _ in range(10):                       # Poisson arrivals
        await asyncio.sleep(float(rng.exponential(0.05)))
        tasks.append(asyncio.create_task(client(server, rng, results)))
    tasks.append(asyncio.create_task(impatient_client(server, rng)))
    *_, abort_reason = await asyncio.gather(*tasks)

    ttft = np.array([r[0] for r in results])
    e2e = np.array([r[1] for r in results])
    print(f"{len(results)} streamed requests | "
          f"TTFT p50={np.median(ttft)*1e3:.0f}ms "
          f"p99={np.quantile(ttft, 0.99)*1e3:.0f}ms | "
          f"E2E p50={np.median(e2e)*1e3:.0f}ms")
    print(f"impatient client: finish_reason={abort_reason!r}")
    stats = server.stats()
    if stats.routed_counts is not None:
        print(f"routing ({server.router.policy.value}): "
              f"{'/'.join(map(str, stats.routed_counts))} across "
              f"{len(stats.replicas)} replicas")
    slo = np.mean((ttft < 2.0) & (e2e < 10.0))
    print(f"SLO attainment (TTFT<2s, E2E<10s): {slo:.0%}")
    server.close()
    if trace_out is not None:
        n = len(server.replicas)
        paths = [trace_out if n == 1 else f"{trace_out}.replica{i}"
                 for i in range(n)]
        for path, eng in zip(paths, server.replicas):
            print(f"trace: {path} ({eng.recorder.num_ticks} ticks)")
        print(f"replay with: python -m repro.runtime.trace replay "
              f"{paths[0]}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record per-replica tick traces (PATH.replicaN) "
                    "plus the router's placement stream (PATH.router)")
    asyncio.run(main(trace_out=ap.parse_args().trace_out))
