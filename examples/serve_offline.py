"""Offline batch serving through the public API: a ShareGPT-like workload
on the real engine, with per-request latency metrics — the end-to-end
driver for the paper's serving scenario (CPU-sized model, identical code
path to the TPU configs).

    PYTHONPATH=src python examples/serve_offline.py [num_requests]
"""
import sys
import time

import numpy as np

from repro.serving import EngineSpec, SamplingParams, ServeSpec, build


def main(n_requests: int = 16):
    server = build(ServeSpec(
        backend="engine",
        engine=EngineSpec(
            arch="qwen2.5-14b",
            reduced_overrides=dict(d_model=128, d_ff=256),
            throttle=dict(num_iters_T=4, max_prefill_tokens=64,
                          min_prefill_tokens=8),
            dims=dict(Sp=2, C=32, Sd=16, pages=1024, page=8, slots=32),
        ),
    ))
    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = []
    for _ in range(n_requests):
        prompt = list(rng.integers(
            0, server.cfg.vocab_size,
            int(np.clip(rng.lognormal(3.0, 0.8), 4, 200))))
        rids.append(server.submit(
            prompt, SamplingParams(max_new_tokens=int(rng.integers(2, 16)))))
    server.drain()
    wall = time.time() - t0

    outs = server.outputs(rids)
    out_toks = sum(len(o.token_ids) for o in outs)
    in_toks = sum(len(o.prompt_token_ids) for o in outs)
    print(f"served {len(outs)} requests in {wall:.1f}s "
          f"({(in_toks + out_toks) / wall:.0f} tok/s on CPU)")
    ttfts = [o.metrics.ttft() for o in outs]
    s = server.stats().replicas[0]
    print(f"TTFT mean={np.mean(ttfts)*1e3:.0f}ms  ticks={s.ticks} "
          f"preemptions={s.preemptions} "
          f"service_rate={s.service_rate:.0f} tok/s (EWMA)")
    eng = server.replicas[0]
    pp_pad = eng.stats.padded_prefill / max(
        1, eng.stats.ticks * eng.dims.Sp * eng.dims.C)
    print(f"prefill bucket padding (bubble fraction): {pp_pad:.1%}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 16)
