"""Offline batch serving: a ShareGPT-like workload through the real engine,
with per-request latency metrics — the end-to-end driver for the paper's
serving scenario (CPU-sized model, identical code path to the TPU configs).

    PYTHONPATH=src python examples/serve_offline.py [num_requests]
"""
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, make_reduced
from repro.core import SamplingParams, ThrottleConfig
from repro.models import transformer as tfm
from repro.models.serve import ServeDims
from repro.runtime.engine import PipelineEngine


def main(n_requests: int = 16):
    cfg = make_reduced(get_config("qwen2.5-14b"), d_model=128,
                       d_ff=256).with_plan(pp=1, tp=1, ep_over_data=False)
    cfg = dataclasses.replace(cfg, dtype="float32")
    mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    dims = ServeDims(Sp=2, C=32, Sd=16, pages=1024, page=8, Bp=64, Bd=64,
                     slots=32)
    with jax.set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, tfm.param_pspecs(cfg),
            is_leaf=lambda x: isinstance(x, P))
        engine = PipelineEngine(
            cfg, dims, params, mesh,
            ThrottleConfig(num_iters_T=4, max_prefill_tokens=64,
                           min_prefill_tokens=8, pipeline_depth=cfg.plan.pp))

    rng = np.random.default_rng(0)
    t0 = time.time()
    reqs = []
    for i in range(n_requests):
        prompt = list(rng.integers(0, cfg.vocab_size,
                                   int(np.clip(rng.lognormal(3.0, 0.8), 4, 200))))
        reqs.append(engine.add_request(
            prompt, SamplingParams(max_new_tokens=int(rng.integers(2, 16)))))
    engine.drain()
    wall = time.time() - t0
    out_toks = sum(r.num_output_tokens for r in reqs)
    in_toks = sum(r.num_prompt_tokens for r in reqs)
    print(f"served {len(reqs)} requests in {wall:.1f}s "
          f"({(in_toks + out_toks) / wall:.0f} tok/s on CPU)")
    ttfts = [r.metrics.ttft() for r in reqs]
    print(f"TTFT mean={np.mean(ttfts)*1e3:.0f}ms  ticks={engine.stats.ticks} "
          f"preemptions={engine.scheduler.stats.preemptions}")
    pp_pad = engine.stats.padded_prefill / max(
        1, engine.stats.ticks * dims.Sp * dims.C)
    print(f"prefill bucket padding (bubble fraction): {pp_pad:.1%}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 16)
