"""HTTP frontend demo: the full client vocabulary over plain HTTP, no jax.

Builds a 2-replica *heterogeneous* sim cluster from one spec (replica 1 is
a declared straggler), serves it with `repro.serving.http` on an ephemeral
port, and exercises every endpoint with stdlib urllib — generate, SSE
streaming (watch the interactive request beat the earlier batch request),
abort, and stats.  The same endpoints serve a real engine:

    PYTHONPATH=src python -m repro.launch.serve --http 8000
"""

import json
import urllib.request

from repro.serving import (ClusterSpec, EngineSpec, HTTPFrontend, ServeSpec,
                           SimSpec, build)

SPEC = ServeSpec(
    backend="sim",
    engine=EngineSpec(arch="qwen2.5-14b",
                      throttle=dict(max_prefill_tokens=64)),
    sim=SimSpec(pp=2, pages=256, page_size=8),
    cluster=ClusterSpec(replicas=2, sim_overrides=(
        None, {"straggler_stage": 0, "straggler_factor": 4.0})),
)


def post(url, body):
    req = urllib.request.Request(url, data=json.dumps(body).encode(),
                                 method="POST")
    return urllib.request.urlopen(req, timeout=30)


def main() -> None:
    frontend = HTTPFrontend(build(SPEC), port=0).start()
    base = frontend.url
    print(f"serving {SPEC.engine.arch} (2 sim replicas, one straggler) "
          f"on {base}")

    # --- sync generate, one per SLO class (batch submitted first) --------
    outs = {}
    for slo in ("batch", "interactive"):
        outs[slo] = json.loads(post(base + "/v1/generate", {
            "prompt": [7] * 48, "max_new_tokens": 8, "slo_class": slo,
        }).read())
    for slo, out in outs.items():
        print(f"  generate[{slo:11s}] rid={out['request_id']} "
              f"{len(out['token_ids'])} tokens "
              f"ttft={out['metrics']['ttft'] * 1e3:.1f}ms "
              f"-> {out['finish_reason']}")

    # --- streaming SSE ---------------------------------------------------
    resp = post(base + "/v1/generate?stream=1",
                {"prompt": [1, 2, 3], "max_new_tokens": 5})
    frames = [json.loads(line.decode()[len("data: "):])
              for line in resp if line.startswith(b"data: ")]
    print(f"  stream: {len(frames)} SSE frames, "
          f"last finish_reason={frames[-1]['finish_reason']}")

    # --- abort a live stream from a second connection --------------------
    resp = post(base + "/v1/generate?stream=1",
                {"prompt": [4] * 8, "max_new_tokens": 1500,
                 "request_id": "runaway"})
    stream_lines = iter(resp)
    next(stream_lines), next(stream_lines)      # the stream is live
    req = urllib.request.Request(base + "/v1/requests/runaway",
                                 method="DELETE")
    ack = json.loads(urllib.request.urlopen(req, timeout=30).read())
    last = None
    for line in stream_lines:                   # drains fast: stream ends
        if line.startswith(b"data: "):          # with the abort frame
            last = json.loads(line.decode()[len("data: "):])
    print(f"  abort: {ack} -> stream closed with "
          f"finish_reason={last['finish_reason']} after {last['index']} "
          f"tokens (of 1500 asked)")

    # --- stats -----------------------------------------------------------
    stats = json.loads(urllib.request.urlopen(base + "/v1/stats",
                                              timeout=30).read())
    for rep in stats["replicas"]:
        print(f"  stats[replica {rep['index']}] ticks={rep['ticks']} "
              f"retired={rep['tokens_retired']} "
              f"service_rate={rep['service_rate']} "
              f"waiting_by_class={rep['waiting_by_class']}")
    print(f"  routed_counts={stats['routed_counts']} "
          f"(straggler is replica 1)")
    frontend.shutdown()
    print("done — all endpoints exercised over HTTP")


if __name__ == "__main__":
    main()
