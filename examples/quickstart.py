"""Quickstart: build a tiny gLLM engine and generate with Token Throttling.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, make_reduced
from repro.core import SamplingParams, ThrottleConfig
from repro.models import transformer as tfm
from repro.models.serve import ServeDims
from repro.runtime.engine import PipelineEngine


def main():
    # a reduced Qwen-family model (same code path as the full configs)
    cfg = make_reduced(get_config("qwen1.5-0.5b")).with_plan(
        pp=1, tp=1, ep_over_data=False)
    cfg = dataclasses.replace(cfg, dtype="float32")
    mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    dims = ServeDims(Sp=1, C=16, Sd=8, pages=256, page=8, Bp=32, Bd=32,
                     slots=16)
    with jax.set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, tfm.param_pspecs(cfg),
            is_leaf=lambda x: isinstance(x, P))
        # the paper's hyperparameters, scaled to the toy bucket
        throttle = ThrottleConfig(num_iters_T=2, max_prefill_tokens=16,
                                  min_prefill_tokens=4, kv_threshold=0.05,
                                  pipeline_depth=cfg.plan.pp)
        engine = PipelineEngine(cfg, dims, params, mesh, throttle)

    rng = np.random.default_rng(0)
    reqs = [engine.add_request(list(rng.integers(0, cfg.vocab_size, n)),
                               SamplingParams(max_new_tokens=8))
            for n in (12, 30, 7)]
    engine.drain()
    for r in reqs:
        print(f"{r.request_id}: prompt={r.num_prompt_tokens:3d} tokens "
              f"-> {r.output_token_ids}")
    s = engine.stats
    print(f"ticks={s.ticks} scheduled_prefill={s.scheduled_prefill} "
          f"bucket_padding={s.padded_prefill} (the TPU 'bubble' metric)")


if __name__ == "__main__":
    main()
