"""Quickstart: one ServeSpec, one build(), generate with Token Throttling.

    PYTHONPATH=src python examples/quickstart.py

The spec below resolves to a tiny exact engine (reduced Qwen family, same
code path as the full TPU configs).  Swap `backend="sim"` to run the same
scenario on the calibrated roofline simulator, or add
`cluster=ClusterSpec(replicas=2)` for a balanced multi-replica cluster —
the client API does not change.
"""
import numpy as np

from repro.serving import EngineSpec, SamplingParams, ServeSpec, build


def main():
    spec = ServeSpec(
        backend="engine",
        engine=EngineSpec(
            arch="qwen1.5-0.5b",
            # the paper's hyperparameters, scaled to the toy bucket
            throttle=dict(num_iters_T=2, max_prefill_tokens=16,
                          min_prefill_tokens=4, kv_threshold=0.05),
            dims=dict(C=16, pages=256, Bp=32, Bd=32),
        ),
    )
    print(f"spec: {spec.to_json()}")
    server = build(spec)

    rng = np.random.default_rng(0)
    rids = [server.submit(list(rng.integers(0, server.cfg.vocab_size, n)),
                          SamplingParams(max_new_tokens=8))
            for n in (12, 30, 7)]
    server.drain()
    for out in server.outputs(rids):
        print(f"{out.request_id}: prompt={len(out.prompt_token_ids):3d} "
              f"tokens -> {out.token_ids} ({out.finish_reason})")
    s = server.stats().replicas[0]
    eng = server.replicas[0]
    print(f"ticks={s.ticks} tokens_retired={s.tokens_retired} "
          f"service_rate={s.service_rate:.0f} tok/s "
          f"bucket_padding={eng.stats.padded_prefill} "
          f"(the TPU 'bubble' metric)")


if __name__ == "__main__":
    main()
