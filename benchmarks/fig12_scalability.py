"""Paper Fig. 12: maximum throughput scaling as chips increase (pp = 1,2,4,8
stages).  gLLM should scale near-linearly; the TP baseline degrades
cross-node (communication-bound)."""

from __future__ import annotations

from benchmarks.common import Scheme, csv_row, max_throughput


def run(verbose: bool = True, *, arch: str = "qwen2.5-14b",
        cross_node: bool = False):
    """Max throughput with the LOAD scaled alongside the system (paper
    protocol: each configuration is saturated): KV pool, concurrency and
    probe rates all grow with the chip count."""
    rows = []
    for scheme in Scheme.all_main():
        base = None
        for pp in (1, 2, 4, 8):
            t = max_throughput(scheme, arch=arch, pp=pp,
                               num_requests=100 * pp,
                               pages=4096 * pp,
                               cross_node=cross_node,
                               probe_rates=(16 * pp, 48 * pp, 128 * pp))
            base = base or t
            rows.append(csv_row(f"fig12_{scheme.name}_pp{pp}_max_thpt", t,
                                f"x{t / base:.2f} vs pp=1"))
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
