"""Prefix caching and cache-aware routing (DESIGN.md §13).

Production traffic shares prefill: shared-system-prompt pools (every
request from an application repeats the same head) and multi-turn chat
(every turn re-sends the whole history).  With per-replica prefix caches,
*where* a request lands decides whether that shared head is a cache hit
or a full recompute — a load-only router scatters a pool's requests
across replicas and re-prefills the same head everywhere, while the
cache-aware router's `cache_affinity` credit steers each request toward
the replica already holding its longest cached prefix.

Two routing modes per workload, identical replicas and arrivals:

  load-only     balanced placement, cache_affinity=0 (cache-blind)
  cache-aware   balanced placement + cached-prefix credit (the default)

Reported per mode: prefill tokens avoided (the scheduler's adoption
counters), cache hit rate, and mean/p95 TTFT.

`--check` exits non-zero unless cache-aware routing avoids strictly more
prefill than load-only and does not lose on mean TTFT — the CI smoke gate
(`make prefix-check`).
"""

from __future__ import annotations

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.core import PagedKVManager, PipelineScheduler, PrefillPolicy, ThrottleConfig
from repro.data.workload import multi_turn_requests, shared_prefix_requests
from repro.runtime.router import BalanceWeights, ReplicaRouter, SimCluster
from repro.runtime.simulator import PipelineSimulator, cost_model_for

MODES = ("load-only", "cache-aware")


def _weights_for(mode: str) -> BalanceWeights:
    return BalanceWeights(cache_affinity=0.0 if mode == "load-only" else 1.0)


def _arrivals(workload: str, rate: float, n: int, seed: int):
    if workload == "shared-prefix":
        return shared_prefix_requests(n, rate, num_pools=2, prefix_len=1024,
                                      mean_suffix=48.0, seed=seed)
    if workload == "multi-turn":
        return multi_turn_requests(max(n // 4, 1), rate, mean_turns=5.0,
                                   seed=seed)
    raise ValueError(workload)


def _make_sched(pp: int, pages: int) -> PipelineScheduler:
    th = ThrottleConfig(pipeline_depth=pp, policy=PrefillPolicy.GLLM)
    kv = PagedKVManager(num_pages=pages, page_size=16,
                        enable_prefix_caching=True)
    return PipelineScheduler(th, kv, max_model_len=pages * 16)


def run_cluster(mode: str, workload: str, *, arch: str = "qwen2.5-14b",
                rate: float = 30.0, num_requests: int = 120, pp: int = 4,
                pages: int = 8192, replicas: int = 2,
                seed: int = 0) -> SimCluster:
    """Homogeneous cache-enabled cluster under one routing mode."""
    cfg = get_config(arch)
    cost = cost_model_for(cfg, pp=pp)
    sims = [PipelineSimulator(_make_sched(pp, pages), pp, cost)
            for _ in range(replicas)]
    router = ReplicaRouter(sims, policy="balanced",
                           weights=_weights_for(mode))
    cluster = SimCluster(sims, router)
    cluster.run(_arrivals(workload, rate, num_requests, seed))
    return cluster


def _avoided(cluster: SimCluster) -> int:
    return sum(s.sched.stats.prefix_tokens_avoided for s in cluster.sims)


def _hit_rate(cluster: SimCluster) -> float:
    hits = sum(s.sched.stats.prefix_hits for s in cluster.sims)
    lookups = sum(s.sched.stats.prefix_lookups for s in cluster.sims)
    return hits / max(lookups, 1)


def run(verbose: bool = True, workloads=("shared-prefix", "multi-turn"),
        **kw):
    rows = []
    for workload in workloads:
        avoided = {}
        ttft = {}
        for mode in MODES:
            c = run_cluster(mode, workload, **kw)
            avoided[mode] = _avoided(c)
            ttft[mode] = c.mean_ttft()
            tag = f"{workload}_{mode}".replace("-", "_")
            rows.append(csv_row(
                f"fig_prefix_{tag}_prefill_tokens_avoided",
                avoided[mode], f"hit_rate={_hit_rate(c):.2f}"))
            rows.append(csv_row(
                f"fig_prefix_{tag}_ttft_mean_s", c.mean_ttft()))
            rows.append(csv_row(
                f"fig_prefix_{tag}_ttft_p95_s", c.ttft_quantile(0.95)))
            rows.append(csv_row(
                f"fig_prefix_{tag}_thpt_tok_s", c.throughput()))
        rows.append(csv_row(
            f"fig_prefix_{workload.replace('-', '_')}_avoided_aware_over_blind",
            avoided["cache-aware"] / max(avoided["load-only"], 1),
            "affinity routing turns shared heads into hits"))
        rows.append(csv_row(
            f"fig_prefix_{workload.replace('-', '_')}_ttft_blind_over_aware",
            ttft["load-only"] / max(ttft["cache-aware"], 1e-9)))
    if verbose:
        for r in rows:
            print(r)
    return rows


def check() -> bool:
    """CI smoke gate: on the pooled shared-prefix workload, cache-aware
    routing must (1) avoid prefill at all, (2) avoid strictly more than a
    cache-blind router stumbling into accidental hits, and (3) not trade
    that away on mean TTFT."""
    blind = run_cluster("load-only", "shared-prefix")
    aware = run_cluster("cache-aware", "shared-prefix")
    a_av, b_av = _avoided(aware), _avoided(blind)
    a_t, b_t = aware.mean_ttft(), blind.mean_ttft()
    good = a_av > 0 and a_av > b_av and a_t <= b_t * 1.05
    print(f"# prefix-check: tokens avoided cache-aware={a_av} "
          f"load-only={b_av}; mean TTFT cache-aware={a_t:.3f}s "
          f"load-only={b_t:.3f}s -> {'OK' if good else 'FAIL'}")
    return good


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="CI gate: cache-aware routing must beat load-only "
                    "on prefill tokens avoided without losing TTFT")
    args = ap.parse_args()
    if args.check:
        raise SystemExit(0 if check() else 1)
    run()
