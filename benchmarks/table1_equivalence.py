"""Paper Table 1 (output quality): the MMLU-pro comparison reduces to the
claim that gLLM's scheduling does not change model outputs.  We verify it
directly: the real engine (paged KV, chunked prefill, throttled batching)
must emit exactly the greedy tokens of a dense full-recompute reference."""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import csv_row


def run(verbose: bool = True, *, num_prompts: int = 8, new_tokens: int = 6):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, make_reduced
    from repro.core import SamplingParams, ThrottleConfig
    from repro.models import transformer as tfm
    from repro.models.reference import greedy_generate
    from repro.models.serve import ServeDims
    from repro.runtime.engine import PipelineEngine

    cfg = make_reduced(get_config("qwen2.5-14b")).with_plan(
        pp=1, tp=1, ep_over_data=False)
    cfg = dataclasses.replace(cfg, dtype="float32")
    mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    dims = ServeDims(Sp=1, C=16, Sd=8, pages=512, page=8, Bp=32, Bd=32,
                     slots=16)
    with jax.set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        pspecs = tfm.param_pspecs(cfg)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, pspecs, is_leaf=lambda x: isinstance(x, P))
        eng = PipelineEngine(cfg, dims, params, mesh,
                             ThrottleConfig(pipeline_depth=1,
                                            max_prefill_tokens=16,
                                            min_prefill_tokens=4,
                                            num_iters_T=2))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, int(n)))
               for n in rng.integers(5, 40, num_prompts)]
    reqs = [eng.add_request(p, SamplingParams(max_new_tokens=new_tokens))
            for p in prompts]
    eng.drain(max_ticks=2000)
    match = sum(
        r.output_token_ids == greedy_generate(cfg, params, p, new_tokens)
        for p, r in zip(prompts, reqs))
    rows = [csv_row("table1_exact_output_match_rate", match / num_prompts,
                    f"{match}/{num_prompts} greedy continuations identical")]
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
