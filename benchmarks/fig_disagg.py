"""Disaggregated prefill/decode serving vs the throttled hybrid
(DESIGN.md §15): the TD-Pipe question asked on our own stack.

gLLM's Token Throttling balances prefill and decode *within* hybrid
batches; TD-Pipe argues that *temporally separating* the phases onto
dedicated replicas wins at high load because prefill chunks stop
inflating decode ticks (TBT) and decode residents stop starving prefill
admission (TTFT).  This study runs both cluster shapes from declarative
`ServeSpec`s on the same prefill-heavy workload:

  hybrid    N mixed replicas, admission balancing + rebalance control
            plane — the throttled-hybrid baseline this repo is built on
  P:D       P prefill-role + D decode-role replicas (P+D = N) with the
            first-decode KV handoff control plane shipping every freshly
            prefilled request to the decode side

Per SLO class (interactive / batch) each shape reports p95 TTFT, p95 TBT
(time between tokens ~ TPOT), and goodput — SLO-attaining requests per
second of makespan.  The ratio sweep traces the frontier: too few
prefill replicas and TTFT collapses, too few decode replicas and TBT
does; the interesting question is whether the best ratio beats the
hybrid at its own game.

`--check` is the CI gate (`make disagg-check`): on the prefill-heavy
scenario the best disaggregated ratio must not lose to the hybrid on
interactive goodput, and handoffs must actually flow.

`--engine` runs the same comparison over HTTP on the reduced live
engine (CPU-sized, smoke-scale): two mixed replicas vs prefill+decode,
requests POSTed to `/v1/generate`, per-role queue depth and handoff
counts read back from `GET /v1/stats`.

`--out PATH` writes the sweep as JSON (the checked-in smoke result is
`BENCH_disagg.json` at the repo root, next to `BENCH_engine.json`).
"""

from __future__ import annotations

import json

import numpy as np

from benchmarks.common import csv_row
from repro.core import SLO_BATCH, SLO_INTERACTIVE, SamplingParams
from repro.data.workload import WorkloadSpec, sample_requests
from repro.runtime.autoscale import DEFAULT_SLOS, attainment_by_class
from repro.runtime.disagg import HandoffPolicy
from repro.runtime.router import RebalancePolicy
from repro.serving import ClusterSpec, ServeSpec, SimSpec, build

# Prefill-heavy: long prompts, outputs long enough that decode residency
# matters (the regime where phase interference shows — paper Fig. 11's
# Azure-like shape, scaled to the sim scenario).
PREFILL_HEAVY = WorkloadSpec("prefill-heavy", mean_input=1200.0,
                             mean_output=96.0, sigma=0.7)

# Per-class SLO targets for goodput (sim seconds): the shared table from
# the autoscale module — one definition across `GET /v1/stats`,
# fig_autoscale, and this study (the interactive TBT target sits right at
# the hybrid's observed tail, because decode-tick isolation is exactly
# what disaggregation sells).
SLOS = DEFAULT_SLOS


def disagg_arrivals(num_requests: int, rate: float, *, seed: int = 0,
                    interactive_frac: float = 0.6):
    """Prefill-heavy Poisson arrivals with an SLO-class mix, in the
    4-tuple form `SimCluster.run` injects (sampling carries the class)."""
    base = sample_requests(PREFILL_HEAVY, num_requests, rate, seed=seed)
    rng = np.random.default_rng(seed + 1)
    out = []
    for t, prompt, lo in base:
        cls = (SLO_INTERACTIVE if rng.random() < interactive_frac
               else SLO_BATCH)
        out.append((t, prompt, lo,
                    SamplingParams(max_new_tokens=lo, slo_class=cls)))
    return out


def cluster_spec(roles, *, replicas: int = 4, pp: int = 4,
                 pages: int = 4096) -> ServeSpec:
    """The declarative description of one cluster shape: roles=None is
    the throttled hybrid (+ rebalance control plane); a role tuple turns
    on the first-decode handoff plane."""
    handoff = None if roles is None else HandoffPolicy(
        interval=0.02, handoff_batch=8, max_decode_tokens=8)
    return ServeSpec(
        backend="sim",
        sim=SimSpec(pp=pp, pages=pages),
        cluster=ClusterSpec(replicas=replicas, route="balanced",
                            rebalance=RebalancePolicy(),
                            roles=roles, handoff=handoff))


# The shared per-class attainment/goodput report (tests pin this
# identity: fig_disagg and fig_autoscale must score requests the same
# way the stats surface does).
_per_class = attainment_by_class


def run_shape(roles, arrivals, *, replicas: int = 4, pp: int = 4,
              pages: int = 4096):
    """Build one shape from its spec, serve the arrivals, report."""
    server = build(cluster_spec(roles, replicas=replicas, pp=pp,
                                pages=pages))
    cluster = server.engine
    finished = cluster.run(arrivals)
    elapsed = max((r.metrics.finish_time or 0.0) for r in finished)
    stats = server.stats()
    report = {
        "roles": list(roles) if roles is not None else None,
        "finished": len(finished),
        "classes": _per_class(finished, SLOS, elapsed=elapsed),
        "queue_depth_by_role": stats.queue_depth_by_role,
    }
    if stats.disagg is not None:
        report["handoffs"] = stats.disagg.handoffs
        report["handoff_tokens"] = stats.disagg.handoff_tokens
        report["handoff_fallbacks"] = stats.disagg.fallbacks
    return report


def ratio_roles(p: int, d: int):
    return ("prefill",) * p + ("decode",) * d


def run(verbose: bool = True, *, num_requests: int = 120, rate: float = 24.0,
        replicas: int = 4, pp: int = 4, pages: int = 4096, seed: int = 0):
    """The sweep: hybrid baseline, then every P:D split of the fleet."""
    arrivals = disagg_arrivals(num_requests, rate, seed=seed)
    shapes = [("hybrid", None)]
    shapes += [(f"{p}P{replicas - p}D", ratio_roles(p, replicas - p))
               for p in range(1, replicas)]
    results = {}
    rows = []
    for name, roles in shapes:
        rep = run_shape(roles, arrivals, replicas=replicas, pp=pp,
                        pages=pages)
        results[name] = rep
        for cls, m in rep["classes"].items():
            rows.append(csv_row(
                f"fig_disagg_{name}_{cls}_goodput_rps", m["goodput"],
                f"ttft_p95={m['ttft_p95']:.3f}s tbt_p95={m['tbt_p95']:.3f}s"
                + (f" handoffs={rep['handoffs']}" if "handoffs" in rep
                   else "")))
    if verbose:
        for r in rows:
            print(r)
    return {"workload": {"num_requests": num_requests, "rate": rate,
                         "mean_input": PREFILL_HEAVY.mean_input,
                         "mean_output": PREFILL_HEAVY.mean_output,
                         "seed": seed},
            "cluster": {"replicas": replicas, "pp": pp, "pages": pages},
            "slos": SLOS,
            "shapes": results}


def check(verbose: bool = True) -> bool:
    """CI smoke gate: on the prefill-heavy scenario the best P:D split
    must (a) actually hand requests off, and (b) not lose to the
    throttled hybrid on interactive goodput or interactive p95 TBT."""
    sweep = run(verbose=False)
    shapes = sweep["shapes"]
    hybrid = shapes["hybrid"]["classes"][SLO_INTERACTIVE]
    best_name, best = max(
        ((n, s) for n, s in shapes.items() if n != "hybrid"),
        key=lambda ns: ns[1]["classes"][SLO_INTERACTIVE]["goodput"])
    bi = best["classes"][SLO_INTERACTIVE]
    handoffs = best.get("handoffs", 0)
    ok = (handoffs > 0
          and bi["goodput"] >= hybrid["goodput"]
          and bi["tbt_p95"] <= hybrid["tbt_p95"])
    if verbose:
        print(f"# disagg-check: hybrid goodput={hybrid['goodput']:.3f}/s "
              f"tbt_p95={hybrid['tbt_p95']:.3f}s | best={best_name} "
              f"goodput={bi['goodput']:.3f}/s tbt_p95={bi['tbt_p95']:.3f}s "
              f"handoffs={handoffs} -> {'OK' if ok else 'FAIL'}")
    return ok


# ---------------------------------------------------------------------------
# the same comparison over HTTP on the live (reduced) engine
# ---------------------------------------------------------------------------

def run_http(num_requests: int = 6, *, max_new_tokens: int = 8,
             verbose: bool = True):
    """Smoke-scale engine comparison: two mixed replicas vs one prefill +
    one decode, requests POSTed to `/v1/generate` on the real HTTP
    frontend, disagg counters read back from `GET /v1/stats`."""
    import http.client

    from repro.serving import EngineSpec, HTTPFrontend

    def serve(roles):
        handoff = None if roles is None else HandoffPolicy(
            interval=0.005, max_decode_tokens=max_new_tokens)
        spec = ServeSpec(
            engine=EngineSpec(reduced=True),
            cluster=ClusterSpec(replicas=2, roles=roles, handoff=handoff))
        frontend = HTTPFrontend(build(spec), port=0).start()
        conn = http.client.HTTPConnection(frontend.host, frontend.port)
        ttfts = []
        try:
            rng = np.random.default_rng(0)
            for i in range(num_requests):
                body = json.dumps({
                    "prompt": rng.integers(1, 1000, 24).tolist(),
                    "max_tokens": max_new_tokens,   # OpenAI alias
                })
                conn.request("POST", "/v1/generate", body)
                resp = json.loads(conn.getresponse().read())
                assert resp["choices"][0]["finish_reason"] == "length", resp
                ttfts.append(resp["metrics"]["ttft"])
            conn.request("GET", "/v1/stats")
            stats = json.loads(conn.getresponse().read())
        finally:
            conn.close()
            frontend.shutdown()
        return ttfts, stats

    out = {}
    for name, roles in (("hybrid", None),
                        ("disagg", ("prefill", "decode"))):
        ttfts, stats = serve(roles)
        out[name] = {
            "ttft_mean": float(np.mean(ttfts)),
            "roles": [r["role"] for r in stats["replicas"]],
            "handoffs": stats.get("disagg", {}).get("handoffs", 0),
            "queue_depth_by_role": stats.get("queue_depth_by_role"),
        }
        if verbose:
            print(f"# fig_disagg[http/{name}]: mean TTFT "
                  f"{out[name]['ttft_mean'] * 1e3:.1f}ms roles="
                  f"{out[name]['roles']} handoffs={out[name]['handoffs']}")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="CI gate: best disagg ratio must not lose to the "
                    "throttled hybrid on the prefill-heavy scenario")
    ap.add_argument("--engine", action="store_true",
                    help="run the HTTP-on-live-engine comparison (slow)")
    ap.add_argument("--out", help="write the sim sweep as JSON")
    args = ap.parse_args()
    if args.check:
        raise SystemExit(0 if check() else 1)
    if args.engine:
        run_http()
        raise SystemExit(0)
    sweep = run()
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(sweep, fh, indent=2, sort_keys=True)
            fh.write("\n")
