"""Elastic fleet vs peak-provisioned static fleet (DESIGN.md §16).

gLLM balances work *within* a fleet; this study asks what the fleet costs.
Production load is not flat — diurnal swings and flash crowds move the
request rate by integer factors — so a static fleet must be sized for its
peak and then burns replica-hours all night serving the trough.  The
autoscaler on the router control plane (`AutoscalePolicy`) grows the fleet
on sustained queue/KV pressure and shrinks it by draining (mask from
admission, steal waiting work, live-migrate residents, retire), so the
fleet tracks the load curve instead of its maximum.

Two cluster shapes from declarative `ServeSpec`s per scenario:

  static      `peak` replicas, admission balancing only — the fleet an
              operator provisions when the only tool is peak sizing
  autoscaled  starts at `start` replicas with `AutoscalePolicy(max_
              replicas=peak)` — same ceiling, elastic floor

Scenarios: a diurnal sinusoid (trough -> peak -> trough) and a flash crowd
(steady base rate with a hard step), both with an interactive/batch SLO
class mix.  Per fleet we report per-class SLO attainment (the shared
`attainment_by_class` definition — same numbers as `GET /v1/stats` and
fig_disagg) and *replica-seconds*, the integral of fleet size over the
serving window (`AutoscaleStats.replica_seconds`; a draining replica still
counts until retired).

`--check` is the CI gate (`make autoscale-check`), reduced scale: on every
scenario the autoscaled fleet must match the static fleet's interactive
attainment while spending <= 75% of its replica-seconds.

The full run (no flags) sizes the fleet at O(100) replicas and writes
`BENCH_autoscale.json` at the repo root; `--validate PATH` re-validates a
checked-in document's schema (`make bench-smoke`).
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict

import numpy as np

from benchmarks.common import csv_row
from repro.core import SLO_BATCH, SLO_INTERACTIVE, SamplingParams
from repro.data.workload import diurnal_requests, flash_crowd_requests
from repro.runtime.autoscale import (
    DEFAULT_SLOS,
    AutoscalePolicy,
    attainment_by_class,
)
from repro.serving import ClusterSpec, ServeSpec, SimSpec, build

REPO_ROOT = Path(__file__).resolve().parent.parent
BENCH_SCHEMA = "repro-bench-autoscale/1"

# The shared per-class targets: one definition across the stats surface and
# every benchmark that reports attainment (tests pin this identity).
SLOS = DEFAULT_SLOS

SCENARIOS = ("diurnal", "flash_crowd")


def _with_classes(base, *, interactive_frac: float = 0.6, seed: int = 0):
    """Attach the SLO-class mix: 3-tuples from the workload generators ->
    the 4-tuple form `SimCluster.run` injects (sampling carries the
    class)."""
    rng = np.random.default_rng(seed + 1)
    out = []
    for t, prompt, lo in base:
        cls = (SLO_INTERACTIVE if rng.random() < interactive_frac
               else SLO_BATCH)
        out.append((t, prompt, lo,
                    SamplingParams(max_new_tokens=lo, slo_class=cls)))
    return out


def scenario_arrivals(name: str, *, duration: float, peak_rate: float,
                      base_rate: float, seed: int = 0):
    """One elastic-serving stressor, classes attached.  `diurnal` sweeps a
    full sinusoid trough->peak->trough; `flash_crowd` steps from the base
    rate to the peak for a fifth of the window with no leading edge."""
    # Long decode residency (relative to the tight per-replica KV pool in
    # `fleet_spec`): each resident parks a few hundred KV tokens for its
    # whole decode, so concurrency — not raw token rate — is what the
    # fleet must be sized for.
    shape = dict(mean_input=96.0, mean_output=192.0, max_output=512)
    if name == "diurnal":
        base = diurnal_requests(duration, base_rate=base_rate,
                                peak_rate=peak_rate, seed=seed, **shape)
    elif name == "flash_crowd":
        base = flash_crowd_requests(
            duration, base_rate=base_rate, spike_rate=peak_rate,
            spike_start=duration * 0.3, spike_len=duration * 0.2,
            seed=seed, **shape)
    else:
        raise ValueError(f"unknown scenario {name!r}")
    return _with_classes(base, seed=seed)


def fleet_spec(*, replicas: int, peak: int, elastic: bool, pp: int = 2,
               pages: int = 256, page_size: int = 8) -> ServeSpec:
    """Declarative description of one fleet.  The elastic fleet gets the
    same `peak` ceiling the static fleet is provisioned at — the study
    varies the floor, not the capacity.  Per-replica KV is deliberately
    tight (page budget ~2k tokens): a replica saturates at a couple dozen
    residents, so the load curve translates into fleet-size demand rather
    than vanishing into one replica's slack."""
    autoscale = AutoscalePolicy(
        interval=0.1, min_replicas=1, max_replicas=peak,
        target_queue=2.0, up_cooldown=0.2, down_cooldown=2.0,
        max_step_up=max(8, peak // 4)) if elastic else None
    return ServeSpec(
        backend="sim",
        sim=SimSpec(pp=pp, pages=pages, page_size=page_size),
        cluster=ClusterSpec(replicas=replicas, route="balanced",
                            autoscale=autoscale))


def run_fleet(arrivals, *, replicas: int, peak: int, elastic: bool,
              pp: int = 2, pages: int = 256) -> Dict[str, Any]:
    """Build one fleet from its spec, serve the arrivals, report
    attainment + replica-seconds."""
    server = build(fleet_spec(replicas=replicas, peak=peak,
                              elastic=elastic, pp=pp, pages=pages))
    cluster = server.engine
    finished = cluster.run(arrivals)
    elapsed = max((r.metrics.finish_time or 0.0) for r in finished)
    report: Dict[str, Any] = {
        "start_replicas": replicas,
        "finished": len(finished),
        "elapsed_s": elapsed,
        "classes": attainment_by_class(finished, SLOS, elapsed=elapsed),
    }
    if elastic:
        st = cluster.router.autoscale_stats
        report["replica_seconds"] = st.replica_seconds(replicas, 0.0,
                                                       elapsed)
        report["peak_replicas"] = max(
            [replicas] + [size for _, kind, size in st.events
                          if kind != "drain"])
        report["scale_ups"] = st.scale_ups
        report["replicas_added"] = st.replicas_added
        report["retired"] = st.retired
        report["drain_moves"] = st.drain_moves
    else:
        report["replica_seconds"] = replicas * elapsed
        report["peak_replicas"] = replicas
    return report


def run_scenario(name: str, *, peak: int, start: int, duration: float,
                 peak_rate: float, base_rate: float,
                 seed: int = 0) -> Dict[str, Any]:
    """Static-vs-autoscaled on one load curve.  `rs_ratio` is the cost
    axis (autoscaled replica-seconds over static); the gate additionally
    reads interactive attainment out of `classes`."""
    arrivals = scenario_arrivals(name, duration=duration,
                                 peak_rate=peak_rate, base_rate=base_rate,
                                 seed=seed)
    static = run_fleet(arrivals, replicas=peak, peak=peak, elastic=False)
    auto = run_fleet(arrivals, replicas=start, peak=peak, elastic=True)
    return {
        "arrivals": len(arrivals),
        "duration_s": duration,
        "base_rate": base_rate,
        "peak_rate": peak_rate,
        "static": static,
        "autoscaled": auto,
        "rs_ratio": auto["replica_seconds"]
        / max(static["replica_seconds"], 1e-9),
    }


def _gate(sc: Dict[str, Any]) -> bool:
    """The acceptance bar: interactive attainment no worse than the
    peak-provisioned fleet, at <= 75% of its replica-seconds."""
    a = sc["autoscaled"]["classes"][SLO_INTERACTIVE]["attainment"]
    s = sc["static"]["classes"][SLO_INTERACTIVE]["attainment"]
    return a >= s and sc["rs_ratio"] <= 0.75


def run(verbose: bool = True, *, peak: int = 96, start: int = 12,
        duration: float = 40.0, peak_rate: float = 400.0,
        base_rate: float = 10.0, seed: int = 0) -> Dict[str, Any]:
    """Both scenarios at one fleet scale.  Defaults are the full O(100)
    study; `check()` re-runs it reduced."""
    scenarios = {}
    rows = []
    for name in SCENARIOS:
        sc = run_scenario(name, peak=peak, start=start, duration=duration,
                          peak_rate=peak_rate, base_rate=base_rate,
                          seed=seed)
        sc["gate"] = _gate(sc)
        scenarios[name] = sc
        for fleet in ("static", "autoscaled"):
            m = sc[fleet]["classes"][SLO_INTERACTIVE]
            rows.append(csv_row(
                f"fig_autoscale_{name}_{fleet}_interactive_attainment",
                m["attainment"],
                f"ttft_p95={m['ttft_p95']:.3f}s "
                f"replica_seconds={sc[fleet]['replica_seconds']:.1f}"))
        rows.append(csv_row(
            f"fig_autoscale_{name}_replica_seconds_ratio", sc["rs_ratio"],
            f"peak={sc['autoscaled']['peak_replicas']}"
            f"/{sc['static']['peak_replicas']} replicas, "
            f"gate={'OK' if sc['gate'] else 'FAIL'}"))
    if verbose:
        for r in rows:
            print(r)
    return {
        "schema": BENCH_SCHEMA,
        "cluster": {"peak": peak, "start": start, "pp": 2, "pages": 256,
                    "page_size": 8, "seed": seed},
        "slos": SLOS,
        "scenarios": scenarios,
    }


def check(verbose: bool = True) -> bool:
    """CI smoke gate (`make autoscale-check`), reduced scale: every
    scenario must pass `_gate` — attainment held at <= 75% of the static
    fleet's replica-seconds — *and* demonstrably exercise the elastic
    loop (scale-ups and retirements both fired; a load too light to grow
    the fleet would pass the cost gate without testing anything)."""
    doc = run(verbose=False, peak=12, start=2, duration=30.0,
              peak_rate=30.0, base_rate=2.0)
    ok = True
    for name, sc in doc["scenarios"].items():
        auto = sc["autoscaled"]
        a = auto["classes"][SLO_INTERACTIVE]["attainment"]
        s = sc["static"]["classes"][SLO_INTERACTIVE]["attainment"]
        good = (sc["gate"] and auto["replicas_added"] > 0
                and auto["retired"] > 0)
        ok = ok and good
        if verbose:
            print(f"# autoscale-check[{name}]: interactive attainment "
                  f"auto={a:.3f} static={s:.3f} "
                  f"replica_seconds_ratio={sc['rs_ratio']:.3f} "
                  f"(peak {auto['peak_replicas']}"
                  f"/{sc['static']['peak_replicas']} replicas, "
                  f"+{auto['replicas_added']}/-{auto['retired']}) "
                  f"-> {'OK' if good else 'FAIL'}")
    return ok


def validate(doc: Dict[str, Any]) -> None:
    """Schema check for BENCH_autoscale.json (no external deps): raises
    ValueError with the offending path on any violation."""
    def need(cond, path, msg):
        if not cond:
            raise ValueError(f"BENCH_autoscale.json invalid at {path}: "
                             f"{msg}")

    need(doc.get("schema") == BENCH_SCHEMA, "schema",
         f"expected {BENCH_SCHEMA!r}, got {doc.get('schema')!r}")
    need(isinstance(doc.get("cluster"), dict), "cluster", "missing dict")
    for k in ("peak", "start", "seed"):
        need(k in doc["cluster"], f"cluster.{k}", "missing")
    need(isinstance(doc.get("slos"), dict), "slos", "missing dict")
    need(isinstance(doc.get("scenarios"), dict), "scenarios",
         "missing dict")
    need(set(doc["scenarios"]) == set(SCENARIOS), "scenarios",
         f"expected {sorted(SCENARIOS)}, got {sorted(doc['scenarios'])}")
    for name, sc in doc["scenarios"].items():
        p = f"scenarios.{name}"
        need(sc.get("gate") is True, f"{p}.gate",
             "checked-in result must pass the attainment/cost gate")
        need(0.0 < sc.get("rs_ratio", -1.0) <= 0.75, f"{p}.rs_ratio",
             "autoscaled fleet must spend <= 75% of static "
             "replica-seconds")
        for fleet in ("static", "autoscaled"):
            rep = sc.get(fleet)
            need(isinstance(rep, dict), f"{p}.{fleet}", "missing dict")
            for k in ("finished", "elapsed_s", "replica_seconds",
                      "peak_replicas"):
                need(isinstance(rep.get(k), (int, float)),
                     f"{p}.{fleet}.{k}",
                     f"missing or non-numeric: {rep.get(k)!r}")
            cls = rep.get("classes", {})
            for c in (SLO_INTERACTIVE, SLO_BATCH):
                need(isinstance(cls.get(c), dict), f"{p}.{fleet}."
                     f"classes.{c}", "missing dict")
                att = cls[c].get("attainment")
                need(isinstance(att, (int, float)) and 0.0 <= att <= 1.0,
                     f"{p}.{fleet}.classes.{c}.attainment",
                     "out of [0, 1]")
        auto = sc["autoscaled"]
        need(auto.get("replicas_added", 0) > 0, f"{p}.autoscaled."
             "replicas_added", "elastic run must actually scale up")
        need(auto.get("retired", 0) > 0, f"{p}.autoscaled.retired",
             "elastic run must actually scale back down")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--check", action="store_true",
                    help="CI gate: autoscaled fleet must hold interactive "
                    "attainment at <= 75% of static replica-seconds")
    ap.add_argument("--validate", type=Path, default=None, metavar="PATH",
                    help="only validate an existing bench document and "
                    "exit")
    ap.add_argument("--out", type=Path, default=None,
                    help=f"output path (default: {REPO_ROOT}/"
                    "BENCH_autoscale.json)")
    args = ap.parse_args()
    if args.validate is not None:
        validate(json.loads(args.validate.read_text()))
        print(f"{args.validate}: valid {BENCH_SCHEMA}")
        raise SystemExit(0)
    if args.check:
        raise SystemExit(0 if check() else 1)
    doc = run()
    validate(doc)
    out = args.out or REPO_ROOT / "BENCH_autoscale.json"
    with open(out, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"# wrote {out}")
