"""Shared benchmark harness: simulator setup per (model, system) scheme."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs import get_config
from repro.core import PagedKVManager, PipelineScheduler, PrefillPolicy, ThrottleConfig
from repro.data.workload import get_workload, sample_requests
from repro.runtime.simulator import (
    CostModel,
    PipelineSimulator,
    RuntimeModel,
    SimMetrics,
    cost_model_for,
)


@dataclass(frozen=True)
class Scheme:
    """A serving system under comparison (paper §4.1 'Schemes')."""

    name: str
    policy: PrefillPolicy
    runtime: RuntimeModel
    tensor_parallel: bool = False     # SGLang-like TP baseline (pp=1, chips=N)

    @staticmethod
    def all_main() -> List["Scheme"]:
        return [
            Scheme("gLLM", PrefillPolicy.GLLM, RuntimeModel.gllm()),
            Scheme("vLLM-like(PP)", PrefillPolicy.SARATHI,
                   RuntimeModel.vllm_like()),
            Scheme("SGLang-like(TP)", PrefillPolicy.SARATHI,
                   RuntimeModel.gllm(), tensor_parallel=True),
        ]

    @staticmethod
    def ablations() -> List["Scheme"]:
        return [
            Scheme("gLLM", PrefillPolicy.GLLM, RuntimeModel.gllm()),
            Scheme("gLLM w/o WT", PrefillPolicy.NO_WT, RuntimeModel.gllm()),
            Scheme("gLLM w/o UT", PrefillPolicy.NO_UT, RuntimeModel.gllm()),
            Scheme("gLLM w/ CK", PrefillPolicy.SARATHI, RuntimeModel.gllm()),
            Scheme("vLLM-like(PP)", PrefillPolicy.SARATHI,
                   RuntimeModel.vllm_like()),
        ]


def simulate(
    scheme: Scheme,
    *,
    arch: str = "qwen2.5-14b",
    workload: str = "sharegpt",
    rate: float = 12.0,
    num_requests: int = 200,
    pp: int = 4,
    pages: int = 8192,
    seed: int = 0,
    throttle_overrides: Optional[dict] = None,
    cross_node: bool = False,
) -> SimMetrics:
    cfg = get_config(arch)
    th_kw = dict(pipeline_depth=pp, policy=scheme.policy)
    th_kw.update(throttle_overrides or {})
    th = ThrottleConfig(**th_kw)
    kv = PagedKVManager(num_pages=pages, page_size=16)
    sched = PipelineScheduler(th, kv, max_model_len=pages * 16)

    if scheme.tensor_parallel:
        # TP folds the whole model onto pp chips with per-token activation
        # all-reduces (2 per layer): high bandwidth demand, no pipelining.
        base = cost_model_for(cfg, chips_per_stage=pp, pp=1)
        cost = CostModel(
            flops_per_token_stage=base.flops_per_token_stage,
            param_bytes_stage=base.param_bytes_stage,
            kv_bytes_per_ctx_token=base.kv_bytes_per_ctx_token,
            chips_per_stage=pp,
            # 2 all-reduces/layer x activation row (d x 2B).  Wire bytes:
            # intra-pod ICI rings have a dedicated link per hop (~2B per
            # token); cross-node, every rank's shards serialize through the
            # shared node NIC => 2(N-1)·B per all-reduce.
            comm_bytes_per_token=2 * cfg.num_layers * cfg.d_model * 2
            * (2 * (pp - 1) if cross_node else 2) / 2,
            # plus ~2(N-1) link latencies per all-reduce
            # (cross-node TCP/RDMA ~400us, intra-pod ICI ~5us)
            comm_latency=2 * cfg.num_layers
            * (400e-6 if cross_node else 5e-6),
            net_bw=9.2e9 if cross_node else 50e9,   # 73.28 Gbps sim-network
        )
        sim_pp = 1
    else:
        cost = cost_model_for(cfg, chips_per_stage=1, pp=pp)
        sim_pp = pp
    sim = PipelineSimulator(sched, sim_pp, cost, scheme.runtime)
    spec = get_workload(workload)
    sim.add_workload(sample_requests(spec, num_requests, rate, seed=seed))
    return sim.run()


def rate_sweep(scheme: Scheme, rates, **kw) -> List[Tuple[float, SimMetrics]]:
    return [(r, simulate(scheme, rate=r, **kw)) for r in rates]


def max_throughput(scheme: Scheme, *, probe_rates=(8, 32, 96, 256),
                   **kw) -> float:
    best = 0.0
    for r in probe_rates:
        m = simulate(scheme, rate=float(r), **kw)
        best = max(best, m.throughput())
    return best


def csv_row(name: str, value: float, derived: str = "") -> str:
    return f"{name},{value:.6g},{derived}"
