"""Paper Fig. 16: hyperparameter sensitivity — #T, #MaxP, #MinP, KV_thresh."""

from __future__ import annotations

from benchmarks.common import Scheme, csv_row, simulate
from repro.core import PrefillPolicy
from repro.runtime.simulator import RuntimeModel

GLLM = Scheme("gLLM", PrefillPolicy.GLLM, RuntimeModel.gllm())

SWEEPS = {
    "num_iters_T": (1, 2, 4, 8, 16),
    "max_prefill_tokens": (512, 1024, 2048, 4096),
    "min_prefill_tokens": (8, 32, 128, 512),
    "kv_threshold": (0.0, 0.05, 0.1, 0.2),
}


def run(verbose: bool = True, *, arch: str = "qwen2.5-14b",
        rate: float = 24.0):
    rows = []
    for knob, values in SWEEPS.items():
        for v in values:
            m = simulate(GLLM, arch=arch, rate=rate, num_requests=120,
                         pages=4096, throttle_overrides={knob: v})
            rows.append(csv_row(
                f"fig16_{knob}={v}_e2el_s", m.e2el(),
                f"ttft={m.ttft()*1e3:.0f}ms tpot={m.tpot()*1e3:.1f}ms "
                f"thpt={m.throughput():.0f}"))
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
