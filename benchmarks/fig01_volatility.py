"""Paper Fig. 1: per-iteration scheduled token counts — Sarathi-Serve's
volatility vs gLLM's balance.  Metric: coefficient of variation of the
per-micro-batch total token count over the serving run."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Scheme, csv_row, simulate
from repro.core import PrefillPolicy
from repro.runtime.simulator import RuntimeModel


def run(verbose: bool = True):
    rows = []
    series = {}
    for scheme in (Scheme("gLLM", PrefillPolicy.GLLM, RuntimeModel.gllm()),
                   Scheme("sarathi", PrefillPolicy.SARATHI,
                          RuntimeModel.gllm())):
        # reach inside the scheduler for the per-tick counts
        from repro.configs import get_config
        from repro.core import PagedKVManager, PipelineScheduler, ThrottleConfig
        from repro.data.workload import SHAREGPT, sample_requests
        from repro.runtime.simulator import PipelineSimulator, cost_model_for

        th = ThrottleConfig(pipeline_depth=4, policy=scheme.policy)
        kv = PagedKVManager(num_pages=8192, page_size=16)
        sched = PipelineScheduler(th, kv, max_model_len=8192 * 16)
        sim = PipelineSimulator(sched, 4, cost_model_for(get_config("qwen2.5-14b"), pp=4),
                                scheme.runtime)
        sim.add_workload(sample_requests(SHAREGPT, 300, 24.0, seed=0))
        sim.run()
        tot = (np.asarray(sched.stats.scheduled_prefill_tokens)
               + np.asarray(sched.stats.scheduled_decode_tokens))
        busy = tot[tot > 0]
        cv = float(np.std(busy) / max(np.mean(busy), 1e-9))
        series[scheme.name] = busy
        rows.append(csv_row(f"fig01_token_cv_{scheme.name}", cv,
                            f"mean={np.mean(busy):.0f} std={np.std(busy):.0f}"))
    ratio = (np.std(series["sarathi"]) / max(np.mean(series["sarathi"]), 1e-9)) / \
        max(np.std(series["gLLM"]) / max(np.mean(series["gLLM"]), 1e-9), 1e-9)
    rows.append(csv_row("fig01_volatility_ratio_sarathi_over_gllm", ratio,
                        "paper: sarathi substantially more volatile"))
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
