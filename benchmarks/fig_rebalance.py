"""Control-plane rebalance: admission-only vs steal vs steal+migrate
(DESIGN.md §9).

The straggler heterogeneous cluster of fig_router_balance, *discovery-only*
(no capacity hints): one replica has a pipeline stage `slow_factor`x slower,
and the router learns it purely from scheduler backlog.  Admission-time
polling reacts a queue-buildup too late — by the time the straggler's score
rises, requests already placed there wait out its backlog.  The periodic
control plane fixes what placement cannot: each interval it re-polls every
replica and moves work *after* the fact — first waiting requests (steal),
then, when imbalance persists under KV pressure, running decodes with their
KV pages (live migration, no recompute).

Three policies per rate, p95/mean TTFT + throughput each:

  admission   balanced placement only (the PR-1 router)
  steal       + periodic rebalance, waiting-queue steals only
  steal+mig   + live migration of running decodes

`--check` exits non-zero unless steal+migrate beats admission-only on p95
TTFT in the straggler scenario — the CI smoke gate (`make rebalance-check`).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.core import PagedKVManager, PipelineScheduler, PrefillPolicy, ThrottleConfig
from repro.data.workload import get_workload, sample_requests
from repro.runtime.router import RebalancePolicy, ReplicaRouter, SimCluster
from repro.runtime.simulator import PipelineSimulator, cost_model_for

POLICIES = ("admission", "steal", "steal+mig")


def _rebalance_for(policy: str):
    if policy == "admission":
        return None
    return RebalancePolicy(migrate=(policy == "steal+mig"))


def _make_sched(pp: int, pages: int) -> PipelineScheduler:
    th = ThrottleConfig(pipeline_depth=pp, policy=PrefillPolicy.GLLM)
    kv = PagedKVManager(num_pages=pages, page_size=16)
    return PipelineScheduler(th, kv, max_model_len=pages * 16)


def run_cluster(policy: str, rate: float, *, arch: str = "qwen2.5-14b",
                workload: str = "sharegpt", num_requests: int = 150,
                pp: int = 4, pages: int = 8192, slow_factor: float = 4.0,
                seed: int = 0, trace_dir: str = None) -> SimCluster:
    """Discovery-only straggler pair under one control-plane policy."""
    cfg = get_config(arch)
    cost = cost_model_for(cfg, pp=pp)
    sims = [
        PipelineSimulator(_make_sched(pp, pages), pp, cost),
        PipelineSimulator(_make_sched(pp, pages), pp, cost,
                          straggler_stage=pp // 2,
                          straggler_factor=slow_factor),
    ]
    router = ReplicaRouter(sims, policy="balanced",
                           rebalance=_rebalance_for(policy))
    cluster = SimCluster(sims, router, trace_dir=trace_dir)
    arrivals = sample_requests(get_workload(workload), num_requests, rate,
                               seed=seed)
    cluster.run(arrivals)
    return cluster


def run(verbose: bool = True, rates=(45.0, 60.0), num_requests: int = 150,
        **kw):
    rows = []
    for rate in rates:
        p95 = {}
        for policy in POLICIES:
            c = run_cluster(policy, rate, num_requests=num_requests, **kw)
            rs = c.router.rebalance_stats
            p95[policy] = c.ttft_quantile(0.95)
            tag = policy.replace("+", "_")
            rows.append(csv_row(
                f"fig_rebalance_{tag}_rate{rate:g}_ttft_p95_s",
                c.ttft_quantile(0.95),
                f"stolen={rs.stolen} migrated={rs.migrated}"))
            rows.append(csv_row(
                f"fig_rebalance_{tag}_rate{rate:g}_ttft_mean_s",
                c.mean_ttft()))
            rows.append(csv_row(
                f"fig_rebalance_{tag}_rate{rate:g}_thpt_tok_s",
                c.throughput()))
        rows.append(csv_row(
            f"fig_rebalance_p95_admission_over_steal_mig_rate{rate:g}",
            p95["admission"] / max(p95["steal+mig"], 1e-9),
            "control plane moves work after placement, not just at it"))
    if verbose:
        for r in rows:
            print(r)
    return rows


def check() -> bool:
    """CI smoke gate, two discovery-only straggler scenarios:

    1. roomy KV pool — the steal path carries the win: steal+migrate must
       beat admission-only p95 TTFT with a wide margin;
    2. tight KV pool — the straggler sits in its KV pressure band, so live
       migration actually fires: it must move KV and not lose to
       admission-only.
    """
    ok = True
    for label, kw, need_migration in (
            ("roomy-pool", dict(rate=45.0), False),
            ("tight-pool", dict(rate=90.0, pages=1536), True)):
        adm = run_cluster("admission", **kw)
        smg = run_cluster("steal+mig", **kw)
        a, s = adm.ttft_quantile(0.95), smg.ttft_quantile(0.95)
        rs = smg.router.rebalance_stats
        good = s < a and (rs.stolen + rs.migrated) > 0
        if need_migration:
            good = good and rs.migrated > 0
        ok = ok and good
        print(f"# rebalance-check[{label}]: p95 TTFT admission={a:.3f}s "
              f"steal+migrate={s:.3f}s (stolen={rs.stolen} "
              f"migrated={rs.migrated}, {rs.migrated_tokens} KV tokens "
              f"moved) -> {'OK' if good else 'FAIL'}")
    return ok


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="CI gate: assert steal+migrate beats admission-only "
                    "p95 TTFT on the straggler scenario")
    args = ap.parse_args()
    if args.check:
        raise SystemExit(0 if check() else 1)
    run()
