"""Deliverable (g): render the roofline tables from dry-run artifacts
(produced by `python -m repro.launch.dryrun`; see results/*.json)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_row
from repro.roofline.analysis import RooflineCell, render_table

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

_KEYS = ("arch", "shape", "mesh", "chips", "hlo_flops", "hlo_bytes",
         "collective_bytes", "collective_breakdown", "model_flops_per_chip",
         "per_device_memory_bytes", "notes")


def load_cells(pattern: str = "roofline_baseline.json"):
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        try:
            data = json.load(open(path))
        except Exception:
            continue
        for d in data:
            cells.append(RooflineCell(**{k: d[k] for k in _KEYS}))
    return cells


def run(verbose: bool = True):
    base = sorted(load_cells(), key=lambda c: (c.arch, c.shape))
    rows = []
    if not base:
        rows.append(csv_row("roofline_cells", 0,
                            "run `python -m repro.launch.dryrun --all "
                            "--single-pod-only --out "
                            "results/roofline_baseline.json` first"))
    else:
        if verbose:
            print("# paper-faithful baseline (single-pod, final cost parser)")
            print(render_table(base))
        for c in base:
            rows.append(csv_row(
                f"roofline_{c.arch}_{c.shape}_{c.mesh}_fraction",
                c.roofline_fraction,
                f"bound={c.bottleneck} useful={c.useful_ratio:.2f}"))
        opts = []
        for path in sorted(glob.glob(os.path.join(RESULTS, "opt*.json"))):
            name = os.path.basename(path)[:-5]
            for c in load_cells(os.path.basename(path)):
                opts.append((name, c))
        if opts and verbose:
            print("# optimized variants (EXPERIMENTS.md §Perf)")
        for name, c in opts:
            rows.append(csv_row(
                f"roofline_{name}_fraction", c.roofline_fraction,
                f"{c.arch} x {c.shape}: t_mem={c.t_memory*1e3:.1f}ms "
                f"t_coll={c.t_collective*1e3:.1f}ms"))
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
