"""Paper Fig. 14: SLO attainment vs request rate (cross-node Llama-100B
deployment in the paper; we use its proxy config)."""

from __future__ import annotations

from benchmarks.common import Scheme, csv_row, simulate


def run(verbose: bool = True, *, arch: str = "llama3.1-100b",
        rates=(1.0, 2.0, 4.0, 8.0), ttft_slo: float = 5.0,
        tpot_slo: float = 0.2):
    rows = []
    for scheme in Scheme.all_main()[:2]:          # gLLM vs vLLM-like (paper)
        for rate in rates:
            m = simulate(scheme, arch=arch, rate=rate, num_requests=80,
                         pp=8, pages=32768)
            att = m.slo_attainment(ttft_slo, tpot_slo)
            rows.append(csv_row(f"fig14_{scheme.name}_r{rate:g}_slo", att,
                                f"ttft<{ttft_slo}s tpot<{tpot_slo}s"))
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
