"""Paper Fig. 15: ablation — gLLM vs w/o WT vs w/o UT vs w/ CK vs vLLM-like.
KV pool sized tight so UT's preemption-avoidance matters (paper: removing UT
costs +22% TTFT / +91% TPOT / +38% E2EL)."""

from __future__ import annotations

from benchmarks.common import Scheme, csv_row, simulate


def run(verbose: bool = True, *, arch: str = "qwen2.5-14b",
        rate: float = 30.0):
    rows = []
    base = {}
    for scheme in Scheme.ablations():
        m = simulate(scheme, arch=arch, rate=rate, num_requests=150,
                     pages=1024)                     # tight KV: UT in play
        vals = {"ttft": m.ttft(), "tpot": m.tpot(), "e2el": m.e2el(),
                "thpt": m.throughput()}
        if scheme.name == "gLLM":
            base = vals
        for k in ("ttft", "tpot", "e2el", "thpt"):
            norm = vals[k] / max(base.get(k, vals[k]), 1e-12)
            rows.append(csv_row(f"fig15_{scheme.name}_{k}", vals[k],
                                f"norm_vs_gLLM={norm:.2f}"))
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
