"""Paper Fig. 10/13: TTFT / TPOT / E2EL / throughput vs request rate for
gLLM vs vLLM-like(PP) vs SGLang-like(TP), on ShareGPT and Azure workloads.
Fig. 13's cross-node variant uses the paper's simulated 73.28 Gbps network
for the TP baseline."""

from __future__ import annotations

from benchmarks.common import Scheme, csv_row, simulate


def run(verbose: bool = True, *, arch: str = "qwen2.5-14b",
        cross_node: bool = False, rates=(4.0, 12.0, 30.0, 90.0),
        workloads=("sharegpt", "azure")):
    rows = []
    tag = "fig13" if cross_node else "fig10"
    for wl in workloads:
        nreq = 150 if wl == "sharegpt" else 60
        for scheme in Scheme.all_main():
            for rate in rates:
                m = simulate(scheme, arch=arch, workload=wl, rate=rate,
                             num_requests=nreq, cross_node=cross_node,
                             pages=65536 if wl == "azure" else 8192)
                base = f"{tag}_{wl}_{scheme.name}_r{rate:g}"
                rows.append(csv_row(base + "_ttft_ms", m.ttft() * 1e3))
                rows.append(csv_row(base + "_tpot_ms", m.tpot() * 1e3))
                rows.append(csv_row(base + "_e2el_s", m.e2el()))
                rows.append(csv_row(base + "_thpt_tok_s", m.throughput()))
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
