"""Engine perf trajectory harness: sync/async dispatch x fixed/bucketed
shapes on the exact reduced engine (DESIGN.md §12).

Runs the same mixed prefill/decode workload through all four dispatch/shape
variants of `PipelineEngine`, asserts their greedy outputs are bit-identical
(scheduling and padding must never change results — the Table-1 claim), and
writes ``BENCH_engine.json`` at the repo root:

    tokens_per_s        end-to-end decode throughput over the serve loop
    host_s_per_tick     host-side work per tick (prepare/meta/fresh/dispatch)
    readback_s_per_tick host time *blocked* on device token readback
    host_wait_per_tick  the sum — everything the host cannot overlap
    padded_ratio        padded tokens / (scheduled + padded) per class
    scanned_pages       KV pages the attention scan walked (bucket width)
    live_pages          KV pages actually holding context
    attn_padded_ratio   1 - live/scanned — dead-page scan waste (schema /2)

The checked-in JSON is the perf trajectory record: regenerate with
``python benchmarks/bench_engine.py`` after engine changes and commit the
diff.  ``--smoke`` runs a seconds-scale version of the same loop (CI's
``make bench-smoke``) and validates the document schema without touching
the checked-in file; ``--validate PATH`` only re-validates an existing
document.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, List

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

import jax  # noqa: E402  (before repro so the compat shim can patch it)

from repro.jax_compat import ensure_jax_compat  # noqa: E402

ensure_jax_compat()

BENCH_SCHEMA = "gllm-bench-engine/2"

VARIANTS = {
    "sync_fixed": dict(async_dispatch=False, bucketed=False),
    "sync_bucketed": dict(async_dispatch=False, bucketed=True),
    "async_fixed": dict(async_dispatch=True, bucketed=False),
    "async_bucketed": dict(async_dispatch=True, bucketed=True),
}
BASELINE = "sync_fixed"
CANDIDATE = "async_bucketed"


def build_engine(params_cache: dict, *, d_model: int, variant_kw: dict):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config, make_reduced
    from repro.core import ThrottleConfig
    from repro.models import transformer as tfm
    from repro.models.serve import ServeDims
    from repro.runtime.engine import PipelineEngine

    cfg = make_reduced(get_config("qwen1.5-0.5b"), d_model=d_model).with_plan(
        pp=1, tp=1, ep_over_data=False)
    cfg = dataclasses.replace(cfg, dtype="float32",
                              moe_capacity_factor=float(
                                  max(cfg.num_experts, 1)))
    mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    dims = ServeDims(Sp=1, C=16, Sd=8, pages=256, page=8, Bp=32, Bd=32,
                     slots=16, Te=0)
    with jax.set_mesh(mesh):
        if "params" not in params_cache:
            params = tfm.init_params(cfg, jax.random.key(0),
                                     dtype=jnp.float32)
            params_cache["params"] = jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                params, tfm.param_pspecs(cfg),
                is_leaf=lambda x: isinstance(x, P))
        th = ThrottleConfig(pipeline_depth=1, max_prefill_tokens=16,
                            min_prefill_tokens=4, num_iters_T=2)
        eng = PipelineEngine(cfg, dims, params_cache["params"], mesh, th,
                             **variant_kw)
    return cfg, eng


def workload(cfg, *, smoke: bool) -> List[dict]:
    """Deterministic mixed workload: three waves of requests with varied
    prompt lengths (single-chunk, multi-chunk) and decode lengths, so the
    ring sees bubbles, partial batches, and every bucket class."""
    import numpy as np
    rng = np.random.default_rng(2024)
    if smoke:
        lens = [(7, 3), (23, 3), (12, 2)]
        waves = [lens]
    else:
        waves = [
            [(7, 16), (23, 12), (12, 20), (40, 8)],
            [(5, 24), (33, 10), (18, 16), (9, 12)],
            [(27, 8), (14, 20), (6, 16), (21, 12)],
        ]
    out = []
    for wave in waves:
        out.append([
            dict(prompt=[int(t) for t in
                         rng.integers(0, cfg.vocab_size, int(plen))],
                 max_new=mnew)
            for plen, mnew in wave
        ])
    return out


def run_variant(name: str, params_cache: dict, waves, *,
                d_model: int) -> Dict[str, Any]:
    from repro.core import SamplingParams

    cfg, eng = build_engine(params_cache, d_model=d_model,
                            variant_kw=VARIANTS[name])
    # identical starting line for all four variants: ladder (or the single
    # full program) compiled before the clock starts
    if not eng.backend.bucketed:
        eng.backend.warm_start()
    compiles_warm = eng.backend.compile_count()

    reqs = []
    t0 = time.perf_counter()
    for wave in waves:
        for w in wave:
            reqs.append(eng.add_request(
                w["prompt"], SamplingParams(max_new_tokens=w["max_new"])))
        for _ in range(5):          # let the wave interleave with service
            eng.step()
    eng.drain(max_ticks=5000)
    wall = time.perf_counter() - t0

    assert all(r.is_finished for r in reqs), \
        f"{name}: unfinished requests {[r.state for r in reqs]}"
    st = eng.backend.stats
    compiles_final = eng.backend.compile_count()
    sched = st.scheduled_prefill + st.scheduled_decode
    padded = st.padded_prefill + st.padded_decode
    return {
        "outputs": [r.output_token_ids for r in reqs],
        "report": {
            "ticks": st.ticks,
            "tokens_out": st.tokens_out,
            "wall_s": round(wall, 4),
            "tokens_per_s": round(st.tokens_out / wall, 2) if wall else None,
            "host_s_per_tick": round(st.host_s / max(st.ticks, 1), 6),
            "readback_s_per_tick": round(st.device_s / max(st.ticks, 1), 6),
            "host_wait_per_tick": round(
                (st.host_s + st.device_s) / max(st.ticks, 1), 6),
            "padded_prefill": st.padded_prefill,
            "padded_decode": st.padded_decode,
            "scheduled_prefill": st.scheduled_prefill,
            "scheduled_decode": st.scheduled_decode,
            "padded_ratio": round(padded / max(sched + padded, 1), 4),
            "scanned_pages": st.scanned_pages,
            "live_pages": st.live_pages,
            "attn_padded_ratio": round(
                1.0 - st.live_pages / max(st.scanned_pages, 1), 4),
            "compiles_after_warm": compiles_warm,
            "recompiles_during_serve": compiles_final - compiles_warm,
        },
    }


def validate(doc: Dict[str, Any]) -> None:
    """Schema check for a bench document (no external deps): raises
    ValueError with the offending path on any violation."""
    def need(cond, path, msg):
        if not cond:
            raise ValueError(f"BENCH_engine.json invalid at {path}: {msg}")

    need(doc.get("schema") == BENCH_SCHEMA, "schema",
         f"expected {BENCH_SCHEMA!r}, got {doc.get('schema')!r}")
    need(isinstance(doc.get("config"), dict), "config", "missing dict")
    for k in ("arch", "d_model", "smoke"):
        need(k in doc["config"], f"config.{k}", "missing")
    need(isinstance(doc.get("variants"), dict), "variants", "missing dict")
    need(set(doc["variants"]) == set(VARIANTS), "variants",
         f"expected {sorted(VARIANTS)}, got {sorted(doc['variants'])}")
    numeric = ("ticks", "tokens_out", "wall_s", "tokens_per_s",
               "host_s_per_tick", "readback_s_per_tick",
               "host_wait_per_tick", "padded_prefill", "padded_decode",
               "scheduled_prefill", "scheduled_decode", "padded_ratio",
               "scanned_pages", "live_pages", "attn_padded_ratio",
               "compiles_after_warm", "recompiles_during_serve")
    for vn, rep in doc["variants"].items():
        for k in numeric:
            need(isinstance(rep.get(k), (int, float)),
                 f"variants.{vn}.{k}", f"missing or non-numeric: "
                 f"{rep.get(k)!r}")
        need(0.0 <= rep["padded_ratio"] <= 1.0,
             f"variants.{vn}.padded_ratio", "out of [0, 1]")
        need(0.0 <= rep["attn_padded_ratio"] <= 1.0,
             f"variants.{vn}.attn_padded_ratio", "out of [0, 1]")
        need(0 <= rep["live_pages"] <= rep["scanned_pages"],
             f"variants.{vn}.live_pages",
             "must satisfy 0 <= live_pages <= scanned_pages")
    cmp_ = doc.get("comparison")
    need(isinstance(cmp_, dict), "comparison", "missing dict")
    for k in ("baseline", "candidate", "padded_ratio_reduced",
              "attn_padded_ratio_reduced", "host_wait_reduced",
              "tick_counts_sane", "outputs_bit_identical"):
        need(k in cmp_, f"comparison.{k}", "missing")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run; writes to a temp file unless "
                         "--out is given")
    ap.add_argument("--out", type=Path, default=None,
                    help=f"output path (default: {REPO_ROOT}/"
                         "BENCH_engine.json, or a temp file with --smoke)")
    ap.add_argument("--d-model", type=int, default=None,
                    help="reduced model width (default 64 smoke / 256 full)")
    ap.add_argument("--validate", type=Path, default=None, metavar="PATH",
                    help="only validate an existing bench document and exit")
    args = ap.parse_args(argv)

    if args.validate is not None:
        validate(json.loads(args.validate.read_text()))
        print(f"{args.validate}: valid {BENCH_SCHEMA}")
        return 0

    d_model = args.d_model or (64 if args.smoke else 256)
    params_cache: dict = {}
    from repro.configs import get_config, make_reduced
    cfg = make_reduced(get_config("qwen1.5-0.5b"), d_model=d_model)
    waves = workload(cfg, smoke=args.smoke)

    results = {}
    for name in VARIANTS:
        print(f"[bench_engine] running {name} ...", flush=True)
        results[name] = run_variant(name, params_cache, waves,
                                    d_model=d_model)

    identical = all(results[n]["outputs"] == results[BASELINE]["outputs"]
                    for n in VARIANTS)
    base = results[BASELINE]["report"]
    cand = results[CANDIDATE]["report"]
    # tick-count sanity (async inflation regression, DESIGN.md §12): deferred
    # retirement must not materially inflate device ticks vs the sync variant
    # on the same workload
    ticks_sane = all(
        results[f"async_{s}"]["report"]["ticks"]
        <= results[f"sync_{s}"]["report"]["ticks"] * 1.15 + 2
        for s in ("fixed", "bucketed"))
    doc = {
        "schema": BENCH_SCHEMA,
        "config": {
            "arch": "qwen1.5-0.5b (reduced)",
            "d_model": d_model,
            "smoke": args.smoke,
            "requests": sum(len(w) for w in waves),
            "platform": "cpu",
        },
        "variants": {n: results[n]["report"] for n in VARIANTS},
        "comparison": {
            "baseline": BASELINE,
            "candidate": CANDIDATE,
            "padded_ratio_reduced":
                cand["padded_ratio"] < base["padded_ratio"],
            "attn_padded_ratio_reduced":
                cand["attn_padded_ratio"] < base["attn_padded_ratio"],
            "host_wait_reduced":
                cand["host_wait_per_tick"] < base["host_wait_per_tick"],
            "tick_counts_sane": ticks_sane,
            "outputs_bit_identical": identical,
        },
    }
    validate(doc)

    if args.out is not None:
        out = args.out
    elif args.smoke:
        out = Path(tempfile.mkdtemp(prefix="bench_engine_")) \
            / "BENCH_engine.json"
    else:
        out = REPO_ROOT / "BENCH_engine.json"
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"[bench_engine] wrote {out}")
    for n, r in doc["variants"].items():
        print(f"  {n:15s} tok/s={r['tokens_per_s']:>8} "
              f"host_wait/tick={r['host_wait_per_tick']:.6f} "
              f"padded_ratio={r['padded_ratio']:.4f} "
              f"attn_padded_ratio={r['attn_padded_ratio']:.4f} "
              f"recompiles={r['recompiles_during_serve']}")
    print(f"  comparison: {doc['comparison']}")

    if not identical:
        print("[bench_engine] FAIL: variant outputs diverged", file=sys.stderr)
        return 1
    if not ticks_sane:
        print("[bench_engine] FAIL: async dispatch inflated tick counts "
              "vs sync", file=sys.stderr)
        return 1
    if not args.smoke and not (doc["comparison"]["padded_ratio_reduced"]
                               and doc["comparison"]["attn_padded_ratio_reduced"]
                               and doc["comparison"]["host_wait_reduced"]):
        print(f"[bench_engine] FAIL: {CANDIDATE} does not strictly improve "
              f"on {BASELINE}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
