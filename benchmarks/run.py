"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only figXX] [--fast]

Prints ``name,value,derived`` CSV rows (stdout), suitable for
``tee bench_output.txt``.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter (e.g. fig10, table1)")
    ap.add_argument("--fast", action="store_true",
                    help="smaller sweeps for CI")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record a reference sim trace (ShareGPT, gLLM "
                    "policy) to PATH and exit — the input of "
                    "`python -m repro.runtime.trace fit`")
    ap.add_argument("--trace-replay", default=None, metavar="PATH",
                    help="strict-replay PATH, report its metrics, and exit "
                    "— turns any recorded run into a regression check")
    args = ap.parse_args()

    if args.trace_out is not None:
        # the reference calibration scenario, stated as a ServeSpec: a
        # ShareGPT workload on the default sim geometry, recorded
        from repro.data.workload import SHAREGPT, sample_requests
        from repro.serving import (EngineSpec, ServeSpec, SimSpec, TraceSpec,
                                   build)
        n, rate = (60, 20.0) if args.fast else (200, 30.0)
        server = build(ServeSpec(
            backend="sim",
            engine=EngineSpec(arch="qwen2.5-14b", policy="gllm"),
            sim=SimSpec(pp=4, pages=2048, page_size=16),
            trace=TraceSpec(record=args.trace_out)))
        server.engine.add_workload(sample_requests(SHAREGPT, n, rate, seed=0))
        finished = server.drain()
        server.close()
        stats = server.stats().replicas[0]
        print(f"# recorded {stats.ticks} ticks "
              f"({len(finished)} requests) -> {args.trace_out}")
        return 0
    if args.trace_replay is not None:
        from repro.serving import ServeSpec, TraceSpec, build
        server = build(ServeSpec(backend="trace",
                                 trace=TraceSpec(replay=args.trace_replay)))
        server.replay()
        print(f"# {server.last_report.summary()} — decisions match the "
              f"recording")
        return 0

    from benchmarks import (fig01_volatility, fig10_latency_throughput,
                            fig12_scalability, fig14_slo, fig15_ablation,
                            fig16_sensitivity, fig_rebalance,
                            fig_router_balance, roofline_report,
                            table1_equivalence)

    suites = [
        ("fig01_volatility", fig01_volatility.run, {}),
        ("fig_router_balance", fig_router_balance.run,
         {"rates": (60.0,), "num_requests": 100} if args.fast else {}),
        ("fig_rebalance", fig_rebalance.run,
         {"rates": (45.0,), "num_requests": 100} if args.fast else {}),
        ("fig10_latency_throughput", fig10_latency_throughput.run,
         {"rates": (8.0, 60.0)} if args.fast else {}),
        ("fig13_cross_node", fig10_latency_throughput.run,
         {"cross_node": True, "rates": (8.0, 60.0),
          "workloads": ("sharegpt",)}),
        ("fig12_scalability", fig12_scalability.run, {}),
        ("fig14_slo", fig14_slo.run, {}),
        ("fig15_ablation", fig15_ablation.run, {}),
        ("fig16_sensitivity", fig16_sensitivity.run, {}),
        ("table1_equivalence", table1_equivalence.run, {}),
        ("roofline_report", roofline_report.run, {}),
    ]
    failures = []
    for name, fn, kw in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn(verbose=True, **kw)
        except Exception as e:  # noqa: BLE001 — benchmarks must not abort the run
            failures.append((name, repr(e)))
            print(f"{name},FAILED,{e!r}")
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        print("# FAILURES:", failures)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
