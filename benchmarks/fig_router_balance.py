"""Multi-replica routing: round-robin vs global-balance (DESIGN.md §1.3).

A data-parallel cluster of PP replicas — one of them handicapped — serves
skewed ShareGPT-style arrivals on the `SimBackend`.  Round-robin splits
requests evenly and saturates the weak replica; balance-score routing reads
each replica's global state (#WP, #RD, KV free rate — the same signals
Token Throttling uses inside a replica) and sheds load before queues build.

Heterogeneity is modeled four ways (the ROADMAP's asymmetric cases):

  slow       uniformly scaled cost model (older silicon / thermal throttle)
  straggler  ONE pipeline stage `slow_factor`x slower (bad chip, hot spot):
             the whole ring drains at the straggler's rate (paper Fig. 3's
             bubbles made permanent)
  kv         smaller KV pool on one replica: the UT term throttles admission
             earlier and preemption churn starts sooner
  depth      deeper pipeline on one replica (same silicon, pp doubled):
             per-stage fixed overheads double and eq. 4 spreads decode over
             twice the micro-batches

For `kv` and `depth` the router discovers the imbalance from scheduler
signals alone (capacities stay 1.0: both replicas have the same silicon).
For `slow` and `straggler` the per-case defaults also pass the known
relative speed as a capacity hint — admission-time polling alone reacts a
queue-buildup too late to beat round-robin on tail TTFT at moderate load
(the ROADMAP's periodic-rebalance item is the discovery-only fix).

Metrics per (hetero, rate, policy): throughput, mean/p95/p99 TTFT.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.core import PagedKVManager, PipelineScheduler, PrefillPolicy, ThrottleConfig
from repro.data.workload import get_workload, sample_requests
from repro.runtime.router import (
    BalanceWeights,
    ReplicaCapacity,
    ReplicaRouter,
    SimCluster,
)
from repro.runtime.simulator import PipelineSimulator, cost_model_for

HETERO_CASES = ("slow", "straggler", "kv", "depth")

# Per-case severity + capacity hints (see module docstring), stated as the
# hardware facts the operator actually knows — `ReplicaCapacity` derives the
# score divisor.  A straggler stage gates the whole ring: a packed pipeline
# drains one micro-batch per straggler beat, so relative throughput is
# pp / (pp - 1 + slow_factor) (ReplicaCapacity.straggler).
CASE_DEFAULTS = {
    "slow": dict(slow_factor=2.5,
                 capacities=[ReplicaCapacity(),
                             ReplicaCapacity.scaled(2.5)]),
    "straggler": dict(slow_factor=4.0,
                      capacities=[ReplicaCapacity(pipeline_depth=4),
                                  ReplicaCapacity.straggler(4, 4.0)]),
    "kv": dict(slow_factor=2.5, capacities=None),
    "depth": dict(slow_factor=2.5, capacities=None),
}


def _make_sched(pp: int, pages: int) -> PipelineScheduler:
    th = ThrottleConfig(pipeline_depth=pp, policy=PrefillPolicy.GLLM)
    kv = PagedKVManager(num_pages=pages, page_size=16)
    return PipelineScheduler(th, kv, max_model_len=pages * 16)


def make_hetero_pair(hetero: str, *, cfg, pp: int = 4, pages: int = 8192,
                     slow_factor: float = 2.5):
    """(fast replica, handicapped replica) for one heterogeneity model."""
    cost = cost_model_for(cfg, pp=pp)
    fast = PipelineSimulator(_make_sched(pp, pages), pp, cost)
    if hetero == "slow":
        weak = PipelineSimulator(_make_sched(pp, pages), pp,
                                 cost.scaled(slow_factor))
    elif hetero == "straggler":
        weak = PipelineSimulator(_make_sched(pp, pages), pp, cost,
                                 straggler_stage=pp // 2,
                                 straggler_factor=slow_factor)
    elif hetero == "kv":
        # pool must still admit the largest sampled request (pressure, not
        # rejection), yet stay strictly smaller than the fast replica's —
        # the floor must never erase or invert the handicap
        small = max(pages // 8, 1024)
        if small >= pages:
            raise ValueError(
                f"kv heterogeneity needs pages > {small} so the weak "
                f"replica's pool stays strictly smaller (got pages={pages})")
        weak = PipelineSimulator(_make_sched(pp, small), pp, cost)
    elif hetero == "depth":
        deep = 2 * pp
        weak = PipelineSimulator(_make_sched(deep, pages), deep,
                                 cost_model_for(cfg, pp=deep))
    else:
        raise ValueError(f"unknown heterogeneity case {hetero!r}")
    return [fast, weak]


def run_cluster(policy: str, rate: float, *, arch: str = "qwen2.5-14b",
                workload: str = "sharegpt", num_requests: int = 200,
                pp: int = 4, pages: int = 8192, slow_factor: float = None,
                hetero: str = "slow", capacities: object = "auto",
                seed: int = 0, trace_dir: str = None) -> SimCluster:
    defaults = CASE_DEFAULTS[hetero]
    if slow_factor is None:
        slow_factor = defaults["slow_factor"]
    if capacities == "auto":
        capacities = defaults["capacities"]
    cfg = get_config(arch)
    sims = make_hetero_pair(hetero, cfg=cfg, pp=pp, pages=pages,
                            slow_factor=slow_factor)
    router = ReplicaRouter(sims, policy=policy, weights=BalanceWeights(),
                           capacities=capacities)
    cluster = SimCluster(sims, router, trace_dir=trace_dir)
    arrivals = sample_requests(get_workload(workload), num_requests, rate,
                               seed=seed)
    cluster.run(arrivals)
    return cluster


def run(verbose: bool = True, rates=(30.0, 60.0, 90.0),
        hetero_cases=HETERO_CASES, **kw):
    rows = []
    for hetero in hetero_cases:
        tag = "" if hetero == "slow" else f"{hetero}_"   # legacy row names
        for rate in rates:
            tail95 = {}
            for policy in ("rr", "balanced"):
                c = run_cluster(policy, rate, hetero=hetero, **kw)
                tail95[policy] = c.ttft_quantile(0.95)
                rows.append(csv_row(
                    f"fig_router_{tag}{policy}_rate{rate:g}_thpt_tok_s",
                    c.throughput(),
                    f"routed={'/'.join(map(str, c.router.routed_counts))}"))
                rows.append(csv_row(
                    f"fig_router_{tag}{policy}_rate{rate:g}_ttft_mean_s",
                    c.mean_ttft()))
                rows.append(csv_row(
                    f"fig_router_{tag}{policy}_rate{rate:g}_ttft_p95_s",
                    c.ttft_quantile(0.95)))
                rows.append(csv_row(
                    f"fig_router_{tag}{policy}_rate{rate:g}_ttft_p99_s",
                    c.ttft_quantile(0.99)))
            rows.append(csv_row(
                f"fig_router_{tag}p95_ttft_rr_over_balanced_rate{rate:g}",
                tail95["rr"] / max(tail95["balanced"], 1e-9),
                "global balance sheds load off the weak replica"))
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
