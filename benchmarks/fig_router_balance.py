"""Multi-replica routing: round-robin vs global-balance (DESIGN.md §1.3).

A data-parallel cluster of PP replicas — one of them slower (older silicon /
thermal throttling, modeled by a uniformly scaled cost model) — serves
skewed ShareGPT-style arrivals on the `SimBackend`.  Round-robin splits
requests evenly and saturates the slow replica; balance-score routing reads
each replica's global state (#WP, #RD, KV free rate — the same signals
Token Throttling uses inside a replica) and sheds load before queues build.

Metrics per (rate, policy): throughput, mean/p95/p99 TTFT.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.core import PagedKVManager, PipelineScheduler, PrefillPolicy, ThrottleConfig
from repro.data.workload import get_workload, sample_requests
from repro.runtime.router import BalanceWeights, ReplicaRouter, SimCluster
from repro.runtime.simulator import PipelineSimulator, cost_model_for


def _make_sched(pp: int, pages: int) -> PipelineScheduler:
    th = ThrottleConfig(pipeline_depth=pp, policy=PrefillPolicy.GLLM)
    kv = PagedKVManager(num_pages=pages, page_size=16)
    return PipelineScheduler(th, kv, max_model_len=pages * 16)


def run_cluster(policy: str, rate: float, *, arch: str = "qwen2.5-14b",
                workload: str = "sharegpt", num_requests: int = 200,
                pp: int = 4, pages: int = 8192, slow_factor: float = 2.5,
                seed: int = 0) -> SimCluster:
    cfg = get_config(arch)
    cost = cost_model_for(cfg, pp=pp)
    sims = [
        PipelineSimulator(_make_sched(pp, pages), pp, cost),
        PipelineSimulator(_make_sched(pp, pages), pp,
                          cost.scaled(slow_factor)),
    ]
    router = ReplicaRouter(sims, policy=policy,
                           weights=BalanceWeights(),
                           capacities=[1.0, 1.0 / slow_factor])
    cluster = SimCluster(sims, router)
    arrivals = sample_requests(get_workload(workload), num_requests, rate,
                               seed=seed)
    cluster.run(arrivals)
    return cluster


def run(verbose: bool = True, rates=(30.0, 60.0, 90.0), **kw):
    rows = []
    for rate in rates:
        tail95 = {}
        for policy in ("rr", "balanced"):
            c = run_cluster(policy, rate, **kw)
            tail95[policy] = c.ttft_quantile(0.95)
            rows.append(csv_row(
                f"fig_router_{policy}_rate{rate:g}_thpt_tok_s",
                c.throughput(),
                f"routed={'/'.join(map(str, c.router.routed_counts))}"))
            rows.append(csv_row(
                f"fig_router_{policy}_rate{rate:g}_ttft_mean_s",
                c.mean_ttft()))
            rows.append(csv_row(
                f"fig_router_{policy}_rate{rate:g}_ttft_p95_s",
                c.ttft_quantile(0.95)))
            rows.append(csv_row(
                f"fig_router_{policy}_rate{rate:g}_ttft_p99_s",
                c.ttft_quantile(0.99)))
        rows.append(csv_row(
            f"fig_router_p95_ttft_rr_over_balanced_rate{rate:g}",
            tail95["rr"] / max(tail95["balanced"], 1e-9),
            "global balance sheds load off the slow replica"))
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
