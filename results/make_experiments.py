"""Assemble EXPERIMENTS.md §Dry-run and §Roofline from results/*.json.
(§Paper-validation and §Perf narrative blocks are maintained inline below.)"""

import glob
import json
import os
import sys

sys.path.insert(0, "src")
from repro.roofline.analysis import RooflineCell, render_table  # noqa: E402

R = "results"


def load(pattern):
    cells = []
    for p in sorted(glob.glob(os.path.join(R, pattern))):
        try:
            data = json.load(open(p))
        except Exception:
            continue
        for d in data:
            cells.append(RooflineCell(**{k: d[k] for k in (
                "arch", "shape", "mesh", "chips", "hlo_flops", "hlo_bytes",
                "collective_bytes", "collective_breakdown",
                "model_flops_per_chip", "per_device_memory_bytes", "notes")}))
    return cells


def dedup(cells):
    seen = {}
    for c in cells:
        seen[(c.arch, c.shape, c.mesh)] = c
    return list(seen.values())


# §Dry-run evidence (both meshes, first sweep) + §Roofline (final parser)
baseline = dedup(load("dryrun_baseline.json") + load("fix_*.json"))
roofline = dedup(load("roofline_baseline.json"))
base_single = sorted([c for c in baseline if c.mesh == "16x16"],
                     key=lambda c: (c.arch, c.shape))
base_multi = sorted([c for c in baseline if c.mesh != "16x16"],
                    key=lambda c: (c.arch, c.shape))

opts = {os.path.basename(p)[:-5]: load(os.path.basename(p))
        for p in glob.glob(os.path.join(R, "opt*.json"))}

out = []
out.append("## §Dry-run — multi-pod lower+compile, every (arch x shape) cell\n")
out.append(f"Single-pod 16x16 (256 chips): **{len(base_single)} cells**; "
           f"multi-pod 2x16x16 (512 chips): **{len(base_multi)} cells** — "
           "all lowered AND compiled (sharding coherent, collectives legal).  "
           "Per-device bytes from `compiled.memory_analysis()`; HBM verdict "
           "vs the 16 GB v5e budget.\n")
out.append("| arch | shape | mesh | bytes/dev (GB) | fits 16GB? | "
           "collectives (GB/dev/step) | compile |")
out.append("|---|---|---|---|---|---|---|")
for c in base_single + base_multi:
    gb = c.per_device_memory_bytes / 2**30
    fits = "yes" if gb <= 16 else "**NO**"
    comp = c.notes.split("compile=")[1].split(" ")[0]
    brk = {k: round(v / 2**30, 2) for k, v in c.collective_breakdown.items()
           if v > 1e6}
    out.append(f"| {c.arch} | {c.shape} | {c.mesh} | {gb:.2f} | {fits} | "
               f"{brk} | {comp} |")

out.append("\nSkipped cells (per assignment): `long_500k` for the eight pure "
           "full-attention archs (sub-quadratic required); it runs for jamba "
           "(hybrid, sequence-sharded KV) and rwkv6 (O(1)-state decode). "
           "Whisper is enc-dec (decoder decodes), so decode shapes run.\n")

out.append("\n## §Roofline — per-chip three-term analysis (16x16 pod, "
           "PAPER-FAITHFUL BASELINE)\n")
out.append("Terms: `compute = HLO_FLOPs/197TF`, `memory = HLO_bytes/819GB/s`, "
           "`collective = coll_bytes/50GB/s-link`, all per chip per step/tick, "
           "from the trip-count-aware HLO cost parser "
           "(`repro.roofline.hlo_cost` — XLA's own cost_analysis counts scan "
           "bodies once; raw values kept in each cell's notes). `useful` = "
           "MODEL_FLOPS/HLO_FLOPs; `roofline` = useful-FLOP time over "
           "dominant-term time.\n")
roof_single = sorted([c for c in roofline if c.mesh == "16x16"],
                     key=lambda c: (c.arch, c.shape)) or base_single
out.append(render_table(roof_single))
out.append("\n(Multi-pod cells compile identically — §Dry-run above — and "
           "their roofline terms match single-pod per chip: the pod axis is "
           "pure replication for serving and data parallelism for training, "
           "adding only the pod-spanning gradient psum.)\n")

out.append("\n### Per-cell bottleneck notes (baseline)\n")
for c in (roof_single if 'roof_single' in dir() else base_single):
    dom = c.bottleneck
    move = {
        "memory": "reduce HBM traffic (avoid KV-pool double-buffering, "
                  "chunk recurrent scans, larger fused blocks)",
        "compute": "raise MFU (bigger micro-batches, less remat recompute)",
        "collective": "compress/overlap gradient sync, shrink EP a2a capacity",
    }[dom]
    out.append(f"- **{c.arch} x {c.shape}**: bound={dom}, useful-ratio "
               f"{c.useful_ratio:.2f}, roofline {c.roofline_fraction:.2%} — {move}.")

if opts:
    out.append("\n## §Perf optimized cells (artifacts)\n")
    out.append("| variant | arch | shape | t_comp(ms) | t_mem(ms) | "
               "t_coll(ms) | bytes/dev(GB) | roofline |")
    out.append("|---|---|---|---|---|---|---|---|")
    for name, cells in sorted(opts.items()):
        for c in cells:
            out.append(
                f"| {name} | {c.arch} | {c.shape} | {c.t_compute*1e3:.2f} | "
                f"{c.t_memory*1e3:.2f} | {c.t_collective*1e3:.2f} | "
                f"{c.per_device_memory_bytes/2**30:.2f} | "
                f"{c.roofline_fraction:.2%} |")

open(os.path.join(R, "experiments_generated.md"), "w").write("\n".join(out))
print(f"wrote results/experiments_generated.md "
      f"({len(base_single)}+{len(base_multi)} baseline cells, "
      f"{sum(len(v) for v in opts.values())} optimized)")
