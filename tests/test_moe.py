"""MoE dispatch: sort-based capacity path vs dense oracle, drops, EP shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

from repro.models.moe import moe_apply, moe_ref, route


def make_params(key, d, E, ff, shared=False):
    ks = jax.random.split(key, 7)
    p = {
        "router": jax.random.normal(ks[0], (d, E)) * 0.5,
        "w_gate": jax.random.normal(ks[1], (E, d, ff)) * 0.1,
        "w_up": jax.random.normal(ks[2], (E, d, ff)) * 0.1,
        "w_down": jax.random.normal(ks[3], (E, ff, d)) * 0.1,
    }
    if shared:
        p["s_gate"] = jax.random.normal(ks[4], (d, ff)) * 0.1
        p["s_up"] = jax.random.normal(ks[5], (d, ff)) * 0.1
        p["s_down"] = jax.random.normal(ks[6], (ff, d)) * 0.1
    return p


@pytest.mark.parametrize("E,topk,shared", [(4, 2, False), (8, 2, True),
                                            (8, 4, False)])
def test_matches_dense_oracle_when_no_drops(E, topk, shared):
    d, ff, T = 16, 32, 64
    p = make_params(jax.random.key(0), d, E, ff, shared)
    x = jax.random.normal(jax.random.key(1), (T, d))
    # capacity_factor large enough that nothing drops
    out, aux = moe_apply(x, p, top_k=topk, capacity_factor=float(E))
    ref = moe_ref(x, p, top_k=topk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)
    assert np.isfinite(float(aux))


def test_drops_under_tight_capacity():
    d, ff, T, E = 8, 16, 128, 4
    p = make_params(jax.random.key(0), d, E, ff)
    # force imbalance: all tokens identical -> one expert takes everything
    x = jnp.ones((T, d))
    out, _ = moe_apply(x, p, top_k=1, capacity_factor=0.05)
    ref = moe_ref(x, p, top_k=1)
    # most rows dropped => output far from oracle but finite (graceful)
    assert bool(jnp.all(jnp.isfinite(out)))
    dropped = jnp.mean(jnp.sum(jnp.abs(out), -1) < 1e-6)
    assert float(dropped) > 0.5


def test_route_normalizes_weights():
    d, E, T = 8, 6, 32
    rw = jax.random.normal(jax.random.key(0), (d, E))
    x = jax.random.normal(jax.random.key(1), (T, d))
    w, idx, aux = route(x, rw, 3)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), np.ones(T),
                               atol=1e-5)
    assert int(idx.max()) < E and int(idx.min()) >= 0
    # perfectly uniform router would give aux ~= 1.0
    assert 0.5 < float(aux) < float(E)


def _oracle_agreement_body(T, E, topk, seed):
    d, ff = 8, 16
    p = make_params(jax.random.key(seed), d, E, ff)
    x = jax.random.normal(jax.random.key(seed + 1), (T, d))
    out, _ = moe_apply(x, p, top_k=topk, capacity_factor=float(E))
    ref = moe_ref(x, p, top_k=topk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-3)


if HAS_HYPOTHESIS:
    @given(T=st.sampled_from([8, 32, 96]), E=st.sampled_from([2, 4, 8]),
           topk=st.integers(1, 2), seed=st.integers(0, 100))
    @settings(max_examples=25, deadline=None)
    def test_property_oracle_agreement(T, E, topk, seed):
        _oracle_agreement_body(T, E, topk, seed)
else:
    @pytest.mark.parametrize("T,E,topk,seed",
                             [(8, 2, 1, 0), (32, 4, 2, 1), (96, 8, 2, 2)])
    def test_property_oracle_agreement(T, E, topk, seed):
        # fallback spot-check without hypothesis (requirements-dev.txt)
        _oracle_agreement_body(T, E, topk, seed)


def test_moe_is_differentiable():
    d, ff, T, E = 8, 16, 32, 4
    p = make_params(jax.random.key(0), d, E, ff)
    x = jax.random.normal(jax.random.key(1), (T, d))

    def loss(p):
        out, aux = moe_apply(x, p, top_k=2, capacity_factor=4.0)
        return jnp.sum(out**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for k, v in g.items():
        assert bool(jnp.all(jnp.isfinite(v))), k
    assert float(jnp.max(jnp.abs(g["w_gate"]))) > 0
