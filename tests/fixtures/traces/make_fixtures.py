"""Mint the checked-in golden traces (run from the repo root):

    PYTHONPATH=src python tests/fixtures/traces/make_fixtures.py

Two regimes, both `SimBackend` runs with fixed seeds so regeneration is
byte-identical (tests/test_trace.py asserts it):

  * prefill_heavy    — long prompts, tiny outputs: Token Throttling's WT term
    dominates, micro-batches are prefill chunks.
  * decode_saturated — short prompts, long outputs on a deliberately tight KV
    pool: the UT term and threshold gate admission, preemption-by-recompute
    fires, decode population saturates eq. 4.

Any change to core/throttle.py or core/scheduler.py that alters batching
makes strict replay of these files diverge — regenerate and review the
fixture diff to accept the new behavior.
"""

from __future__ import annotations

import os

from repro.data.workload import WorkloadSpec, sample_requests
from repro.runtime.simulator import record_sim_trace

HERE = os.path.dirname(os.path.abspath(__file__))

PREFILL_HEAVY = WorkloadSpec("prefill-heavy", mean_input=220.0,
                             mean_output=6.0, sigma=0.7,
                             max_input=512, max_output=12)
DECODE_SATURATED = WorkloadSpec("decode-saturated", mean_input=24.0,
                                mean_output=80.0, sigma=0.5,
                                max_input=64, max_output=120)

FIXTURES = {
    # burst arrivals: #WP spikes so the WT term schedules multi-hundred-token
    # prefill chunks — ticks that are genuinely compute-bound (the regime
    # CostModel.fit_from_trace needs to see to identify mfu)
    "prefill_heavy.trace.jsonl": dict(
        spec=PREFILL_HEAVY, n=28, rate=200.0, pages=512, seed=7),
    "decode_saturated.trace.jsonl": dict(
        spec=DECODE_SATURATED, n=20, rate=60.0, pages=80, seed=7),
}


def generate(path: str, *, spec: WorkloadSpec, n: int, rate: float,
             pages: int, seed: int):
    return record_sim_trace(path, sample_requests(spec, n, rate, seed=seed),
                            pages=pages)


def main() -> None:
    for name, kw in FIXTURES.items():
        path = os.path.join(HERE, name)
        sim = generate(path, **kw)
        st = sim.sched.stats
        print(f"{name}: {st.ticks} ticks, {len(sim.metrics.finished)} "
              f"requests, {st.preemptions} preemptions, "
              f"min KV-free {min(st.kv_free_rate):.3f}, "
              f"{os.path.getsize(path)} bytes")


if __name__ == "__main__":
    main()
