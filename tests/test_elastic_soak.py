"""Chaos + soak layer for cluster-scale elasticity (DESIGN.md §16).

Two complementary stressors over the same conservation property — every
submitted request is finished exactly once, or provably alive somewhere:

  * a hypothesis *stateful* machine interleaving add_request / tick /
    abort / scale_up / drain in random orders, auditing
    `ReplicaRouter.check_invariants` after every operation (self-skips
    when hypothesis is not installed);
  * a deterministic flash-crowd soak at fleet scale: `REPRO_SOAK_REPLICAS`
    (default 16) bounds the CI run, the O(100)-replica variant rides
    behind the `slow` marker.  Both assert zero stuck requests and
    monotone per-ordinal request-id accounting across every drain.
"""

import os

import pytest

try:
    from hypothesis import settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (
        RuleBasedStateMachine,
        initialize,
        invariant,
        rule,
    )
    HAS_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

from repro.configs import get_config
from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    PrefillPolicy,
    SamplingParams,
    ThrottleConfig,
)
from repro.data.workload import flash_crowd_requests
from repro.runtime.autoscale import AutoscalePolicy
from repro.runtime.router import ReplicaRouter, SimCluster
from repro.runtime.simulator import PipelineSimulator, cost_model_for

CFG = get_config("qwen2.5-14b")

SOAK_REPLICAS = int(os.environ.get("REPRO_SOAK_REPLICAS", "16"))


def make_sim(pp=2, pages=256, page_size=8):
    th = ThrottleConfig(pipeline_depth=pp, policy=PrefillPolicy.GLLM)
    kv = PagedKVManager(num_pages=pages, page_size=page_size)
    sched = PipelineScheduler(th, kv, max_model_len=pages * page_size)
    return PipelineSimulator(sched, pp, cost_model_for(CFG, pp=pp))


def elastic_cluster(n, *, max_replicas, interval=0.05, target_queue=2.0,
                    up_cooldown=0.1, down_cooldown=1.0):
    pol = AutoscalePolicy(interval=interval, max_replicas=max_replicas,
                          target_queue=target_queue,
                          up_cooldown=up_cooldown,
                          down_cooldown=down_cooldown)
    sims = [make_sim() for _ in range(n)]
    router = ReplicaRouter(sims, policy="balanced", autoscale=pol,
                           replica_factory=lambda o: make_sim())
    return SimCluster(sims, router)


# ---------------------------------------------------------------------------
# hypothesis chaos machine
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:

    class ElasticChaos(RuleBasedStateMachine):
        """Random interleavings of the whole elastic surface.  After every
        rule the cluster must conserve requests: nothing lost across a
        drain, nothing duplicated by a re-homed delivery, nothing both
        alive and finished."""

        @initialize()
        def setup(self):
            self.cluster = elastic_cluster(2, max_replicas=5)
            self.router = self.cluster.router
            self.submitted = []

        @rule(tokens=st.integers(8, 200), out=st.integers(1, 24))
        def add_request(self, tokens, out):
            req = self.cluster.add_request(
                [1] * tokens, SamplingParams(max_new_tokens=out))
            self.submitted.append(req.request_id)

        @rule(n=st.integers(1, 5))
        def tick(self, n):
            for _ in range(n):
                self.cluster.step()

        @rule(pick=st.integers(0, 10**6))
        def abort(self, pick):
            if self.submitted:
                self.cluster.abort_request(
                    self.submitted[pick % len(self.submitted)])

        @rule()
        def scale_up(self):
            if len(self.router.replicas) < 5:
                self.router.add_replica()

        @rule(pick=st.integers(0, 10**6))
        def drain(self, pick):
            i = pick % len(self.router.replicas)
            try:
                self.router.start_drain(i)
            except ValueError:
                pass    # role cover / last replica / already draining

        @invariant()
        def conserved(self):
            if hasattr(self, "router"):
                self.router.check_invariants(expected_rids=self.submitted)

        def teardown(self):
            if not hasattr(self, "cluster"):
                return
            self.cluster.drain()
            self.router.check_invariants(expected_rids=self.submitted)
            done = [r.request_id for r in self.cluster.finished]
            assert sorted(done) == sorted(set(done)), "request finished twice"
            assert set(self.submitted) <= set(done), "request stuck or lost"

    ElasticChaos.TestCase.settings = settings(
        max_examples=25, stateful_step_count=30, deadline=None)
    TestElasticChaos = ElasticChaos.TestCase

else:    # pragma: no cover - minimal installs

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_elastic_chaos_machine():
        pass


# ---------------------------------------------------------------------------
# seeded chaos (runs everywhere, hypothesis or not)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_seeded_chaos_interleaving(seed):
    """The same operation mix as the hypothesis machine, driven by a seeded
    RNG so minimal installs still exercise the chaos layer."""
    import numpy as np
    rng = np.random.default_rng(seed)
    cluster = elastic_cluster(2, max_replicas=5)
    router = cluster.router
    submitted = []
    for _ in range(120):
        op = rng.integers(0, 10)
        if op < 4:
            req = cluster.add_request(
                [1] * int(rng.integers(8, 200)),
                SamplingParams(max_new_tokens=int(rng.integers(1, 24))))
            submitted.append(req.request_id)
        elif op < 7:
            for _ in range(int(rng.integers(1, 5))):
                cluster.step()
        elif op == 7 and submitted:
            cluster.abort_request(
                submitted[int(rng.integers(0, len(submitted)))])
        elif op == 8 and len(router.replicas) < 5:
            router.add_replica()
        else:
            try:
                router.start_drain(
                    int(rng.integers(0, len(router.replicas))))
            except ValueError:
                pass
        router.check_invariants(expected_rids=submitted)
    cluster.drain()
    router.check_invariants(expected_rids=submitted)
    done = [r.request_id for r in cluster.finished]
    assert sorted(done) == sorted(set(done)), "request finished twice"
    assert set(submitted) <= set(done), "request stuck or lost"


# ---------------------------------------------------------------------------
# deterministic fleet-scale soak
# ---------------------------------------------------------------------------

def _soak(replica_cap: int, num: int, seed: int = 0):
    """One flash-crowd soak: start with 1/8 of the cap, spike hard, let the
    autoscaler ride it up and back down.  Returns (cluster, arrivals)."""
    start = max(1, replica_cap // 8)
    cluster = elastic_cluster(start, max_replicas=replica_cap,
                              target_queue=1.0)
    arrivals = flash_crowd_requests(
        8.0, base_rate=2.0, spike_rate=num / 2.0, spike_start=1.0,
        spike_len=2.0, mean_input=48.0, mean_output=12.0, seed=seed)
    return cluster, arrivals


def _assert_soak_clean(cluster, arrivals):
    router = cluster.router
    fin = cluster.run(arrivals, until=600.0)
    # zero stuck requests: everything submitted came back finished, once
    assert len(fin) == len(arrivals)
    rids = [r.request_id for r in fin]
    assert len(rids) == len(set(rids))
    router.check_invariants(expected_rids=rids)
    st_ = router.autoscale_stats
    assert st_.replicas_added > 0, "soak must actually exercise scale-up"
    # monotone request-id accounting at drain: each ordinal's finished
    # history is still intact after the fleet shrank
    assert st_.retired > 0, "soak must actually exercise retirement"
    per_source = [len(s.metrics.finished)
                  for s in list(cluster.sims) + list(router.retired)]
    assert sum(per_source) + len(router._aborted) == len(arrivals)
    # the fleet came back off its peak once the crowd passed (the run stops
    # when the last request finishes, so full return to baseline is not
    # required — only that scale-down demonstrably engaged)
    peak = max(size for _, kind, size in st_.events)
    assert len(router.replicas) < peak


def test_flash_crowd_soak_reduced():
    """CI-sized soak (REPRO_SOAK_REPLICAS caps the fleet, default 16)."""
    cluster, arrivals = _soak(SOAK_REPLICAS, num=240, seed=1)
    _assert_soak_clean(cluster, arrivals)


@pytest.mark.slow
def test_flash_crowd_soak_o100_replicas():
    """The full O(100)-replica chaos target from DESIGN.md §16."""
    cluster, arrivals = _soak(100, num=2400, seed=2)
    _assert_soak_clean(cluster, arrivals)


def test_soak_is_deterministic():
    """Same seed, same fleet trajectory: the soak is a regression test,
    not a statistical one."""
    outs = []
    for _ in range(2):
        cluster, arrivals = _soak(8, num=60, seed=3)
        cluster.run(arrivals, until=600.0)
        st_ = cluster.router.autoscale_stats
        outs.append((st_.replicas_added, st_.retired, st_.events))
    assert outs[0] == outs[1]
