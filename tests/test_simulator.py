"""Simulator: policy effects (paper directions), faults, stragglers."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import PagedKVManager, PipelineScheduler, PrefillPolicy, ThrottleConfig
from repro.data.workload import AZURE, SHAREGPT, get_workload, sample_requests
from repro.runtime.simulator import (
    PipelineSimulator,
    RuntimeModel,
    cost_model_for,
)

CFG = get_config("qwen2.5-14b")
PP = 4


def run_sim(policy, runtime, *, rate=12.0, n=150, pages=8192, seed=0,
            fail_at=None, straggler=None):
    th = ThrottleConfig(pipeline_depth=PP, policy=policy)
    kv = PagedKVManager(num_pages=pages, page_size=16)
    sched = PipelineScheduler(th, kv, max_model_len=pages * 16)
    st_stage, st_fac = straggler if straggler else (None, 1.0)
    sim = PipelineSimulator(sched, PP, cost_model_for(CFG, pp=PP), runtime,
                            straggler_stage=st_stage, straggler_factor=st_fac)
    sim.add_workload(sample_requests(SHAREGPT, n, rate, seed=seed))
    if fail_at is not None:
        sim.inject_failure(fail_at, downtime=1.0)
    return sim.run()


def test_all_requests_complete():
    m = run_sim(PrefillPolicy.GLLM, RuntimeModel.gllm())
    assert len(m.finished) == 150
    assert m.throughput() > 0
    assert m.ttft() > 0 and m.tpot() > 0


def test_gllm_beats_sarathi_at_saturation():
    """The paper's headline: higher max throughput + lower TPOT/E2EL at
    saturation (rate far above the ~25 req/s capacity of this setup)."""
    g = run_sim(PrefillPolicy.GLLM, RuntimeModel.gllm(), rate=90.0)
    s = run_sim(PrefillPolicy.SARATHI, RuntimeModel.vllm_like(), rate=90.0)
    assert g.throughput() > s.throughput()
    assert g.tpot() < s.tpot()
    assert g.e2el() < s.e2el()
    assert g.bubble_time < s.bubble_time


def test_runtime_alone_helps():
    """gLLM w/ CK (Sarathi policy on the async runtime) still beats the
    vLLM-like runtime — paper Fig. 15's ~10% runtime effect."""
    ck = run_sim(PrefillPolicy.SARATHI, RuntimeModel.gllm(), rate=90.0)
    vl = run_sim(PrefillPolicy.SARATHI, RuntimeModel.vllm_like(), rate=90.0)
    assert ck.throughput() > vl.throughput()


def test_ut_matters_under_kv_pressure():
    """Fig. 15: removing UT degrades E2EL/TPOT when KV is tight — the
    threshold + UT scaling prevent preemption-recompute churn."""
    full = run_sim(PrefillPolicy.GLLM, RuntimeModel.gllm(), rate=30.0,
                   pages=1024)
    nout = run_sim(PrefillPolicy.NO_UT, RuntimeModel.gllm(), rate=30.0,
                   pages=1024)
    assert nout.e2el() > full.e2el() * 1.1     # paper: +38%
    assert nout.tpot() > full.tpot() * 1.1     # paper: +91%


def test_slo_attainment_direction():
    g = run_sim(PrefillPolicy.GLLM, RuntimeModel.gllm(), rate=35.0)
    s = run_sim(PrefillPolicy.SARATHI, RuntimeModel.vllm_like(), rate=35.0)
    assert g.slo_attainment(2.0, 0.05) >= s.slo_attainment(2.0, 0.05)


def test_failure_recovery_completes_all():
    m = run_sim(PrefillPolicy.GLLM, RuntimeModel.gllm(), rate=20.0,
                fail_at=2.0)
    assert len(m.finished) == 150          # nothing lost, only delayed


def test_straggler_slows_but_completes():
    base = run_sim(PrefillPolicy.GLLM, RuntimeModel.gllm(), rate=20.0)
    slow = run_sim(PrefillPolicy.GLLM, RuntimeModel.gllm(), rate=20.0,
                   straggler=(2, 3.0))
    assert len(slow.finished) == 150
    assert slow.e2el() > base.e2el()


def test_workloads_match_paper_ratios():
    rng_reqs = sample_requests(AZURE, 2000, 1.0, seed=0)
    s_reqs = sample_requests(SHAREGPT, 2000, 1.0, seed=0)
    a_in = np.mean([len(p) for _, p, _ in rng_reqs])
    s_in = np.mean([len(p) for _, p, _ in s_reqs])
    a_out = np.mean([o for _, _, o in rng_reqs])
    s_out = np.mean([o for _, _, o in s_reqs])
    assert 4.0 < a_in / s_in < 6.5          # paper: 5.21x
    assert 1.3 < a_out / s_out < 2.1        # paper: 1.66x
    assert get_workload("azure") is AZURE
