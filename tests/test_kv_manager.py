"""Paged KV allocator: unit + stateful property tests.

The property tests need `hypothesis` (see requirements-dev.txt); without it
only those tests are skipped — the deterministic unit tests always run.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

from repro.core.kv_manager import PagedKVManager


class TestBasics:
    def test_alloc_slots_and_tables(self):
        kv = PagedKVManager(num_pages=8, page_size=4)
        slots = kv.allocate("a", 6)
        assert len(slots) == 6
        assert len(kv.block_table("a")) == 2
        assert slots[0][1] == 0 and slots[4][1] == 0 and slots[5][1] == 1
        assert kv.num_free_pages == 6
        kv.free("a")
        assert kv.num_free_pages == 8

    def test_extend_uses_slack_before_new_page(self):
        kv = PagedKVManager(num_pages=4, page_size=4)
        kv.allocate("a", 3)
        assert kv.pages_needed("a", 1) == 0
        assert kv.pages_needed("a", 2) == 1
        kv.allocate("a", 2)
        assert len(kv.block_table("a")) == 2

    def test_oom_raises(self):
        kv = PagedKVManager(num_pages=2, page_size=4)
        kv.allocate("a", 8)
        assert not kv.can_allocate("b", 1)
        with pytest.raises(MemoryError):
            kv.allocate("b", 1)

    def test_free_rate_signal(self):
        kv = PagedKVManager(num_pages=10, page_size=4)
        assert kv.kv_free_rate == 1.0
        kv.allocate("a", 20)
        assert kv.kv_free_rate == 0.5


class TestPrefixCache:
    def test_match_and_reuse(self):
        kv = PagedKVManager(num_pages=16, page_size=4,
                            enable_prefix_caching=True)
        prompt = list(range(10))
        kv.allocate("a", 10)
        kv.freeze_full_pages("a", prompt)
        # same prefix: two full pages (8 tokens) should match
        n, pages = kv.match_prefix(prompt)
        assert n == 8 and len(pages) == 2
        kv.adopt_prefix("b", n, pages)
        kv.allocate("b", 2)
        # shared pages are refcounted: freeing one owner keeps them
        kv.free("a")
        assert kv.num_tokens("b") == 10
        kv.check_invariants()
        kv.free("b")
        kv.check_invariants()

    def test_eviction_under_pressure(self):
        kv = PagedKVManager(num_pages=4, page_size=4,
                            enable_prefix_caching=True)
        kv.allocate("a", 16)
        kv.freeze_full_pages("a", list(range(16)))
        kv.free("a")                      # pages become evictable, not free
        assert kv.num_free_pages == 4
        kv.allocate("b", 16)              # must evict the cached pages
        assert kv.num_free_pages == 0
        n, _ = kv.match_prefix(list(range(16)))
        assert n == 0                     # cache fully evicted
        kv.check_invariants()

    def test_no_match_for_different_tokens(self):
        kv = PagedKVManager(num_pages=8, page_size=4,
                            enable_prefix_caching=True)
        kv.allocate("a", 8)
        kv.freeze_full_pages("a", [1] * 8)
        n, pages = kv.match_prefix([2] * 8)
        assert n == 0 and not pages


if HAS_HYPOTHESIS:
    @st.composite
    def _ops(draw):
        return draw(st.lists(
            st.one_of(
                st.tuples(st.just("alloc"), st.integers(0, 9),
                          st.integers(1, 12)),
                st.tuples(st.just("free"), st.integers(0, 9), st.just(0)),
            ), min_size=1, max_size=60))

    class TestStatefulProperties:
        @given(ops=_ops(), page_size=st.sampled_from([1, 4, 8]))
        @settings(max_examples=150, deadline=None)
        def test_invariants_under_random_ops(self, ops, page_size):
            kv = PagedKVManager(num_pages=24, page_size=page_size)
            live = {}
            for op, rid_i, n in ops:
                rid = f"r{rid_i}"
                if op == "alloc":
                    if kv.can_allocate(rid, n):
                        kv.allocate(rid, n)
                        live[rid] = live.get(rid, 0) + n
                else:
                    kv.free(rid)
                    live.pop(rid, None)
                kv.check_invariants()
                # every live request's table covers its tokens exactly
                for r, tok in live.items():
                    table = kv.block_table(r)
                    assert len(table) == -(-tok // page_size)
                    assert len(set(table)) == len(table)   # no page shared
            # tables of distinct requests are disjoint (no prefix cache here)
            seen = set()
            for r in live:
                t = set(kv.block_table(r))
                assert not (t & seen)
                seen |= t
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_invariants_under_random_ops():
        pass
