"""THE integration test: the pipelined, paged, chunked, throttled serving
engine must produce *exactly* the greedy tokens of a dense full-recompute
reference (scheduling must never change outputs — the paper's Table 1 claim).

Runs on a 1-device mesh (pp=1); multi-stage equivalence is covered by
tests/test_multidevice.py in a subprocess with forced host devices.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, make_reduced
from repro.core import SamplingParams, ThrottleConfig
from repro.models import transformer as tfm
from repro.models.reference import greedy_generate
from repro.models.serve import ServeDims
from repro.runtime.engine import PipelineEngine


def one_device_mesh():
    return jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)


def build(arch, *, pages=256, page=8, C=16, max_p=16):
    cfg = make_reduced(get_config(arch)).with_plan(pp=1, tp=1,
                                                   ep_over_data=False)
    # dropless MoE: capacity drops are schedule-dependent and would break
    # exact output equivalence (DESIGN.md §7 notes the production tradeoff)
    cf = float(max(cfg.num_experts, 1))
    cfg = dataclasses.replace(cfg, dtype="float32", moe_capacity_factor=cf)
    mesh = one_device_mesh()
    Te = 16 if cfg.is_encoder_decoder else 0
    dims = ServeDims(Sp=1, C=C, Sd=8, pages=pages, page=page, Bp=32, Bd=32,
                     slots=16, Te=Te)
    with jax.set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        pspecs = tfm.param_pspecs(cfg)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, pspecs, is_leaf=lambda x: isinstance(x, P))
        th = ThrottleConfig(pipeline_depth=1, max_prefill_tokens=max_p,
                            min_prefill_tokens=4, num_iters_T=2)
        eng = PipelineEngine(cfg, dims, params, mesh, th)
    return cfg, params, eng, dims


ARCHS = ["qwen1.5-0.5b", "qwen2-vl-7b", "internlm2-1.8b", "minicpm3-4b",
         "olmoe-1b-7b", "rwkv6-3b", "whisper-small"]


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_matches_dense_reference(arch):
    cfg, params, eng, dims = build(arch)
    rng = np.random.default_rng(42)
    prompts = [list(rng.integers(0, cfg.vocab_size, int(n)))
               for n in (7, 23, 12)]
    encs = {}
    reqs = []
    for i, p in enumerate(prompts):
        enc = None
        if cfg.is_encoder_decoder:
            enc = (rng.normal(size=(dims.Te, cfg.d_model)) * 0.05
                   ).astype(np.float32)
        encs[i] = enc
        reqs.append(eng.add_request(p, SamplingParams(max_new_tokens=5),
                                    enc_embeds=enc))
    eng.drain(max_ticks=500)
    for i, (p, r) in enumerate(zip(prompts, reqs)):
        assert r.is_finished, r.state
        want = greedy_generate(cfg, params, p, 5, enc_embeds=encs[i])
        assert r.output_token_ids == want, (
            arch, i, r.output_token_ids, want)


def test_chunked_prefill_equivalence():
    """A prompt longer than the chunk bucket (forced multi-chunk prefill)
    still yields the reference tokens."""
    cfg, params, eng, dims = build("qwen1.5-0.5b", C=8, max_p=8)
    rng = np.random.default_rng(1)
    prompt = list(rng.integers(0, cfg.vocab_size, 37))   # 5 chunks of 8
    r = eng.add_request(prompt, SamplingParams(max_new_tokens=4))
    eng.drain(max_ticks=300)
    want = greedy_generate(cfg, params, prompt, 4)
    assert r.output_token_ids == want


def test_preemption_recompute_equivalence():
    """Force preemption with a tiny KV pool.  Recompute must (a) never
    rewrite an already-streamed token — preempted requests resume, not
    restart — and (b) keep unpreempted requests bit-identical to the dense
    reference.  (Post-recompute tokens of *preempted* requests may differ
    from the reference only by float-associativity at argmax near-ties:
    chunked re-prefill sums attention in a different block order.)"""
    # decode-heavy growth: all three admit while small, then outgrow the pool
    cfg, params, eng, dims = build("qwen1.5-0.5b", pages=10, page=8)
    streamed = {}
    eng.on_token = lambda req, tok: streamed.setdefault(
        req.request_id, []).append(tok)
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab_size, 16)) for _ in range(3)]
    reqs = [eng.add_request(p, SamplingParams(max_new_tokens=18))
            for p in prompts]
    eng.drain(max_ticks=900)
    assert eng.scheduler.stats.preemptions >= 1, "test needs KV pressure"
    for p, r in zip(prompts, reqs):
        assert r.is_finished and r.num_output_tokens == 18
        # (a) the emitted stream is exactly the final output: no rewrites
        assert streamed[r.request_id] == r.output_token_ids
        want = greedy_generate(cfg, params, p, 18)
        if r.metrics.num_preemptions == 0:
            assert r.output_token_ids == want      # (b) bit-identical
        else:
            # prefix up to the first numeric divergence must still be long
            agree = sum(1 for a, b in zip(r.output_token_ids, want)
                        if a == b)
            assert agree >= 5, (r.output_token_ids, want)


def test_sarathi_policy_same_outputs():
    """Policies change *scheduling*, never *results* (Table-1 claim)."""
    from repro.core import PrefillPolicy
    outs = {}
    for pol in (None, PrefillPolicy.SARATHI):
        cfg, params, eng, dims = build("qwen1.5-0.5b")
        if pol is not None:
            eng.scheduler.cfg = dataclasses.replace(eng.scheduler.cfg,
                                                    policy=pol)
        rng = np.random.default_rng(7)
        prompts = [list(rng.integers(0, cfg.vocab_size, int(n)))
                   for n in (11, 19)]
        reqs = [eng.add_request(p, SamplingParams(max_new_tokens=5))
                for p in prompts]
        eng.drain(max_ticks=400)
        outs[pol] = [r.output_token_ids for r in reqs]
    assert outs[None] == outs[PrefillPolicy.SARATHI]


def test_prefix_caching_same_outputs_fewer_prefills():
    """RadixAttention-style prefix reuse: same greedy outputs, fewer prefill
    tokens scheduled for a shared-prefix batch."""
    stats = {}
    outs = {}
    for caching in (False, True):
        cfg, params, eng, dims = build("qwen1.5-0.5b")
        eng.kv.enable_prefix_caching = caching
        rng = np.random.default_rng(11)
        shared = list(rng.integers(0, cfg.vocab_size, 24))
        prompts = [shared + list(rng.integers(0, cfg.vocab_size, 5))
                   for _ in range(3)]
        reqs = []
        for p in prompts:
            reqs.append(eng.add_request(p, SamplingParams(max_new_tokens=4)))
            eng.drain(max_ticks=200)     # serialize so pages are frozen
        outs[caching] = [r.output_token_ids for r in reqs]
        stats[caching] = eng.scheduler.stats.scheduled_prefill_tokens
    assert outs[False] == outs[True]
    assert sum(stats[True]) < sum(stats[False])
