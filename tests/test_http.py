"""HTTP frontend conformance (repro.serving.http, DESIGN.md §11).

A `ThreadingHTTPServer` over a sim-backed `LLMServer` on an ephemeral port:
generate (sync), streaming SSE (incl. mid-stream abort), DELETE-abort,
stats (service-rate EWMA + SLO-class queue composition), request
validation, and spec-declared heterogeneous clusters end-to-end over HTTP.

Everything here is stdlib http on the client side too — the suite runs
anywhere the scheduler does (no jax, no sockets beyond loopback).
"""

import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.serving import (ClusterSpec, EngineSpec, HTTPFrontend, ServeSpec,
                           SimSpec, build)

SPEC = ServeSpec(backend="sim", engine=EngineSpec(arch="qwen2.5-14b"),
                 sim=SimSpec(pp=2, pages=256, page_size=8))


@pytest.fixture()
def frontend():
    fe = HTTPFrontend(build(SPEC), port=0).start()
    yield fe
    fe.shutdown()


def _post(url, body, **kw):
    req = urllib.request.Request(url, data=json.dumps(body).encode(),
                                 method="POST", **kw)
    return urllib.request.urlopen(req, timeout=30)


def _json(resp):
    return json.loads(resp.read())


def _sse_frames(resp):
    """Decode an SSE stream into the JSON payloads, as they arrive."""
    for line in resp:
        line = line.decode().strip()
        if line.startswith("data: "):
            yield json.loads(line[len("data: "):])


# ---------------------------------------------------------------------------
# generate / stream / abort / stats
# ---------------------------------------------------------------------------

def test_generate_sync(frontend):
    out = _json(_post(frontend.url + "/v1/generate",
                      {"prompt": [1, 2, 3, 4], "max_new_tokens": 5}))
    assert out["finish_reason"] == "length"
    assert len(out["token_ids"]) == 5
    assert out["prompt_tokens"] == 4
    assert out["metrics"]["ttft"] is not None
    assert out["metrics"]["e2el"] >= out["metrics"]["ttft"]


def test_generate_honors_request_id_and_slo_fields(frontend):
    out = _json(_post(frontend.url + "/v1/generate",
                      {"prompt": [9] * 8, "max_new_tokens": 2,
                       "request_id": "mine", "slo_class": "batch",
                       "priority": 3}))
    assert out["request_id"] == "mine"
    assert out["finish_reason"] == "length"


def test_stream_sse(frontend):
    resp = _post(frontend.url + "/v1/generate?stream=1",
                 {"prompt": [5, 6, 7], "max_new_tokens": 4})
    assert resp.headers["Content-Type"] == "text/event-stream"
    frames = list(_sse_frames(resp))
    tokens = [f for f in frames if f["token"] is not None]
    assert len(tokens) == 4
    assert [f["index"] for f in tokens] == [1, 2, 3, 4]
    assert frames[-1]["finish_reason"] == "length"
    assert all(f["finish_reason"] is None for f in frames[:-1])


def test_abort_mid_stream(frontend):
    """DELETE from a second connection ends a long-running stream with
    finish_reason="abort" — the full client-visible cancel path."""
    resp = _post(frontend.url + "/v1/generate?stream=1",
                 {"prompt": [1] * 8, "max_new_tokens": 500,
                  "request_id": "victim"})
    frames = _sse_frames(resp)
    first = next(frames)                      # stream is live
    assert first["request_id"] == "victim"

    def do_abort():
        req = urllib.request.Request(
            frontend.url + "/v1/requests/victim", method="DELETE")
        return _json(urllib.request.urlopen(req, timeout=30))

    aborter = threading.Thread(target=do_abort)
    aborter.start()
    rest = list(frames)
    aborter.join(timeout=30)
    assert rest, "stream ended without a terminal frame"
    assert rest[-1]["finish_reason"] == "abort"
    assert len(rest) < 500


def test_abort_unknown_request_404(frontend):
    req = urllib.request.Request(frontend.url + "/v1/requests/ghost",
                                 method="DELETE")
    with pytest.raises(urllib.error.HTTPError) as e:
        urllib.request.urlopen(req, timeout=30)
    assert e.value.code == 404


def test_stats_snapshot(frontend):
    _json(_post(frontend.url + "/v1/generate",
                {"prompt": [1] * 16, "max_new_tokens": 6}))
    stats = _json(urllib.request.urlopen(frontend.url + "/v1/stats",
                                         timeout=30))
    assert len(stats["replicas"]) == 1
    rep = stats["replicas"][0]
    for key in ("ticks", "tokens_retired", "service_rate", "kv_free_rate",
                "waiting", "running_decode", "preemptions",
                "waiting_by_class", "prefix_lookups", "prefix_hits",
                "prefix_tokens_avoided", "bucket", "scanned_pages",
                "live_pages"):
        assert key in rep
    assert stats["tokens_retired"] >= 6
    assert rep["ticks"] > 0
    assert 0 <= rep["live_pages"] <= rep["scanned_pages"] or \
        rep["scanned_pages"] == 0    # sim replicas report no attention depth


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("body,match", [
    ({}, "prompt"),
    ({"prompt": "abc"}, "prompt"),
    ({"prompt": [True, False]}, "prompt"),   # JSON bools are not token ids
    ({"prompt": [1, 2], "typo_knob": 3}, "unknown request field"),
    ({"prompt": [1, 2], "slo_class": "platinum"}, "slo_class"),
])
def test_bad_requests_are_400(frontend, body, match):
    with pytest.raises(urllib.error.HTTPError) as e:
        _post(frontend.url + "/v1/generate", body)
    assert e.value.code == 400
    assert match in json.loads(e.value.read())["error"]


def test_unknown_endpoint_404(frontend):
    with pytest.raises(urllib.error.HTTPError) as e:
        urllib.request.urlopen(frontend.url + "/v1/nope", timeout=30)
    assert e.value.code == 404


# ---------------------------------------------------------------------------
# OpenAI-compatible request/response surface
# ---------------------------------------------------------------------------

def test_openai_completion_shape(frontend):
    """The response body carries an OpenAI-completions shape (`id`,
    `object`, `choices`, `usage`) alongside the repo-native fields."""
    out = _json(_post(frontend.url + "/v1/generate",
                      {"prompt": [1, 2, 3, 4], "max_new_tokens": 5}))
    assert out["object"] == "completion"
    assert out["id"] == out["request_id"]
    choice = out["choices"][0]
    assert choice["index"] == 0
    assert choice["token_ids"] == out["token_ids"]
    assert choice["finish_reason"] == "length"
    assert out["usage"] == {"prompt_tokens": 4, "completion_tokens": 5,
                            "total_tokens": 9}


def test_openai_max_tokens_alias(frontend):
    out = _json(_post(frontend.url + "/v1/generate",
                      {"prompt": [1, 2, 3], "max_tokens": 4}))
    assert out["finish_reason"] == "length"
    assert len(out["token_ids"]) == 4


def test_openai_stop_alias(frontend):
    """`stop` maps onto the native token-id stop list; this prompt's
    first sampled token is deterministic in the sim, so stopping on it
    ends the request after one token with finish_reason="stop"."""
    first = _json(_post(frontend.url + "/v1/generate",
                        {"prompt": [7] * 6, "max_new_tokens": 3}))
    tok = first["token_ids"][0]
    out = _json(_post(frontend.url + "/v1/generate",
                      {"prompt": [7] * 6, "max_new_tokens": 8,
                       "stop": [tok]}))
    assert out["finish_reason"] == "stop"
    assert out["token_ids"] == [tok]


def test_openai_stream_body_flag(frontend):
    """`"stream": true` in the body is equivalent to `?stream=1`."""
    resp = _post(frontend.url + "/v1/generate",
                 {"prompt": [5, 6, 7], "max_new_tokens": 3, "stream": True})
    assert resp.headers["Content-Type"] == "text/event-stream"
    frames = list(_sse_frames(resp))
    assert frames[-1]["finish_reason"] == "length"


@pytest.mark.parametrize("body,match", [
    ({"prompt": [1], "max_tokens": 2, "max_new_tokens": 2},
     "duplicates"),
    ({"prompt": [1], "stream": "yes"}, "stream"),
])
def test_openai_alias_misuse_is_400(frontend, body, match):
    with pytest.raises(urllib.error.HTTPError) as e:
        _post(frontend.url + "/v1/generate", body)
    assert e.value.code == 400
    assert match in json.loads(e.value.read())["error"]


# ---------------------------------------------------------------------------
# spec-driven heterogeneous cluster over HTTP
# ---------------------------------------------------------------------------

def test_heterogeneous_cluster_over_http():
    """`ClusterSpec.sim_overrides` declares an asymmetric pair; balanced
    routing sees the asymmetry through `balance_score` and the whole thing
    serves over HTTP — stats exposes both replica geometries."""
    spec = ServeSpec(backend="sim", engine=EngineSpec(arch="qwen2.5-14b"),
                     sim=SimSpec(pp=2, pages=256, page_size=8),
                     cluster=ClusterSpec(replicas=2, sim_overrides=(
                         None,
                         {"straggler_stage": 0, "straggler_factor": 8.0})))
    fe = HTTPFrontend(build(spec), port=0).start()
    try:
        for i in range(6):
            out = _json(_post(fe.url + "/v1/generate",
                              {"prompt": [i + 1] * 24,
                               "max_new_tokens": 4}))
            assert out["finish_reason"] == "length"
        stats = _json(urllib.request.urlopen(fe.url + "/v1/stats",
                                             timeout=30))
        assert len(stats["replicas"]) == 2
        assert sum(stats["routed_counts"]) == 6
        # the declared straggler must not win the placement majority
        assert stats["routed_counts"][0] >= stats["routed_counts"][1]
    finally:
        fe.shutdown()


def test_concurrent_streams_interleave():
    """Two handler threads streaming at once: both make progress through
    the shared step lock and both terminate cleanly."""
    fe = HTTPFrontend(build(SPEC), port=0).start()
    results = {}

    def one(name, n):
        resp = _post(fe.url + "/v1/generate?stream=1",
                     {"prompt": [1, 2, 3], "max_new_tokens": n})
        results[name] = list(_sse_frames(resp))

    try:
        threads = [threading.Thread(target=one, args=(f"c{i}", 3 + i))
                   for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert results["c0"][-1]["finish_reason"] == "length"
        assert results["c1"][-1]["finish_reason"] == "length"
        assert len([f for f in results["c1"] if f["token"] is not None]) == 4
    finally:
        fe.shutdown()
