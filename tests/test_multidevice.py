"""Multi-device pipeline tests (subprocess: they need
--xla_force_host_platform_device_count, which must NOT leak into the other
tests' single-device jax runtime).

These programs keep the `tensor` axis auto-sharded inside shard_map
(partial-auto lowering); jax versions old enough to need the compat shims
(repro/jax_compat.py) reject that on CPU with 'PartitionId ... not
supported for SPMD partitioning', so the module skips there.
"""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.jax_compat import is_shimmed

pytestmark = pytest.mark.skipif(
    is_shimmed(),
    reason="partial-auto shard_map needs a native newer jax/XLA "
           "(old SPMD partitioner: 'PartitionId instruction is not "
           "supported')")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_pipelined_train_loss_decreases():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, make_reduced
        from repro.distributed.pipeline import build_train_step
        from repro.distributed.optimizer import adam_init
        from repro.models import transformer as tfm

        mesh = jax.make_mesh((2, 2, 2), ("data", "stage", "tensor"),
                             axis_types=(jax.sharding.AxisType.Auto,)*3)
        cfg = make_reduced(get_config("qwen1.5-0.5b")).with_plan(pp=2, tp=2)
        cfg = dataclasses.replace(cfg, dtype="float32")
        with jax.set_mesh(mesh):
            step = jax.jit(build_train_step(cfg, mesh))
            params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
            pspecs = tfm.param_pspecs(cfg)
            params = jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                params, pspecs, is_leaf=lambda x: isinstance(x, P))
            opt = adam_init(params)
            rng = np.random.default_rng(0)
            batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 2, 32)), jnp.int32),
                     "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 2, 32)), jnp.int32)}
            losses = []
            for _ in range(6):
                params, opt, m = step(params, opt, batch)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print("LOSSES", losses[0], losses[-1])
    """)
    assert "LOSSES" in out


@pytest.mark.slow
def test_pipeline_loss_matches_dense_reference():
    """The pp=2/tp=2 train step's loss (pipeline + vocab-sharded xent) must
    equal the dense single-device cross-entropy on the same batch."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, make_reduced
        from repro.distributed.optimizer import AdamConfig, adam_init
        from repro.distributed.pipeline import build_train_step
        from repro.models import transformer as tfm
        from repro.models.reference import dense_forward

        mesh = jax.make_mesh((2, 2, 2), ("data", "stage", "tensor"),
                             axis_types=(jax.sharding.AxisType.Auto,)*3)
        cfg = make_reduced(get_config("internlm2-1.8b")).with_plan(pp=2, tp=2)
        cfg = dataclasses.replace(cfg, dtype="float32")
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        M, mb, T = 4, 2, 16
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (M, mb, T)), jnp.int32)
        labs = jnp.asarray(rng.integers(0, cfg.vocab_size, (M, mb, T)), jnp.int32)
        with jax.set_mesh(mesh):
            # lr=0 so the returned loss is exactly f(params) on this batch
            step = jax.jit(build_train_step(cfg, mesh, adam=AdamConfig(lr=0.0),
                                            aux_coef=0.0))
            pd = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                              params, tfm.param_pspecs(cfg),
                              is_leaf=lambda x: isinstance(x, P))
            _, _, metrics = step(pd, adam_init(pd), {"tokens": toks, "labels": labs})
            got = float(metrics["loss"])

        logits = np.asarray(dense_forward(cfg, params, toks.reshape(M*mb, T)),
                            np.float32)
        flat_l = np.asarray(labs).reshape(M*mb, T)
        lse = jax.nn.logsumexp(jnp.asarray(logits), axis=-1)
        gold = np.take_along_axis(logits, flat_l[..., None], axis=-1)[..., 0]
        want = float(np.mean(np.asarray(lse) - gold))
        assert abs(got - want) < 2e-4, (got, want)
        print("PIPELINE_LOSS_MATCH", got, want)
    """)
    assert "PIPELINE_LOSS_MATCH" in out


@pytest.mark.slow
def test_serve_tick_multistage_engine_equivalence():
    """Engine on a pp=2 mesh produces the dense reference's greedy tokens."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, make_reduced
        from repro.core import SamplingParams, ThrottleConfig
        from repro.models import transformer as tfm
        from repro.models.reference import greedy_generate
        from repro.models.serve import ServeDims
        from repro.runtime.engine import PipelineEngine

        mesh = jax.make_mesh((1, 2, 2), ("data", "stage", "tensor"),
                             axis_types=(jax.sharding.AxisType.Auto,)*3)
        cfg = make_reduced(get_config("qwen1.5-0.5b")).with_plan(pp=2, tp=2)
        cfg = dataclasses.replace(cfg, dtype="float32")
        dims = ServeDims(Sp=1, C=16, Sd=8, pages=256, page=8, Bp=32, Bd=32, slots=16)
        with jax.set_mesh(mesh):
            params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
            pspecs = tfm.param_pspecs(cfg)
            params = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                                  params, pspecs, is_leaf=lambda x: isinstance(x, P))
            th = ThrottleConfig(pipeline_depth=2, max_prefill_tokens=16,
                                min_prefill_tokens=4, num_iters_T=2)
            eng = PipelineEngine(cfg, dims, params, mesh, th)
        rng = np.random.default_rng(5)
        prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (9, 21)]
        reqs = [eng.add_request(p, SamplingParams(max_new_tokens=5)) for p in prompts]
        eng.drain(max_ticks=400)
        for p, r in zip(prompts, reqs):
            want = greedy_generate(cfg, params, p, 5)
            assert r.output_token_ids == want, (r.output_token_ids, want)
        print("SERVE_MULTISTAGE_MATCH")
    """)
    assert "SERVE_MULTISTAGE_MATCH" in out


@pytest.mark.slow
def test_ep_moe_train_and_grad_compression():
    """Expert-parallel MoE over the data axis + int8/ring8 grad compression
    all lower, run, and keep the loss finite & decreasing."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, make_reduced
        from repro.distributed.pipeline import build_train_step
        from repro.distributed.optimizer import adam_init
        from repro.models import transformer as tfm

        mesh = jax.make_mesh((2, 2, 2), ("data", "stage", "tensor"),
                             axis_types=(jax.sharding.AxisType.Auto,)*3)
        cfg = make_reduced(get_config("kimi-k2-1t-a32b")).with_plan(pp=2, tp=2)
        cfg = dataclasses.replace(cfg, dtype="float32")
        assert cfg.plan.ep_over_data
        for mode in (None, "int8", "ring8"):
            with jax.set_mesh(mesh):
                step = jax.jit(build_train_step(cfg, mesh, grad_compression=mode))
                params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
                pspecs = tfm.param_pspecs(cfg)
                params = jax.tree.map(
                    lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                    params, pspecs, is_leaf=lambda x: isinstance(x, P))
                opt = adam_init(params)
                rng = np.random.default_rng(0)
                batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 2, 32)), jnp.int32),
                         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 2, 32)), jnp.int32)}
                losses = []
                for _ in range(4):
                    params, opt, m = step(params, opt, batch)
                    losses.append(float(m["loss"]))
            assert all(np.isfinite(losses)), (mode, losses)
            assert losses[-1] < losses[0], (mode, losses)
            print("MODE_OK", mode, round(losses[0], 3), round(losses[-1], 3))
    """, timeout=1200)
    assert out.count("MODE_OK") == 3
