"""Shared runtime core: TickLoop/ExecutionBackend semantics, and the
ReplicaRouter's globally-balanced multi-replica routing (DESIGN.md §1)."""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    PrefillPolicy,
    Request,
    SamplingParams,
    ThrottleConfig,
)
from repro.data.workload import SHAREGPT, sample_requests
from repro.runtime.core import ExecResult, ExecutionBackend, TickLoop
from repro.runtime.router import (
    BalanceWeights,
    ReplicaRouter,
    ReplicaSnapshot,
    RoutingPolicy,
    SimCluster,
    balance_score,
)
from repro.runtime.simulator import (
    PipelineSimulator,
    RuntimeModel,
    cost_model_for,
)

CFG = get_config("qwen2.5-14b")


def make_sched(pp=3, pages=4096, policy=PrefillPolicy.GLLM):
    th = ThrottleConfig(pipeline_depth=pp, policy=policy)
    kv = PagedKVManager(num_pages=pages, page_size=16)
    return PipelineScheduler(th, kv, max_model_len=pages * 16)


class RecordingBackend(ExecutionBackend):
    """Toy backend: constant token 9, records the ring at each tick."""

    def __init__(self, pp):
        self.pp = pp
        self.rings = []
        self.finished_reqs = []

    @property
    def depth(self):
        return self.pp

    def execute(self, ring, exiting_id, now):
        self.rings.append([bid for bid, _ in ring])
        if exiting_id is None:
            return ExecResult([], now)
        batch = self.scheduler.get_batch(exiting_id)
        n = sum(1 for s in batch.seqs if s.produces_token)
        return ExecResult([9] * n, now)

    def finish_request(self, req):
        self.finished_reqs.append(req.request_id)


class TestTickLoop:
    def test_ring_depth_and_retirement_delay(self):
        """A batch scheduled at tick t exits at tick t+depth-1: it spends one
        tick per pipeline stage, finishing its last stage on the final one."""
        pp = 3
        sched = make_sched(pp=pp)
        be = RecordingBackend(pp)
        loop = TickLoop(sched, be)
        r = Request("a", [1] * 4, SamplingParams(max_new_tokens=1))
        sched.add_request(r)
        assert not loop.busy
        loop.step(0.0)                       # schedules the prefill
        first_id = be.rings[-1][0]
        assert first_id is not None and loop.busy
        for k in range(pp - 2):              # mid-pipeline, bubbles behind it
            loop.step(float(k + 1))
            assert not r.is_finished
        finished = loop.step(float(pp - 1))  # last stage: exits the ring
        assert r.is_finished and finished == [r]
        assert not loop.busy
        assert loop.finished == [r]
        assert be.finished_reqs == ["a"]
        assert r.output_token_ids == [9]

    def test_depth_one_retires_same_tick(self):
        sched = make_sched(pp=1)
        be = RecordingBackend(1)
        loop = TickLoop(sched, be)
        r = Request("a", [1] * 4, SamplingParams(max_new_tokens=1))
        sched.add_request(r)
        assert loop.step(0.0) == [r]
        assert r.is_finished

    def test_streaming_hook_and_drain(self):
        pp = 2
        sched = make_sched(pp=pp)
        be = RecordingBackend(pp)
        streamed = []
        loop = TickLoop(sched, be,
                        on_token=lambda req, tok: streamed.append(
                            (req.request_id, tok)))
        reqs = [Request(f"r{i}", [1] * 5, SamplingParams(max_new_tokens=3))
                for i in range(3)]
        for r in reqs:
            sched.add_request(r)
        clock = iter(range(10000))
        loop.drain(lambda: float(next(clock)))
        assert all(r.is_finished for r in reqs)
        assert not loop.busy and not sched.has_work
        assert len(streamed) == sum(r.num_output_tokens for r in reqs)
        assert all(tok == 9 for _, tok in streamed)

    def test_abort_inflight_requeues_and_clears_ring(self):
        pp = 4
        sched = make_sched(pp=pp)
        be = RecordingBackend(pp)
        loop = TickLoop(sched, be)
        r = Request("a", [1] * 40, SamplingParams(max_new_tokens=4))
        sched.add_request(r)
        loop.step(0.0)
        assert loop.busy
        affected = loop.abort_inflight()
        assert r in affected and not loop.busy
        assert sched.active_batch_ids() == []
        assert r in sched.waiting
        loop.drain(lambda: 1.0)
        assert r.is_finished


class TestSimulatorOnCore:
    """The simulator runs the same TickLoop as the engine."""

    def test_sim_is_a_tickloop(self):
        sched = make_sched(pp=4)
        sim = PipelineSimulator(sched, 4, cost_model_for(CFG, pp=4))
        assert isinstance(sim.loop, TickLoop)
        assert sim.backend.depth == 4
        sim.add_workload(sample_requests(SHAREGPT, 40, 20.0, seed=0))
        m = sim.run()
        assert len(m.finished) == 40
        assert m.ttft() > 0 and m.throughput() > 0

    def test_run_until_is_causal(self):
        """run_until(t) never starts a tick after t."""
        sched = make_sched(pp=4)
        sim = PipelineSimulator(sched, 4, cost_model_for(CFG, pp=4))
        sim.add_workload(sample_requests(SHAREGPT, 60, 30.0, seed=1))
        sim.run_until(0.5)
        assert sim._next_tick_time() > 0.5 or not (
            sim.sched.has_work or sim.loop.busy)
        done_early = len(sim.metrics.finished)
        sim.run()
        assert len(sim.metrics.finished) == 60
        assert len(sim.metrics.finished) >= done_early


def _hetero_cluster(policy, *, slow_factor=2.5, pp=4, pages=4096,
                    capacities=None):
    """Two replicas, one `slow_factor`x slower.  Without `capacities` the
    router must discover the imbalance from scheduler backlog alone; with
    them it also normalizes load by known relative speed."""
    cost = cost_model_for(CFG, pp=pp)
    sims = [
        PipelineSimulator(make_sched(pp=pp, pages=pages), pp, cost),
        PipelineSimulator(make_sched(pp=pp, pages=pages), pp,
                          cost.scaled(slow_factor)),
    ]
    router = ReplicaRouter(sims, policy=policy, capacities=capacities)
    return SimCluster(sims, router)


class TestReplicaRouter:
    def test_round_robin_alternates(self):
        sims = [PipelineSimulator(make_sched(), 3, cost_model_for(CFG, pp=3))
                for _ in range(3)]
        router = ReplicaRouter(sims, policy="rr")
        assert [router.select(10) for _ in range(6)] == [0, 1, 2, 0, 1, 2]
        assert router.routed_counts == [2, 2, 2]

    def test_balance_score_prefers_idle_and_kv_free(self):
        idle = ReplicaSnapshot(0, 0, 1.0)
        busy = ReplicaSnapshot(4000, 0, 1.0)
        starved = ReplicaSnapshot(0, 0, 0.05)
        w = BalanceWeights()
        assert balance_score(idle, 100, w) < balance_score(busy, 100, w)
        assert balance_score(idle, 100, w) < balance_score(starved, 100, w)
        # decode population counts as pending work
        decoding = ReplicaSnapshot(0, 64, 1.0)
        assert balance_score(idle, 100, w) < balance_score(decoding, 100, w)

    def test_balanced_routing_sheds_load_off_slow_replica(self):
        cluster = _hetero_cluster(RoutingPolicy.BALANCED)
        arrivals = sample_requests(SHAREGPT, 150, 30.0, seed=0)
        cluster.run(arrivals)
        fast, slow = cluster.router.routed_counts
        assert fast + slow == 150
        assert fast > slow          # backlog signal diverted load

    def test_global_balance_beats_round_robin_on_tail_ttft(self):
        """ISSUE acceptance: skewed (heavy-tailed lognormal, Poisson-bursty)
        arrivals onto heterogeneous replicas at a rate that saturates the
        slow replica under round-robin — balance-score routing beats
        round-robin on tail TTFT (and mean TTFT, and throughput)."""
        results = {}
        for policy in ("rr", "balanced"):
            cluster = _hetero_cluster(policy, capacities=[1.0, 1 / 2.5])
            arrivals = sample_requests(SHAREGPT, 150, 60.0, seed=0)
            finished = cluster.run(arrivals)
            assert len(finished) == 150
            results[policy] = cluster
        assert results["balanced"].ttft_quantile(0.95) < \
            results["rr"].ttft_quantile(0.95)
        assert results["balanced"].mean_ttft() < results["rr"].mean_ttft()
        assert results["balanced"].throughput() > results["rr"].throughput()

    def test_single_replica_router_is_transparent(self):
        sim = PipelineSimulator(make_sched(), 3, cost_model_for(CFG, pp=3))
        router = ReplicaRouter([sim])
        assert router.scheduler is sim.sched
        assert router.select(10) == 0
