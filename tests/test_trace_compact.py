"""Trace compaction: delta-encoded tick records must be a *lossless*
re-encoding — same records, same bytes after expansion, same replay
behavior — on the checked-in golden fixtures and on freshly recorded runs.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.runtime.trace import (
    Trace,
    TraceSchemaError,
    check_trace,
    compact_records,
    dumps_record,
    expand_records,
)

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = ["prefill_heavy.trace.jsonl", "decode_saturated.trace.jsonl"]


def fixture_path(name):
    return os.path.join(HERE, "fixtures", "traces", name)


def raw_records(path):
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


@pytest.mark.parametrize("name", FIXTURES)
class TestLosslessRoundTrip:
    def test_expand_inverts_compact_exactly(self, name):
        records = raw_records(fixture_path(name))
        compacted = compact_records(records)
        expanded = expand_records(compacted)
        # byte-level identity, not just ==: field order is part of the
        # round-trip guarantee (dumps_record serializes insertion order)
        want = [dumps_record(r) for r in records]
        got = [dumps_record(r) for r in expanded]
        assert got == want

    def test_compaction_actually_shrinks(self, name):
        records = raw_records(fixture_path(name))
        compacted = compact_records(records)
        raw = sum(len(dumps_record(r)) for r in records)
        small = sum(len(dumps_record(r)) for r in compacted)
        # steady-state decode ticks repeat most scalar fields AND collapse
        # their batch to the `STEADY_DECODE` marker; prefill-heavy ticks
        # change their batch every record, so less drops out
        budget = {"prefill_heavy.trace.jsonl": 0.92,
                  "decode_saturated.trace.jsonl": 0.65}[name]
        assert small < budget * raw, (small, raw)

    def test_compacted_trace_loads_transparently(self, name, tmp_path):
        records = raw_records(fixture_path(name))
        compacted = compact_records(records)
        out = tmp_path / name
        out.write_text("\n".join(dumps_record(r) for r in compacted) + "\n")
        trace = Trace.load(str(out))
        assert "compact" not in trace.header
        assert trace.dumps() == Trace.load(fixture_path(name)).dumps()

    def test_compacted_trace_passes_strict_replay_gate(self, name,
                                                       tmp_path):
        records = raw_records(fixture_path(name))
        compacted = compact_records(records)
        out = tmp_path / name
        out.write_text("\n".join(dumps_record(r) for r in compacted) + "\n")
        report = check_trace(str(out))     # the `make trace-check` gate
        assert report.ticks == len(Trace.load(fixture_path(name)).ticks)


class TestSteadyDecodeDelta:
    """Steady decode batches (same requests, one step later, `depth` ticks
    apart) collapse to the `STEADY_DECODE` marker — the decode-heavy
    fixture is dominated by them."""

    def test_markers_dominate_decode_heavy_fixture(self):
        records = raw_records(fixture_path("decode_saturated.trace.jsonl"))
        compacted = compact_records(records)
        ticks = sum(1 for r in records if r.get("kind") == "tick")
        markers = sum(1 for r in compacted if r.get("batch") == "+1")
        assert markers > 0.5 * ticks, (markers, ticks)

    def test_marker_expands_to_the_cohorts_batch(self):
        records = raw_records(fixture_path("decode_saturated.trace.jsonl"))
        depth = records[0]["depth"]
        compacted = compact_records(records)
        expanded = expand_records(compacted)
        # pair each marker with the original tick it must reconstruct
        originals = {r["tick"]: r for r in records if r.get("kind") == "tick"}
        for rec, full in zip(compacted, expanded):
            if rec.get("batch") != "+1":
                continue
            want = originals[full["tick"]]["batch"]
            assert full["batch"] == want
            prev = originals[full["tick"] - depth]["batch"]
            assert full["batch"]["decode"] == [
                [rid, s + 1] for rid, s in prev["decode"]]


class TestCompactionEdges:
    def test_compact_is_idempotent(self):
        records = raw_records(fixture_path(FIXTURES[0]))
        once = compact_records(records)
        twice = compact_records(once)
        assert twice == once

    def test_non_canonical_tick_rejected(self):
        records = raw_records(fixture_path(FIXTURES[0]))
        # re-order one tick's keys: loses the byte-identity guarantee
        for i, rec in enumerate(records):
            if rec.get("kind") == "tick":
                scrambled = dict(reversed(list(rec.items())))
                records[i] = scrambled
                break
        with pytest.raises(TraceSchemaError):
            compact_records(records)

    def test_non_tick_records_pass_through(self):
        records = raw_records(fixture_path(FIXTURES[0]))
        compacted = compact_records(records)
        want = [r for r in records if r["kind"] not in ("tick", "header")]
        got = [r for r in compacted if r["kind"] not in ("tick", "header")]
        assert got == want

    def test_cli_compact_roundtrip(self, tmp_path):
        src = fixture_path(FIXTURES[0])
        out = str(tmp_path / "c.jsonl")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(HERE, "..", "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        res = subprocess.run(
            [sys.executable, "-m", "repro.runtime.trace", "compact", src,
             "-o", out], capture_output=True, text=True, env=env)
        assert res.returncode == 0, res.stderr
        assert os.path.getsize(out) < os.path.getsize(src)
        assert Trace.load(out).dumps() == Trace.load(src).dumps()


class TestRunLengthEncoding:
    """Schema 1.5: `stage_times` and exit token lists run-length encode to
    `{"r": [[value, count], ...]}` iff strictly shorter — deterministic, so
    the delta stream stays byte-stable through compact/expand cycles."""

    def test_rle_engages_on_repetitive_fields(self):
        from repro.runtime.trace import _maybe_rle, _rle_expand
        enc = _maybe_rle([7] * 12)
        assert isinstance(enc, dict) and enc == {"r": [[7, 12]]}
        assert _rle_expand(enc["r"]) == [7] * 12

    def test_rle_declines_when_not_shorter(self):
        from repro.runtime.trace import _maybe_rle
        varied = [1, 2, 3, 4, 5]
        assert _maybe_rle(varied) is varied      # raw list passes through
        assert _maybe_rle([3]) == [3]            # too short to ever win

    @pytest.mark.parametrize("name", FIXTURES)
    def test_rle_fields_expand_losslessly_on_fixtures(self, name):
        records = raw_records(fixture_path(name))
        compacted = compact_records(records)
        saw = 0
        for rec in compacted:
            if isinstance(rec.get("stage_times"), dict):
                saw += 1
            ex = rec.get("exit")
            if isinstance(ex, dict) and isinstance(ex.get("tokens"), dict):
                saw += 1
        # sim traces have uniform stage costs -> stage_times RLE must win
        # somewhere; expansion must still reproduce every original byte
        assert saw > 0
        want = [dumps_record(r) for r in records]
        got = [dumps_record(r) for r in expand_records(compacted)]
        assert got == want

    def test_synthetic_exit_tokens_round_trip(self):
        records = raw_records(fixture_path(FIXTURES[0]))
        # graft a long constant token burst onto one exit record so the
        # exit-token RLE arm is exercised even if fixtures never hit it
        for rec in records:
            if rec.get("kind") == "tick" and rec.get("exit"):
                rec["exit"]["tokens"] = [0] * 32
                break
        compacted = compact_records(records)
        assert any(isinstance(r.get("exit"), dict)
                   and isinstance(r["exit"].get("tokens"), dict)
                   for r in compacted)
        want = [dumps_record(r) for r in records]
        got = [dumps_record(r) for r in expand_records(compacted)]
        assert got == want
