"""Cluster-scale elasticity (DESIGN.md §16).

Five layers, matching the autoscaler's structure:

  * policy — `AutoscalePolicy` validation and exact JSON round-trip through
    `ClusterSpec.autoscale`; the spec layer rejects fleets outside the
    policy's bounds and autoscaling on non-sim backends;
  * signal — `replica_pressure` / `scale_up_step` arithmetic, and the
    shared `attainment_by_class` definition (pinned here because
    `GET /v1/stats`, fig_autoscale, and fig_disagg all report through it);
  * lifecycle — scale-up under a flash crowd, drains that conserve every
    request (nothing lost, duplicated, or leaked; KV pool empty at
    retire), role-safe victim selection, and the in-transit re-home path
    when a delivery's destination drains or retires mid-flight;
  * accounting — ordinal-keyed router state survives fleet-size changes
    between passes (the positional-index regression), and the chaos
    auditor `check_invariants` actually *fails* against a deliberately
    broken drain (the suite has teeth);
  * recording — elastic runs strict-replay byte-identically through the
    1.6 `scale_up`/`drain`/`retire` records, and pre-1.6 traces load.
"""

import json
import os

import pytest

from repro.configs import get_config
from repro.core import (
    SLO_BATCH,
    SLO_INTERACTIVE,
    PagedKVManager,
    PipelineScheduler,
    PrefillPolicy,
    Request,
    SamplingParams,
    ThrottleConfig,
)
from repro.data.workload import diurnal_requests, flash_crowd_requests
from repro.runtime.autoscale import (
    DEFAULT_SLOS,
    AutoscalePolicy,
    attainment_by_class,
    fleet_pressure,
    replica_pressure,
    request_attains,
    scale_up_step,
)
from repro.runtime.disagg import (
    ROLE_DECODE,
    ROLE_MIXED,
    ROLE_PREFILL,
    HandoffPolicy,
    retirable,
)
from repro.runtime.router import ReplicaRouter, SimCluster
from repro.runtime.simulator import PipelineSimulator, cost_model_for
from repro.runtime.trace import SCHEMA_MAJOR, Trace, check_trace, replay_trace

CFG = get_config("qwen2.5-14b")


def make_sim(pp=2, pages=512, page_size=8, caching=False):
    th = ThrottleConfig(pipeline_depth=pp, policy=PrefillPolicy.GLLM)
    kv = PagedKVManager(num_pages=pages, page_size=page_size,
                        enable_prefix_caching=caching)
    sched = PipelineScheduler(th, kv, max_model_len=pages * page_size)
    return PipelineSimulator(sched, pp, cost_model_for(CFG, pp=pp))


def elastic_cluster(n=1, *, policy=None, roles=None, trace_dir=None,
                    pages=512, caching=False):
    """`n` mixed sims behind an autoscaling router whose factory mints more
    of the same geometry."""
    pol = policy or AutoscalePolicy(interval=0.05, max_replicas=6,
                                    up_cooldown=0.1, down_cooldown=0.5,
                                    target_queue=2.0)
    sims = [make_sim(pages=pages, caching=caching) for _ in range(n)]
    router = ReplicaRouter(
        sims, policy="balanced", roles=roles, autoscale=pol,
        replica_factory=lambda o: make_sim(pages=pages, caching=caching))
    return SimCluster(sims, router, trace_dir=trace_dir)


def flash_crowd(num=60, seed=0):
    return flash_crowd_requests(4.0, base_rate=1e-9, spike_rate=num / 1.0,
                                spike_start=0.5, spike_len=1.0,
                                mean_input=64.0, mean_output=16.0, seed=seed)


def alive_rids(router):
    """Every live request id in the cluster, including mid-tick in-flight
    ones that have left `waiting` but not yet entered a running list."""
    out = []
    for r in router.replicas:
        sched = r.scheduler
        seen = set()
        for group in (sched.waiting, sched.running_prefill,
                      sched.running_decode):
            for req in group:
                seen.add(req.request_id)
        for bid in sched.active_batch_ids():
            for seq in sched.get_batch(bid).seqs:
                seen.add(seq.request.request_id)
        out.extend(seen)
    return out


def run_ticks(sched, n, clock_start=0.0):
    """Drive a bare scheduler loop: schedule+complete with dummy tokens."""
    now = clock_start
    for _ in range(n):
        batch = sched.schedule(now)
        toks = [7] * sum(1 for s in batch.seqs if s.produces_token)
        sched.complete(batch.batch_id, toks, now)
        now += 0.01
    return now


# ---------------------------------------------------------------------------
# policy + spec layer
# ---------------------------------------------------------------------------

class TestPolicy:
    def test_defaults_are_sane(self):
        pol = AutoscalePolicy()
        assert pol.down_threshold < pol.up_threshold
        assert pol.min_replicas >= 1 and pol.max_replicas >= pol.min_replicas
        assert pol.interval > 0 and pol.max_step_up >= 1

    @pytest.mark.parametrize("kw", [
        dict(min_replicas=0),
        dict(min_replicas=4, max_replicas=2),
        dict(down_threshold=1.0, up_threshold=1.0),
        dict(interval=0.0),
    ])
    def test_validation(self, kw):
        with pytest.raises(ValueError):
            AutoscalePolicy(**kw)

    def test_spec_round_trip_exact(self):
        from repro.serving import ClusterSpec, ServeSpec
        spec = ServeSpec(
            backend="sim",
            cluster=ClusterSpec(
                replicas=2,
                autoscale=AutoscalePolicy(interval=0.2, max_replicas=32,
                                          target_queue=6.0)))
        again = ServeSpec.from_json(spec.to_json())
        assert again == spec
        assert again.cluster.autoscale == AutoscalePolicy(
            interval=0.2, max_replicas=32, target_queue=6.0)

    def test_spec_rejects_fleet_outside_policy_bounds(self):
        from repro.serving import ClusterSpec
        with pytest.raises(ValueError, match="autoscale range"):
            ClusterSpec(replicas=2,
                        autoscale=AutoscalePolicy(min_replicas=3,
                                                  max_replicas=8))
        with pytest.raises(ValueError, match="autoscale range"):
            ClusterSpec(replicas=9,
                        autoscale=AutoscalePolicy(max_replicas=8))

    def test_spec_rejects_autoscale_off_sim(self):
        from repro.serving import ClusterSpec, ServeSpec
        with pytest.raises(ValueError, match="sim"):
            ServeSpec(backend="engine",
                      cluster=ClusterSpec(replicas=2,
                                          autoscale=AutoscalePolicy()))


# ---------------------------------------------------------------------------
# pressure signal + scale step
# ---------------------------------------------------------------------------

class TestPressure:
    def test_idle_replica_has_zero_pressure(self):
        pol = AutoscalePolicy()
        assert replica_pressure(make_sim(), pol) == 0.0
        assert fleet_pressure([make_sim(), make_sim()], pol) == 0.0

    def test_queue_depth_normalizes_to_target(self):
        pol = AutoscalePolicy(target_queue=4.0)
        sim = make_sim()
        for k in range(8):
            sim.sched.add_request(
                Request(f"q{k}", [1] * 16, SamplingParams(max_new_tokens=4)))
        assert replica_pressure(sim, pol) == pytest.approx(2.0)

    def test_scale_up_step_is_proportional_and_clamped(self):
        pol = AutoscalePolicy(max_replicas=32, max_step_up=8)
        # barely over threshold: one replica
        assert scale_up_step(4, 1.01, pol) == 1
        # 2x overload at n=4 wants ~4 more
        assert scale_up_step(4, 2.0, pol) == 4
        # huge overload clamps to max_step_up ...
        assert scale_up_step(4, 10.0, pol) == 8
        # ... and to the max_replicas ceiling
        assert scale_up_step(30, 10.0, pol) == 2
        assert scale_up_step(32, 10.0, pol) == 0


# ---------------------------------------------------------------------------
# attainment — the one shared definition (stats surface + both benchmarks)
# ---------------------------------------------------------------------------

def _finished_req(rid, cls, *, ttft, tpot, n_out=11):
    r = Request(rid, [1] * 8,
                SamplingParams(max_new_tokens=n_out, slo_class=cls))
    r.output_token_ids = [0] * n_out
    r.metrics.arrival_time = 1.0
    r.metrics.first_token_time = 1.0 + ttft
    r.metrics.finish_time = 1.0 + ttft + tpot * (n_out - 1)
    return r


class TestAttainment:
    def test_pinned_definition(self):
        """A request attains iff TTFT <= slo["ttft"] AND mean TPOT <=
        slo["tbt"]; the class row reports n/attained/attainment and p95s.
        This is the single definition every reporting surface shares —
        changing it is an API break, not a tweak."""
        slos = {SLO_INTERACTIVE: {"ttft": 1.0, "tbt": 0.1},
                SLO_BATCH: {"ttft": 10.0, "tbt": 1.0}}
        reqs = [
            _finished_req("a", SLO_INTERACTIVE, ttft=0.5, tpot=0.05),  # ok
            _finished_req("b", SLO_INTERACTIVE, ttft=2.0, tpot=0.05),  # ttft
            _finished_req("c", SLO_INTERACTIVE, ttft=0.5, tpot=0.2),   # tbt
            _finished_req("d", SLO_BATCH, ttft=5.0, tpot=0.5),         # ok
        ]
        out = attainment_by_class(reqs, slos, elapsed=10.0)
        inter = out[SLO_INTERACTIVE]
        assert inter["n"] == 3 and inter["attained"] == 1
        assert inter["attainment"] == pytest.approx(1 / 3)
        assert inter["goodput"] == pytest.approx(0.1)
        batch = out[SLO_BATCH]
        assert batch["n"] == 1 and batch["attainment"] == 1.0
        assert inter["ttft_p95"] > 0 and inter["tbt_p95"] > 0

    def test_empty_class_attains_vacuously(self):
        out = attainment_by_class([])
        assert set(out) == set(DEFAULT_SLOS)
        for row in out.values():
            assert row["n"] == 0 and row["attainment"] == 1.0
            assert "goodput" not in row  # only with elapsed=

    def test_no_first_token_never_attains(self):
        r = Request("x", [1] * 8, SamplingParams())
        assert not request_attains(r, {"ttft": 100.0, "tbt": 100.0})

    def test_benchmarks_share_this_definition(self):
        from benchmarks.fig_autoscale import SLOS as auto_slos
        from benchmarks.fig_disagg import SLOS as disagg_slos
        from benchmarks.fig_disagg import _per_class
        assert _per_class is attainment_by_class
        assert auto_slos == disagg_slos == DEFAULT_SLOS


# ---------------------------------------------------------------------------
# lifecycle: scale-up, drain, retire
# ---------------------------------------------------------------------------

class TestScaleUp:
    def test_add_replica_requires_factory(self):
        router = ReplicaRouter([make_sim()])
        with pytest.raises(RuntimeError, match="replica_factory"):
            router.add_replica()

    def test_flash_crowd_grows_fleet_and_conserves_requests(self):
        cluster = elastic_cluster(1)
        router = cluster.router
        reqs = flash_crowd(60)
        fin = cluster.run(reqs, until=120.0)
        st = router.autoscale_stats
        assert st.replicas_added > 0, "flash crowd must trigger scale-up"
        assert len(fin) == len(reqs)
        router.check_invariants(
            expected_rids=[r.request_id for r in fin])
        # the burst absorbed, underload drains the fleet back down
        assert st.retired > 0
        assert len(router.replicas) < 1 + st.replicas_added
        up_sizes = [s for _, k, s in st.events if k == "scale_up"]
        assert up_sizes == sorted(up_sizes)

    def test_newborn_replicas_get_namespaced_rid_streams(self):
        cluster = elastic_cluster(1)
        cluster.run(flash_crowd(60), until=120.0)
        fin = cluster.finished
        assert len(fin) == len({r.request_id for r in fin})

    def test_up_cooldown_rate_limits_growth(self):
        pol = AutoscalePolicy(interval=0.05, up_cooldown=1e9,
                              max_replicas=6, target_queue=2.0)
        cluster = elastic_cluster(1, policy=pol)
        cluster.run(flash_crowd(60), until=120.0)
        assert cluster.router.autoscale_stats.scale_ups <= 1


class TestDrain:
    def _loaded_cluster(self):
        """3 mixed replicas, replica 0 holding waiting + resident work."""
        sims = [make_sim() for _ in range(3)]
        router = ReplicaRouter(sims, policy="balanced")
        for k in range(6):
            sims[0].inject_request(0.0, [1] * 64, 8)
        sims[0].run_until(0.05)  # some admitted + resident, some waiting
        return SimCluster(sims, router), router

    def test_drain_conserves_and_retires_with_empty_pool(self):
        cluster, router = self._loaded_cluster()
        victim = router.replicas[0]
        rids = alive_rids(router)
        assert len(rids) == 6
        router.start_drain(0, now=0.05)
        cluster.drain()
        assert victim in router.retired
        assert sorted(r.request_id for r in cluster.finished) == sorted(rids)
        router.check_invariants(expected_rids=rids)
        # no KV leaked on retire: the victim's pool is fully free
        kv = victim.sched.kv
        assert kv.num_free_pages == kv.num_pages
        assert router.autoscale_stats.drain_moves > 0
        assert router.autoscale_stats.retired == 1

    def test_draining_replica_masked_from_admission(self):
        cluster, router = self._loaded_cluster()
        router.start_drain(0, now=0.05)
        assert 0 not in router._admissible
        for _ in range(8):
            assert router.select(16) != 0

    def test_drain_refuses_to_break_role_cover(self):
        sims = [make_sim(), make_sim()]
        router = ReplicaRouter(sims, roles=(ROLE_PREFILL, ROLE_DECODE),
                               handoff=HandoffPolicy(interval=0.01))
        for i in range(2):  # each is the last of its kind
            with pytest.raises(ValueError, match="cover"):
                router.start_drain(i)
        single = ReplicaRouter([make_sim()])
        with pytest.raises(ValueError, match="cover"):
            single.start_drain(0)

    def test_double_drain_rejected(self):
        cluster, router = self._loaded_cluster()
        router.start_drain(0, now=0.05)
        with pytest.raises(ValueError, match="already draining"):
            router.start_drain(0, now=0.06)

    def test_retirable_keeps_prefill_and_decode_cover(self):
        assert retirable((ROLE_MIXED, ROLE_MIXED), 0)
        assert not retirable((ROLE_PREFILL, ROLE_DECODE), 0)
        assert not retirable((ROLE_PREFILL, ROLE_DECODE), 1)
        assert retirable((ROLE_PREFILL, ROLE_MIXED, ROLE_DECODE), 0)
        assert not retirable((ROLE_MIXED,), 0)

    def test_autoscaler_never_drains_last_role_holder(self):
        """Underload on a disaggregated fleet: the scale-down pass must
        skip the lowest-pressure victim when removing it would break role
        cover — the pure-prefill replica survives every drain because it
        is the fleet's only prefill capability."""
        pol = AutoscalePolicy(interval=0.05, min_replicas=1,
                              down_cooldown=0.0, target_queue=2.0)
        sims = [make_sim() for _ in range(3)]
        router = ReplicaRouter(sims, roles=(ROLE_PREFILL, ROLE_DECODE,
                                            ROLE_DECODE),
                               handoff=HandoffPolicy(interval=0.01),
                               autoscale=pol)
        for t in range(1, 40):  # idle fleet, many passes: EWMA decays to 0
            router.control_tick(t * 0.05)
        # one redundant decode replica retired; the survivors are exactly
        # the minimal role cover, which no further pass may shrink
        assert router.autoscale_stats.retired == 1
        assert sims[0] in router.replicas, "last prefill must survive"
        assert router.roles == (ROLE_PREFILL, ROLE_DECODE)
        assert router.retired[0] in (sims[1], sims[2])


# ---------------------------------------------------------------------------
# in-transit deliveries across fleet changes (re-home, §15/§13 composition)
# ---------------------------------------------------------------------------

class TestInTransitRehome:
    def _resident_on(self, sim, rid="mig", tokens=64, out=32):
        """Drive the bare scheduler to a clean tick boundary with `rid`
        resident in decode (no sim-loop tick in flight, so the control
        plane may drain it)."""
        req = Request(rid, [1] * tokens, SamplingParams(max_new_tokens=out))
        sim.sched.add_request(req)
        run_ticks(sim.sched, 4)
        assert req in sim.sched.running_decode
        return req

    def test_delivery_to_draining_dst_is_rehomed_not_dropped(self):
        sims = [make_sim() for _ in range(3)]
        router = ReplicaRouter(sims, policy="balanced")
        req = self._resident_on(sims[0])
        assert router.migrate_request(req.request_id, 0, 1, now=0.1)
        assert router.has_in_transit
        router.start_drain(1, now=0.1)
        # flush past the transfer delay: dst is draining -> re-homed
        router.control_tick(10.0)
        assert not router.has_in_transit
        assert router.autoscale_stats.rehomed == 1
        assert not any(r.request_id == req.request_id
                       for r in sims[1].sched.waiting)
        assert not sims[1].sched.kv.has_request(req.request_id)
        assert req in sims[2].sched.running_decode or any(
            r.request_id == req.request_id for r in sims[2].sched.waiting
        ) or req in sims[0].sched.running_decode
        router.check_invariants(expected_rids=[req.request_id])

    def test_retire_waits_for_in_transit_toward_victim(self):
        """Satellite regression: drain a replica that is mid-handoff
        *destination* — the victim cannot retire while a payload is on the
        wire toward it, and the flush re-homes instead of delivering into
        a draining replica (no request is double-moved)."""
        sims = [make_sim() for _ in range(3)]
        router = ReplicaRouter(sims, policy="balanced",
                               roles=(ROLE_PREFILL, ROLE_DECODE, ROLE_MIXED),
                               handoff=HandoffPolicy(interval=0.05,
                                                     max_decode_tokens=4))
        req = self._resident_on(sims[0], rid="hand")
        # ship the first-decode request prefill -> decode (§15)
        assert router._move_request("hand", 0, 1, now=0.1, kind="handoff")
        assert router.has_in_transit
        dst_ord = router._in_transit[0][2]
        assert dst_ord == router.replica_ids[1]
        router.start_drain(1, now=0.1)
        assert not router._try_retire(dst_ord, 0.1)
        assert router._index_of(dst_ord) is not None
        router.control_tick(10.0)
        st = router.autoscale_stats
        assert st.rehomed == 1
        # re-homed to the only serving decode-capable replica: the mixed one
        assert req in sims[2].sched.running_decode or any(
            r.request_id == "hand" for r in sims[2].sched.waiting)
        assert sims[1] in router.retired
        # moved exactly once per plane: the handoff happened, then the
        # re-home redirected the same delivery — no second export
        assert router.disagg_stats.handoffs == 1
        router.check_invariants(expected_rids=["hand"])

    def test_drain_of_prefix_adopted_head_is_plain_steal(self):
        """Satellite regression (§13 x §16): a waiting request whose block
        table is an adopted prefix head drains as a steal — the head is
        released at the source (no page leak at retire), no KV crosses the
        wire, and the destination re-admits from scratch."""
        sims = [make_sim(caching=True, pages=256), make_sim(caching=True,
                                                            pages=256)]
        router = ReplicaRouter(sims, policy="balanced")
        src = sims[0].sched
        shared = list(range(16))
        warm = Request("warm", shared + [77],
                       SamplingParams(max_new_tokens=2))
        src.add_request(warm)
        sims[0].run_until(0.5)
        assert warm.is_finished
        victim = Request("victim", shared + [90, 91, 92],
                         SamplingParams(max_new_tokens=3))
        cached, pages = src.kv.match_prefix(victim.effective_prompt[:-1])
        assert cached == len(shared)
        src.kv.adopt_prefix("victim", cached, pages)
        victim.num_prefilled = cached
        src.waiting.append(victim)

        router.start_drain(0, now=0.5)
        router.control_tick(0.6)
        assert router.rebalance_stats.stolen == 1
        assert router.rebalance_stats.migrated == 0  # no KV on the wire
        assert victim in sims[1].sched.waiting
        assert victim.num_prefilled == 0
        assert sims[0] in router.retired
        # the adopted head was released at drain: no page pinned to the rid
        assert not sims[0].sched.kv.has_request("victim")
        router.check_invariants(expected_rids=["victim"])


# ---------------------------------------------------------------------------
# ordinal-keyed accounting across fleet-size changes
# ---------------------------------------------------------------------------

class TestElasticAccounting:
    def test_routed_counts_survive_add_and_retire(self):
        """Regression: per-replica counters are keyed by ordinal, so a
        retire must shift positions without reassigning history."""
        cluster = elastic_cluster(2)
        router = cluster.router
        for _ in range(6):
            router.select(16)
        before = dict(zip(router.replica_ids, router.routed_counts))
        new_i = router.add_replica(now=0.0)
        assert router.routed_counts[new_i] == 0
        router.start_drain(0, now=0.0)
        router.control_tick(0.1)   # empty victim retires immediately
        assert len(router.replicas) == 2
        after = dict(zip(router.replica_ids, router.routed_counts))
        for ordinal, count in after.items():
            assert count == before.get(ordinal, 0)

    def test_stats_and_scores_tolerate_fleet_changes_between_passes(self):
        """Regression: `scores`/`_calibrate` must not assume the fleet size
        they saw last pass — every per-replica list is rebuilt per call and
        keyed bookkeeping follows the ordinal."""
        from repro.runtime.router import RebalancePolicy
        sims = [make_sim() for _ in range(3)]
        router = ReplicaRouter(sims, rebalance=RebalancePolicy(interval=0.1),
                               replica_factory=lambda o: make_sim())
        sims[0].inject_request(0.0, [1] * 32, 4)
        sims[0].run(1.0)
        router.control_tick(0.1)    # calibration pass at fleet size 3
        router.add_replica(now=0.2)
        router.start_drain(0, now=0.2)
        router.control_tick(0.3)    # pass across add + retire
        assert len(router.scores(16)) == len(router.replicas) == 3
        assert len(router._caps_eff) == len(router.replicas)
        router.control_tick(0.4)
        assert router.rebalance_stats.passes >= 2

    def test_finished_history_survives_retirement(self):
        cluster, router = TestDrain()._loaded_cluster()
        router.start_drain(0, now=0.05)
        cluster.drain()
        assert router.autoscale_stats.retired == 1
        assert len(cluster.finished) == 6  # includes work the victim did

    def test_server_stats_expose_live_fleet_ordinals(self):
        """The stats surface stays position-aligned with the live fleet and
        names each row's stable ordinal, so consumers can join counters
        across scale events (retired ordinals leave the list, newborns get
        fresh ones)."""
        from repro.serving import ClusterSpec, SamplingParams, ServeSpec, \
            SimSpec, build
        srv = build(ServeSpec(
            backend="sim",
            sim=SimSpec(pp=2, pages=256, page_size=8),
            cluster=ClusterSpec(replicas=1, autoscale=AutoscalePolicy(
                interval=0.05, max_replicas=4, target_queue=2.0,
                up_cooldown=0.1, down_cooldown=0.5))))
        try:
            for i in range(40):
                srv.submit([100 + i] * 64,
                           SamplingParams(max_new_tokens=64))
            srv.drain()
            s = srv.stats()
            assert s.autoscale is not None
            assert s.autoscale.replicas_added > 0
            assert (len(s.replica_ordinals) == len(s.replicas)
                    == len(s.routed_counts))
            assert len(set(s.replica_ordinals)) == len(s.replica_ordinals)
            assert s.fleet_size + s.draining == len(s.replicas)
            if s.autoscale.retired:
                # retired ordinals are gone from the live view but their
                # work is not: total placements still cover every request
                assert s.retired == s.autoscale.retired
            from repro.serving.http import stats_to_json
            doc = stats_to_json(s)
            assert doc["replica_ordinals"] == list(s.replica_ordinals)
        finally:
            srv.close()


# ---------------------------------------------------------------------------
# the auditor has teeth: a broken drain must be caught
# ---------------------------------------------------------------------------

class TestAuditorTeeth:
    def test_lossy_drain_is_caught(self, monkeypatch):
        """Deliberately break `_drain_move` to drop requests on the floor
        (drain from the source, never deliver): `check_invariants` with the
        submitted rid set must fail with "lost". If this test ever passes
        silently the whole chaos layer is decorative."""
        cluster, router = TestDrain()._loaded_cluster()
        rids = alive_rids(router)

        def lossy(victim_i, dst_i, req, now):
            sched = router.replicas[victim_i].scheduler
            drained = sched.drain_request(req.request_id)
            if drained is None:
                return False
            if sched.kv.has_request(req.request_id):
                sched.kv.free(req.request_id)
            return True     # "moved" — but nobody received it

        monkeypatch.setattr(router, "_drain_move", lossy)
        router.start_drain(0, now=0.05)
        router.control_tick(0.1)
        assert router.autoscale_stats.drain_moves > 0
        with pytest.raises(AssertionError, match="lost"):
            router.check_invariants(expected_rids=rids)

    def test_duplicating_drain_is_caught(self):
        """A drain that delivers without removing from the source leaves
        the rid alive in two schedulers — the other failure mode the
        auditor must see."""
        sims = [make_sim(), make_sim()]
        router = ReplicaRouter(sims)
        req = Request("dup", [1] * 16, SamplingParams(max_new_tokens=2))
        sims[0].sched.add_request(req)
        sims[1].sched.adopt_request(
            Request("dup", [1] * 16, SamplingParams(max_new_tokens=2)))
        with pytest.raises(AssertionError, match="both"):
            router.check_invariants()


# ---------------------------------------------------------------------------
# recording: elastic runs replay; old traces still load
# ---------------------------------------------------------------------------

class TestElasticTraces:
    def test_strict_replay_through_scale_records(self, tmp_path):
        d = str(tmp_path / "elastic")
        cluster = elastic_cluster(1, trace_dir=d)
        cluster.run(flash_crowd(40), until=120.0)
        st = cluster.router.autoscale_stats
        assert st.replicas_added > 0 and st.retired > 0
        for s in cluster.sims:
            if s.recorder is not None:
                s.recorder.close()
        cluster.router.close_trace()
        names = sorted(n for n in os.listdir(d) if n.startswith("replica"))
        assert len(names) == 1 + st.replicas_added
        saw_scale = 0
        for name in names:
            path = os.path.join(d, name)
            kinds = [json.loads(l)["kind"] for l in open(path)]
            saw_scale += sum(k in ("scale_up", "drain", "retire")
                             for k in kinds)
            check_trace(path)   # raises on any byte divergence
        assert saw_scale >= st.replicas_added + 2 * st.retired

    def test_newborn_stream_opens_with_scale_up_and_retires_closed(
            self, tmp_path):
        d = str(tmp_path / "elastic")
        cluster = elastic_cluster(1, trace_dir=d)
        cluster.run(flash_crowd(40), until=120.0)
        router = cluster.router
        assert router.retired, "test needs at least one retirement"
        # a retired newborn's stream: header, scale_up first, retire last
        for n in sorted(os.listdir(d)):
            if not n.startswith("replica") or n == "replica0.trace.jsonl":
                continue
            recs = [json.loads(l) for l in open(os.path.join(d, n))]
            assert recs[0]["kind"] == "header"
            assert recs[1]["kind"] == "scale_up"
            if any(r["kind"] == "retire" for r in recs):
                assert recs[-1]["kind"] == "retire"

    def test_pre_16_traces_still_load(self):
        fixture = os.path.join(os.path.dirname(__file__), "fixtures",
                               "traces", "prefill_heavy.trace.jsonl")
        lines = open(fixture).read().splitlines()
        header = json.loads(lines[0])
        header["version"] = [SCHEMA_MAJOR, 5]
        old = "\n".join([json.dumps(header)] + lines[1:])
        trace = Trace.loads(old)    # no scale records, older minor: fine
        assert trace.header["version"] == [SCHEMA_MAJOR, 5]
        replay_trace(trace)

    def test_scale_event_validates_kind(self, tmp_path):
        sim = make_sim()
        sim.attach_trace(str(tmp_path / "t.jsonl"))
        with pytest.raises(ValueError, match="unknown scale event"):
            sim.recorder.record_scale_event("shrink", 0.0)


# ---------------------------------------------------------------------------
# workload generators for the elastic benchmarks
# ---------------------------------------------------------------------------

class TestElasticWorkloads:
    def test_diurnal_rate_tracks_the_sinusoid(self):
        reqs = diurnal_requests(200.0, base_rate=1.0, peak_rate=20.0,
                                seed=3)
        trough = sum(1 for t, _, _ in reqs if t < 50.0)
        peak = sum(1 for t, _, _ in reqs if 75.0 <= t < 125.0)
        assert peak > 3 * max(trough, 1)
        assert all(0 <= t < 200.0 for t, _, _ in reqs)

    def test_flash_crowd_concentrates_in_the_spike(self):
        reqs = flash_crowd_requests(30.0, base_rate=1.0, spike_rate=30.0,
                                    spike_start=10.0, spike_len=5.0, seed=3)
        inside = sum(1 for t, _, _ in reqs if 10.0 <= t < 15.0)
        assert inside > len(reqs) * 0.6

    def test_generators_are_deterministic(self):
        a = diurnal_requests(50.0, base_rate=2.0, peak_rate=8.0, seed=7)
        b = diurnal_requests(50.0, base_rate=2.0, peak_rate=8.0, seed=7)
        assert a == b

    def test_rate_bounds_validated(self):
        with pytest.raises(ValueError):
            diurnal_requests(10.0, base_rate=5.0, peak_rate=1.0)
        with pytest.raises(ValueError):
            flash_crowd_requests(10.0, base_rate=5.0, spike_rate=1.0,
                                 spike_start=1.0, spike_len=1.0)
