"""Roofline plumbing: HLO collective parsing, term math, mesh derivation."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.roofline.analysis import (
    HBM_BW,
    PEAK_FLOPS,
    RooflineCell,
    model_flops,
    param_count,
    parse_collective_bytes,
)

HLO_SAMPLE = """
HloModule jit_f
ENTRY main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ar = bf16[128,256]{1,0} all-reduce(bf16[128,256]{1,0} %p0), to_apply=%add
  %ag = f32[64]{0} all-gather(f32[16]{0} %x), dimensions={0}
  %cp-start = bf16[8,128]{1,0} collective-permute-start(bf16[8,128]{1,0} %y)
  %cp = bf16[8,128]{1,0} collective-permute-done(%cp-start)
  %a2a = f32[4,32]{1,0} all-to-all(f32[4,32]{1,0} %z), dimensions={0}
  %rs = f32[8]{0} reduce-scatter(f32[32]{0} %w), dimensions={0}
}
"""


class TestCollectiveParse:
    def test_counts_each_kind(self):
        got = parse_collective_bytes(HLO_SAMPLE)
        assert got["all-reduce"] == 128 * 256 * 2
        assert got["all-gather"] == 16 * 4           # operand, not result
        assert got["collective-permute"] == 8 * 128 * 2
        assert got["all-to-all"] == 4 * 32 * 4
        assert got["reduce-scatter"] == 32 * 4

    def test_done_ops_not_double_counted(self):
        got = parse_collective_bytes(HLO_SAMPLE)
        # only the -start line carries the permute payload
        assert got["collective-permute"] == 8 * 128 * 2


class TestCellMath:
    def _cell(self, flops, bytes_, coll):
        return RooflineCell(
            arch="x", shape="train_4k", mesh="16x16", chips=256,
            hlo_flops=flops, hlo_bytes=bytes_, collective_bytes=coll,
            collective_breakdown={}, model_flops_per_chip=flops * 0.8,
            per_device_memory_bytes=1e9)

    def test_terms_and_bottleneck(self):
        c = self._cell(1e12, 1e9, 1e8)
        assert c.t_compute == pytest.approx(1e12 / PEAK_FLOPS)
        assert c.t_memory == pytest.approx(1e9 / HBM_BW)
        assert c.bottleneck == "compute"
        c2 = self._cell(1e10, 1e11, 1e8)
        assert c2.bottleneck == "memory"
        c3 = self._cell(1e9, 1e6, 1e10)
        assert c3.bottleneck == "collective"

    def test_roofline_fraction(self):
        c = self._cell(1e12, 1.0, 1.0)       # pure compute-bound
        assert c.roofline_fraction == pytest.approx(0.8)
        assert c.useful_ratio == pytest.approx(0.8)


class TestParamCounts:
    @pytest.mark.parametrize("arch,lo,hi", [
        ("qwen2.5-14b", 12e9, 17e9),
        ("qwen1.5-0.5b", 0.3e9, 0.8e9),
        ("internlm2-1.8b", 1.2e9, 2.5e9),
        ("olmoe-1b-7b", 5e9, 9e9),
        ("kimi-k2-1t-a32b", 0.7e12, 1.3e12),
        ("jamba-1.5-large-398b", 280e9, 480e9),
        ("rwkv6-3b", 2e9, 4.5e9),
        ("minicpm3-4b", 2.5e9, 5.5e9),
        ("qwen2-vl-7b", 6e9, 10e9),
        ("whisper-small", 0.15e9, 0.5e9),
    ])
    def test_total_params_near_published(self, arch, lo, hi):
        n = param_count(get_config(arch))
        assert lo < n < hi, f"{arch}: {n/1e9:.1f}B"

    def test_moe_active_far_below_total(self):
        cfg = get_config("kimi-k2-1t-a32b")
        total = param_count(cfg)
        active = param_count(cfg, active_only=True)
        assert active < total / 10           # 1T total vs ~32B active
        assert 15e9 < active < 60e9

    def test_model_flops_scales_with_tokens(self):
        from repro.configs import ASSIGNED_SHAPES
        cfg = get_config("qwen2.5-14b")
        tr = model_flops(cfg, ASSIGNED_SHAPES["train_4k"], 256, "train")
        de = model_flops(cfg, ASSIGNED_SHAPES["decode_32k"], 256, "decode")
        assert tr > de * 100                 # 1M tokens vs one tick


class TestMeshDerivation:
    def test_factoring_preserves_devices(self):
        from repro.launch.mesh import derive_pipeline_mesh

        devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)

        class FakeMesh:
            devices = devs
            axis_names = ("data", "model")

        # derive requires pp*tp == model axis
        with pytest.raises(ValueError):
            derive_pipeline_mesh(FakeMesh, 3, 2)
