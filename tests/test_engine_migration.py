"""Engine-level live migration (DESIGN.md §9): a request migrated between
two `JaxBackend` replicas mid-decode must produce *exactly* the greedy
tokens of a dense full-recompute reference — migration, like scheduling,
must never change outputs (the paper's Table 1 claim extended across the
replica boundary).

Also pins the device-side transfer itself: KV pages gathered at the source
slots are bit-identical to the destination cache contents at the re-mapped
slots after import.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, make_reduced
from repro.core import SamplingParams, ThrottleConfig
from repro.jax_compat import ensure_jax_compat
from repro.models import transformer as tfm
from repro.models.reference import greedy_generate
from repro.models.serve import ServeDims
from repro.runtime.engine import PipelineEngine
from repro.runtime.router import ReplicaRouter

ensure_jax_compat()   # jax may be imported after repro in combined runs


def build_pair(arch="qwen1.5-0.5b", *, pages=256, page=8):
    """Two engine replicas sharing one read-only parameter tree (the
    launcher's --replicas topology), plus the config/params for the dense
    reference."""
    cfg = make_reduced(get_config(arch)).with_plan(pp=1, tp=1,
                                                   ep_over_data=False)
    cf = float(max(cfg.num_experts, 1))
    cfg = dataclasses.replace(cfg, dtype="float32", moe_capacity_factor=cf)
    mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    dims = ServeDims(Sp=1, C=16, Sd=8, pages=pages, page=page, Bp=32, Bd=32,
                     slots=16, Te=0)
    th = ThrottleConfig(pipeline_depth=1, max_prefill_tokens=16,
                        min_prefill_tokens=4, num_iters_T=2)
    with jax.set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, tfm.param_pspecs(cfg),
            is_leaf=lambda x: isinstance(x, P))
        engines = [PipelineEngine(cfg, dims, params, mesh, th)
                   for _ in range(2)]
    return cfg, params, engines


@pytest.fixture(scope="module")
def pair():
    return build_pair()


def test_migrated_request_matches_dense_reference(pair):
    cfg, params, (eng_a, eng_b) = pair
    router = ReplicaRouter([eng_a, eng_b])
    rng = np.random.default_rng(3)
    prompt = list(rng.integers(0, cfg.vocab_size, 21))
    max_new = 8

    req = eng_a.add_request(prompt, SamplingParams(max_new_tokens=max_new))
    # decode a few tokens on A, then live-migrate to B (pp=1: the ring
    # drains every tick, so the request is always drainable between steps)
    for _ in range(200):
        eng_a.step()
        if req.num_output_tokens >= 3:
            break
    assert 0 < req.num_output_tokens < max_new
    out_before = list(req.output_token_ids)

    assert router.migrate_request(req.request_id, 0, 1)
    assert not eng_a.scheduler.kv.has_request(req.request_id)
    assert eng_b.scheduler.kv.has_request(req.request_id)
    assert req.request_id not in eng_a.slots.owner

    eng_b.drain(max_ticks=300)
    assert req.is_finished
    assert req.output_token_ids[:len(out_before)] == out_before
    want = greedy_generate(cfg, params, prompt, max_new)
    assert req.output_token_ids == want, (req.output_token_ids, want)


def test_mid_prefill_handoff_matches_dense_reference(pair):
    """The §15 disagg enabler: a request moved *mid-prefill* (chunk cursor
    and prefilled KV in flight, no decode token yet) must still produce
    exactly the dense reference's greedy tokens after the destination
    finishes the remaining chunks and all of decode."""
    cfg, params, (eng_a, eng_b) = pair
    router = ReplicaRouter([eng_a, eng_b])
    rng = np.random.default_rng(7)
    # several 16-token chunks' worth of prompt (dims.C == 16)
    prompt = list(rng.integers(0, cfg.vocab_size, 45))
    max_new = 6

    req = eng_a.add_request(prompt, SamplingParams(max_new_tokens=max_new))
    moved = False
    for _ in range(200):
        eng_a.step()
        if 0 < req.num_prefilled < req.num_effective_prompt_tokens \
                and req.num_output_tokens == 0:
            # same mechanism the first-decode handoff plane uses
            if router._move_request(req.request_id, 0, 1, kind="handoff"):
                moved = True
                break
    assert moved, "never caught the request between prefill chunks"
    assert router.disagg_stats.handoffs == 1
    assert not eng_a.scheduler.kv.has_request(req.request_id)
    assert eng_b.scheduler.kv.has_request(req.request_id)
    # exactly the prefilled prefix is resident at the destination
    assert eng_b.scheduler.kv.num_tokens(req.request_id) == req.num_prefilled

    eng_b.drain(max_ticks=300)
    assert req.is_finished
    want = greedy_generate(cfg, params, prompt, max_new)
    assert req.output_token_ids == want, (req.output_token_ids, want)


def test_unmigrated_and_migrated_runs_agree(pair):
    """Two identical prompts, one served in place on A, one migrated to B
    mid-decode: token streams must be identical."""
    cfg, params, (eng_a, eng_b) = pair
    router = ReplicaRouter([eng_a, eng_b])
    rng = np.random.default_rng(11)
    prompt = list(rng.integers(0, cfg.vocab_size, 13))
    max_new = 6

    stay = eng_a.add_request(prompt, SamplingParams(max_new_tokens=max_new))
    eng_a.drain(max_ticks=300)
    assert stay.is_finished

    move = eng_a.add_request(prompt, SamplingParams(max_new_tokens=max_new))
    for _ in range(200):
        eng_a.step()
        if move.num_output_tokens >= 2:
            break
    assert router.migrate_request(move.request_id, 0, 1)
    eng_b.drain(max_ticks=300)
    assert move.is_finished
    assert move.output_token_ids == stay.output_token_ids


def test_kv_pages_bit_identical_across_transfer(pair):
    cfg, params, (eng_a, eng_b) = pair
    rng = np.random.default_rng(5)
    prompt = list(rng.integers(0, cfg.vocab_size, 19))
    req = eng_a.add_request(prompt, SamplingParams(max_new_tokens=12))
    for _ in range(200):
        eng_a.step()
        if req.num_output_tokens >= 4:
            break
    rid = req.request_id
    export = eng_a.scheduler.kv.export_kv(rid)
    payload = eng_a.backend.export_kv_pages(rid, export.slots)
    assert payload, "transformer must have paged KV leaves"

    dst_slots = eng_b.scheduler.kv.import_kv(export)
    eng_b.backend.import_kv_pages(rid, payload, dst_slots)
    after = eng_b.backend.export_kv_pages(rid, dst_slots)
    assert set(payload) == set(after)
    for key in payload:
        np.testing.assert_array_equal(np.asarray(payload[key]),
                                      np.asarray(after[key]))
    # cleanup so the module-scoped pair stays reusable
    eng_b.scheduler.kv.free(rid)
    drained = eng_a.scheduler.drain_request(rid)
    assert drained is req
    eng_a.scheduler.kv.free(rid)
    eng_a.backend.finish_request(req)
