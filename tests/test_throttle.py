"""Unit + property tests for Token Throttling (paper eqs. 1-4)."""

import math

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

from repro.core.throttle import (
    PrefillPolicy,
    ThrottleConfig,
    decode_budget,
    prefill_budget,
    prefill_budget_ut,
    prefill_budget_wt,
)

CFG = ThrottleConfig(num_iters_T=8, max_prefill_tokens=2048,
                     min_prefill_tokens=32, kv_threshold=0.05,
                     pipeline_depth=4)


class TestEquations:
    def test_eq1_wt_spreads_over_T(self):
        # 8192 pending over T=8 iterations -> 1024 per batch
        assert prefill_budget_wt(8192, CFG) == 1024

    def test_eq1_clamps(self):
        assert prefill_budget_wt(10, CFG) == 32          # MinP floor
        assert prefill_budget_wt(10**6, CFG) == 2048     # MaxP ceiling
        assert prefill_budget_wt(0, CFG) == 0

    def test_eq2_ut_scales_with_free(self):
        assert prefill_budget_ut(1.0, CFG) == 2048
        assert prefill_budget_ut(0.5, CFG) == 1024
        assert prefill_budget_ut(0.0, CFG) == 32         # MinP floor

    def test_eq3_threshold_suspends_prefill(self):
        # below KV_thresh the system suspends prefill entirely (§3.1.3)
        assert prefill_budget(10000, 0.05, CFG) == 0
        assert prefill_budget(10000, 0.01, CFG) == 0
        assert prefill_budget(10000, 0.06, CFG) > 0

    def test_eq3_combined_min_of_wt_ut(self):
        # WT term: ceil(16000/8) = 2000; UT term at kv_free=0.5:
        # 2048*(0.5-0.05)/0.95 = 970 -> min -> 970
        got = prefill_budget(16000, 0.5, CFG)
        expect = int(min(2000, 2048 * (0.5 - 0.05) / 0.95))
        assert got == expect

    def test_eq4_decode_even_spread(self):
        assert decode_budget(128, CFG) == 32
        assert decode_budget(130, CFG) == math.ceil(130 / 4)
        assert decode_budget(0, CFG) == 0
        assert decode_budget(3, CFG) == 1

    def test_ablation_no_ut_ignores_kv(self):
        cfg = ThrottleConfig(policy=PrefillPolicy.NO_UT)
        # WT-only: KV pressure does not throttle (no threshold either)
        assert prefill_budget(16000, 0.02, cfg) == \
            prefill_budget(16000, 0.9, cfg)

    def test_ablation_no_wt_ignores_backlog(self):
        cfg = ThrottleConfig(policy=PrefillPolicy.NO_WT)
        assert prefill_budget(100000, 0.5, cfg) == \
            prefill_budget(2000, 0.5, cfg)


if HAS_HYPOTHESIS:
    class TestProperties:
        @given(wp=st.integers(0, 10**7), kv=st.floats(0.0, 1.0),
               policy=st.sampled_from([PrefillPolicy.GLLM,
                                       PrefillPolicy.NO_WT,
                                       PrefillPolicy.NO_UT]))
        @settings(max_examples=300, deadline=None)
        def test_budget_bounds(self, wp, kv, policy):
            cfg = ThrottleConfig(policy=policy)
            b = prefill_budget(wp, kv, cfg)
            assert 0 <= b <= cfg.max_prefill_tokens
            assert b <= max(wp, 0)                   # never over-schedule
            if wp == 0:
                assert b == 0
            if policy is not PrefillPolicy.NO_UT and kv <= cfg.kv_threshold:
                assert b == 0                        # threshold safeguard

        @given(wp=st.integers(1, 10**6), kv=st.floats(0.06, 1.0))
        @settings(max_examples=200, deadline=None)
        def test_budget_monotone_in_kv_free(self, wp, kv):
            cfg = ThrottleConfig()
            lo = prefill_budget(wp, kv * 0.9, cfg)
            hi = prefill_budget(wp, kv, cfg)
            assert hi >= lo                          # more free KV, >= budget

        @given(rd=st.integers(0, 10**6), pp=st.integers(1, 64))
        @settings(max_examples=200, deadline=None)
        def test_decode_budget_covers_pool(self, rd, pp):
            cfg = ThrottleConfig(pipeline_depth=pp)
            b = decode_budget(rd, cfg)
            # pp micro-batches at budget b must cover the decode pool exactly
            assert b * pp >= rd
            assert rd == 0 or b * pp < rd + pp       # and without waste > pp
else:
    # fallback spot-checks without hypothesis (requirements-dev.txt)
    @pytest.mark.parametrize("wp,kv", [(0, 0.5), (1000, 0.0), (10**6, 1.0),
                                       (5000, 0.3)])
    def test_budget_bounds(wp, kv):
        for policy in (PrefillPolicy.GLLM, PrefillPolicy.NO_WT,
                       PrefillPolicy.NO_UT):
            cfg = ThrottleConfig(policy=policy)
            b = prefill_budget(wp, kv, cfg)
            assert 0 <= b <= cfg.max_prefill_tokens
            assert b <= max(wp, 0)
            if wp == 0:
                assert b == 0
            if policy is not PrefillPolicy.NO_UT and kv <= cfg.kv_threshold:
                assert b == 0

    @pytest.mark.parametrize("wp,kv", [(100, 0.2), (10**5, 0.8), (777, 0.06)])
    def test_budget_monotone_in_kv_free(wp, kv):
        cfg = ThrottleConfig()
        assert prefill_budget(wp, kv, cfg) >= prefill_budget(wp, kv * 0.9, cfg)

    @pytest.mark.parametrize("rd,pp", [(0, 4), (1, 8), (129, 4), (10**5, 64)])
    def test_decode_budget_covers_pool(rd, pp):
        cfg = ThrottleConfig(pipeline_depth=pp)
        b = decode_budget(rd, cfg)
        assert b * pp >= rd
        assert rd == 0 or b * pp < rd + pp


class TestConfigValidation:
    def test_invalid_configs_rejected(self):
        with pytest.raises(ValueError):
            ThrottleConfig(kv_threshold=1.5)
        with pytest.raises(ValueError):
            ThrottleConfig(num_iters_T=0)
        with pytest.raises(ValueError):
            ThrottleConfig(min_prefill_tokens=100, max_prefill_tokens=10)
