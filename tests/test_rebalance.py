"""Control-plane rebalance smoke (benchmarks/fig_rebalance.py — the
`make rebalance-check` CI gate, exercised in-process).

Deterministic seeds, sized to run fast: on the discovery-only straggler
cluster, periodic steal+migrate must beat admission-only routing on p95
TTFT, and on the tight-KV-pool variant live migration must actually fire.
These are regression tests on the control-plane policy (the sim replays
exactly), not statistical claims.
"""

import pytest

from benchmarks.fig_rebalance import check, run_cluster
from repro.runtime.router import RebalancePolicy


def test_steal_plus_migrate_beats_admission_only_p95():
    adm = run_cluster("admission", 45.0, num_requests=150, seed=0)
    smg = run_cluster("steal+mig", 45.0, num_requests=150, seed=0)
    assert len(adm.finished) == len(smg.finished) == 150
    assert smg.ttft_quantile(0.95) < adm.ttft_quantile(0.95)
    rs = smg.router.rebalance_stats
    assert rs.passes > 0 and rs.stolen + rs.migrated > 0


def test_tight_pool_exercises_live_migration():
    adm = run_cluster("admission", 90.0, pages=1536, num_requests=150,
                      seed=0)
    smg = run_cluster("steal+mig", 90.0, pages=1536, num_requests=150,
                      seed=0)
    rs = smg.router.rebalance_stats
    assert rs.migrated > 0 and rs.migrated_tokens > 0
    assert rs.migration_fallbacks == 0
    assert smg.ttft_quantile(0.95) < adm.ttft_quantile(0.95)


def test_ci_gate_passes():
    assert check()


def test_steal_only_policy_never_migrates():
    c = run_cluster("steal", 60.0, pages=2048, num_requests=100, seed=0)
    rs = c.router.rebalance_stats
    assert rs.migrated == 0


def test_rebalance_policy_defaults_are_sane():
    pol = RebalancePolicy()
    assert pol.migrate_trigger_ratio >= pol.trigger_ratio
    assert pol.interval > 0 and pol.max_request_migrations >= 1
