"""Bucket ladder properties (models/serve.py, DESIGN.md §12).

`bucket_ladder` builds the static ladder of serve shapes; `select_bucket`
picks the smallest entry covering a tick.  These are pure shape functions
(no jax execution), so the properties are checked exhaustively over the
reachable need-space and — when hypothesis is installed — over random
geometries too.  The engine-level contract (zero recompiles after warmup)
lives in tests/test_async_dispatch.py.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

from repro.models.serve import ServeDims, bucket_ladder, select_bucket


def make_dims(Sp=1, C=16, Sd=8):
    return ServeDims(Sp=Sp, C=C, Sd=Sd, pages=256, page=8, Bp=32, Bd=32,
                     slots=16)


def check_ladder(dims):
    ladder = bucket_ladder(dims)
    assert dims in ladder, "full shape must be servable"
    keys = [(b.Sp, b.C, b.Sd) for b in ladder]
    assert len(set(keys)) == len(keys), "ladder entries must be distinct"
    for b in ladder:
        assert not (b.Sp == 0 and b.Sd == 0), "empty shape is not a bucket"
        assert b.Sp in (0, dims.Sp) and 0 < b.C <= dims.C
        assert 0 <= b.Sd <= dims.Sd
        # one KV pool / carry / param tree serves the whole ladder
        assert (b.pages, b.page, b.Bp, b.Bd, b.slots, b.Te) == \
            (dims.pages, dims.page, dims.Bp, dims.Bd, dims.slots, dims.Te)
    return ladder


def check_selection(dims, ladder, need_c, need_d):
    b = select_bucket(ladder, need_c, need_d)
    # covers the demand
    assert b.Sd >= need_d
    if need_c > 0:
        assert b.Sp > 0 and b.C >= need_c
    # minimal: no other covering entry pads fewer rows (ties break toward
    # the narrower prefill bucket, then the smaller decode bucket)
    for other in ladder:
        covers = ((need_c == 0 or (other.Sp > 0 and other.C >= need_c))
                  and other.Sd >= need_d)
        if covers:
            assert (b.rows, b.C, b.Sd) <= (other.rows, other.C, other.Sd)


def test_ladder_and_selection_exhaustive_default_cell():
    """Every reachable (need_c, need_d) of the reduced serving cell."""
    dims = make_dims()
    ladder = check_ladder(dims)
    for need_c in range(dims.C + 1):
        for need_d in range(dims.Sd + 1):
            if need_c == 0 and need_d == 0:
                continue        # bubble ticks use the smallest bucket
            check_selection(dims, ladder, need_c, need_d)


def test_decode_only_cell():
    dims = make_dims(Sp=0, Sd=8)
    ladder = check_ladder(dims)
    assert all(b.Sp == 0 for b in ladder)
    for need_d in range(1, dims.Sd + 1):
        check_selection(dims, ladder, 0, need_d)


def test_overdemand_raises():
    dims = make_dims()
    ladder = bucket_ladder(dims)
    with pytest.raises(ValueError, match="no bucket"):
        select_bucket(ladder, dims.C + 1, 0)
    with pytest.raises(ValueError, match="no bucket"):
        select_bucket(ladder, 0, dims.Sd + 1)


def test_tiny_cells_do_not_degenerate():
    """C=1 / Sd=1 collapse the ladder steps onto each other; dedup must
    leave a valid single-entry-per-class ladder."""
    for dims in (make_dims(C=1, Sd=1), make_dims(C=2, Sd=1),
                 make_dims(C=1, Sd=8)):
        ladder = check_ladder(dims)
        check_selection(dims, ladder, dims.C, dims.Sd)


if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(Sp=st.integers(0, 2), C=st.integers(1, 64), Sd=st.integers(0, 32),
           need_c=st.integers(0, 64), need_d=st.integers(0, 32))
    def test_selection_covers_and_is_minimal(Sp, C, Sd, need_c, need_d):
        if Sp == 0 and Sd == 0:
            return              # no servable rows: not a valid cell
        dims = make_dims(Sp=Sp, C=C, Sd=Sd)
        ladder = check_ladder(dims)
        need_c = min(need_c, C) if Sp > 0 else 0
        need_d = min(need_d, Sd)
        if need_c == 0 and need_d == 0:
            return
        check_selection(dims, ladder, need_c, need_d)
