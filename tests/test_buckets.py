"""Bucket ladder properties (models/serve.py, DESIGN.md §12/§14).

`bucket_ladder` builds the static ladder of serve shapes; `select_bucket`
picks the smallest entry covering a tick.  These are pure shape functions
(no jax execution), so the properties are checked exhaustively over the
reachable need-space and — when hypothesis is installed — over random
geometries too.  Besides the token dimensions (C, Sd), the ladder carries a
KV *depth* dimension (Bp/Bd block-table widths, PR 8): depth steps are
multiples of the flash gather granularity, shared across phases, and the
selector must cover the ring-wide pages-in-use demand.  The engine-level
contract (zero recompiles after warmup) lives in tests/test_async_dispatch.py.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

from repro.models.serve import (ServeDims, bucket_ladder, depth_steps,
                                select_bucket)


def make_dims(Sp=1, C=16, Sd=8):
    return ServeDims(Sp=Sp, C=C, Sd=Sd, pages=256, page=8, Bp=32, Bd=32,
                     slots=16)


def check_ladder(dims):
    ladder = bucket_ladder(dims)
    assert dims in ladder, "full shape must be servable"
    keys = [(b.Sp, b.C, b.Sd, b.Bp, b.Bd) for b in ladder]
    assert len(set(keys)) == len(keys), "ladder entries must be distinct"
    bp_steps = depth_steps(dims.Bp)
    bd_steps = depth_steps(dims.Bd)
    for b in ladder:
        assert not (b.Sp == 0 and b.Sd == 0), "empty shape is not a bucket"
        assert b.Sp in (0, dims.Sp) and 0 < b.C <= dims.C
        assert 0 <= b.Sd <= dims.Sd
        # one KV pool / carry / param tree serves the whole ladder
        assert (b.pages, b.page, b.slots, b.Te) == \
            (dims.pages, dims.page, dims.slots, dims.Te)
        # depth buckets come from the declared step ladders; a phase with no
        # rows keeps its full table width (its meta is all-zero anyway)
        assert b.Bp in bp_steps and b.Bd in bd_steps
        if b.Sp == 0:
            assert b.Bp == dims.Bp
        if b.Sd == 0:
            assert b.Bd == dims.Bd
    return ladder


def check_selection(dims, ladder, need_c, need_d, need_bp=0, need_bd=0):
    b = select_bucket(ladder, need_c, need_d, need_bp=need_bp,
                      need_bd=need_bd)
    # covers the demand
    assert b.Sd >= need_d
    if need_c > 0:
        assert b.Sp > 0 and b.C >= need_c and b.Bp >= need_bp
    if need_d > 0:
        assert b.Bd >= need_bd
    # minimal: no other covering entry pads fewer rows (ties break toward
    # the narrower prefill bucket, the smaller decode bucket, then the
    # shallower block tables)
    for other in ladder:
        covers = ((need_c == 0 or (other.Sp > 0 and other.C >= need_c
                                   and other.Bp >= need_bp))
                  and other.Sd >= need_d
                  and (need_d == 0 or other.Bd >= need_bd))
        if covers:
            assert (b.rows, b.C, b.Sd, b.Bp, b.Bd) <= \
                (other.rows, other.C, other.Sd, other.Bp, other.Bd)


def test_depth_steps_shape():
    assert depth_steps(32, pages_per_block=8) == (8, 16, 32)
    assert depth_steps(32, pages_per_block=8, divisors=(1,)) == (32,)
    # ⌈24/4⌉=6 rounds up to the 8-page gather granularity
    assert depth_steps(24, pages_per_block=8) == (8, 16, 24)
    # misaligned full width: no sub-buckets (attention requires divisibility)
    assert depth_steps(30, pages_per_block=8) == (30,)
    assert depth_steps(0, pages_per_block=8) == (0,)


def test_ladder_and_selection_exhaustive_default_cell():
    """Every reachable (need_c, need_d, need_bp, need_bd) of the reduced
    serving cell (depth demands sampled at the step boundaries ±1)."""
    dims = make_dims()
    ladder = check_ladder(dims)
    depth_probes = sorted({0, 1, 7, 8, 9, 15, 16, 17, 31, 32})
    for need_c in range(dims.C + 1):
        for need_d in range(dims.Sd + 1):
            if need_c == 0 and need_d == 0:
                continue        # bubble ticks use the smallest bucket
            check_selection(dims, ladder, need_c, need_d)
            for bp in depth_probes:
                for bd in depth_probes:
                    check_selection(dims, ladder, need_c, need_d,
                                    need_bp=bp if need_c else 0,
                                    need_bd=bd if need_d else 0)


def test_decode_only_cell():
    dims = make_dims(Sp=0, Sd=8)
    ladder = check_ladder(dims)
    assert all(b.Sp == 0 for b in ladder)
    for need_d in range(1, dims.Sd + 1):
        check_selection(dims, ladder, 0, need_d)
        check_selection(dims, ladder, 0, need_d, need_bd=dims.Bd)


def test_overdemand_raises():
    dims = make_dims()
    ladder = bucket_ladder(dims)
    with pytest.raises(ValueError, match="no bucket"):
        select_bucket(ladder, dims.C + 1, 0)
    with pytest.raises(ValueError, match="no bucket"):
        select_bucket(ladder, 0, dims.Sd + 1)
    with pytest.raises(ValueError, match="no bucket"):
        select_bucket(ladder, 1, 0, need_bp=dims.Bp + 1)
    with pytest.raises(ValueError, match="no bucket"):
        select_bucket(ladder, 0, 1, need_bd=dims.Bd + 1)


def test_depth_selection_prefers_shallow_tables():
    """A shallow-context tick must land in a sub-full block-table bucket —
    the whole point of the depth dimension."""
    dims = make_dims()
    ladder = bucket_ladder(dims)
    b = select_bucket(ladder, 0, 4, need_bd=3)
    assert b.Bd == 8            # smallest depth step of Bd=32, ppb=8
    b = select_bucket(ladder, 4, 0, need_bp=9)
    assert b.Bp == 16
    # full-depth demand still lands on the full table
    b = select_bucket(ladder, dims.C, dims.Sd, need_bp=dims.Bp,
                      need_bd=dims.Bd)
    assert (b.Bp, b.Bd) == (dims.Bp, dims.Bd)


def test_tiny_cells_do_not_degenerate():
    """C=1 / Sd=1 collapse the ladder steps onto each other; dedup must
    leave a valid single-entry-per-class ladder."""
    for dims in (make_dims(C=1, Sd=1), make_dims(C=2, Sd=1),
                 make_dims(C=1, Sd=8)):
        ladder = check_ladder(dims)
        check_selection(dims, ladder, dims.C, dims.Sd)


if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(Sp=st.integers(0, 2), C=st.integers(1, 64), Sd=st.integers(0, 32),
           need_c=st.integers(0, 64), need_d=st.integers(0, 32),
           need_bp=st.integers(0, 32), need_bd=st.integers(0, 32))
    def test_selection_covers_and_is_minimal(Sp, C, Sd, need_c, need_d,
                                             need_bp, need_bd):
        if Sp == 0 and Sd == 0:
            return              # no servable rows: not a valid cell
        dims = make_dims(Sp=Sp, C=C, Sd=Sd)
        ladder = check_ladder(dims)
        need_c = min(need_c, C) if Sp > 0 else 0
        need_d = min(need_d, Sd)
        if need_c == 0 and need_d == 0:
            return
        check_selection(dims, ladder, need_c, need_d,
                        need_bp=need_bp if need_c else 0,
                        need_bd=need_bd if need_d else 0)
