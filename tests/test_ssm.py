"""SSM mixers: chunked-state equivalence (the serving-correctness property)
and padding-mask correctness for mamba + rwkv6."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


def mamba_params(key, d, di, ds, dc):
    ks = jax.random.split(key, 8)
    dtr = max(8, d // 16)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di)) * 0.1,
        "conv_w": jax.random.normal(ks[1], (dc, di)) * 0.3,
        "conv_b": jnp.zeros((di,)),
        "x_proj": jax.random.normal(ks[2], (di, dtr + 2 * ds)) * 0.1,
        "dt_proj": jax.random.normal(ks[3], (dtr, di)) * 0.1,
        "dt_bias": jnp.zeros((di,)),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "D": jnp.ones((di,)),
        "out_proj": jax.random.normal(ks[4], (di, d)) * 0.1,
    }


def rwkv_params(key, d, ff, hd):
    ks = jax.random.split(key, 20)
    lora = 16
    z = lambda *s: jnp.zeros(s)
    n = lambda i, *s, sc=0.1: jax.random.normal(ks[i], s) * sc
    return {
        "ln1_g": jnp.ones((d,)), "ln1_b": z(d),
        "ln2_g": jnp.ones((d,)), "ln2_b": z(d),
        "mu_r": n(0, d, sc=0.5), "mu_k": n(1, d, sc=0.5),
        "mu_v": n(2, d, sc=0.5), "mu_g": n(3, d, sc=0.5),
        "mu_w": n(4, d, sc=0.5),
        "w_r": n(5, d, d), "w_k": n(6, d, d), "w_v": n(7, d, d),
        "w_g": n(8, d, d), "w_o": n(9, d, d),
        "w0": jnp.full((d,), -1.0),
        "w_lora_a": n(10, d, lora), "w_lora_b": z(lora, d),
        "u": n(11, d, sc=0.3),
        "ln_x_g": jnp.ones((d,)),
        "cm_mu_k": n(12, d, sc=0.5), "cm_mu_r": n(13, d, sc=0.5),
        "cm_k": n(14, d, ff), "cm_v": n(15, ff, d), "cm_r": n(16, d, d),
    }


class TestMamba:
    def test_chunked_equals_full(self):
        """Running [0:T/2] then [T/2:T] with carried state == one pass."""
        d, di, ds, dc, B, T = 8, 16, 4, 4, 2, 32
        p = mamba_params(jax.random.key(0), d, di, ds, dc)
        x = jax.random.normal(jax.random.key(1), (B, T, d))
        full, _ = ssm.mamba_mixer(x, p, d_state=ds, d_conv=dc)
        st = ssm.mamba_init_state(B, di, ds, dc, jnp.float32)
        h1, st = ssm.mamba_mixer(x[:, : T // 2], p, d_state=ds, d_conv=dc,
                                 state=st)
        h2, _ = ssm.mamba_mixer(x[:, T // 2 :], p, d_state=ds, d_conv=dc,
                                state=st)
        got = jnp.concatenate([h1, h2], axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   atol=1e-4, rtol=1e-3)

    def test_padding_freezes_state(self):
        """A chunk padded beyond chunk_lens must leave state as if only the
        valid rows ran."""
        d, di, ds, dc, B = 8, 16, 4, 4, 1
        p = mamba_params(jax.random.key(0), d, di, ds, dc)
        x = jax.random.normal(jax.random.key(1), (B, 12, d))
        st0 = ssm.mamba_init_state(B, di, ds, dc, jnp.float32)
        # run 8 valid rows via a 12-row padded chunk
        xpad = jnp.concatenate([x[:, :8], jnp.zeros((B, 4, d))], axis=1)
        valid = jnp.arange(12)[None] < 8
        _, st_pad = ssm.mamba_mixer(xpad, p, d_state=ds, d_conv=dc, state=st0,
                                    valid=valid, chunk_lens=jnp.array([8]))
        _, st_exact = ssm.mamba_mixer(x[:, :8], p, d_state=ds, d_conv=dc,
                                      state=st0)
        np.testing.assert_allclose(np.asarray(st_pad.ssm),
                                   np.asarray(st_exact.ssm),
                                   atol=1e-4, rtol=1e-3)


class TestRWKV:
    def test_chunked_equals_full(self):
        d, ff, hd, B, T = 16, 32, 8, 2, 24
        p = rwkv_params(jax.random.key(0), d, ff, hd)
        x = jax.random.normal(jax.random.key(1), (B, T, d))
        full, _ = ssm.rwkv_block(x, p, head_dim=hd, norm_eps=1e-5,
                                 state=ssm.rwkv_init_state(B, d, d // hd, hd,
                                                           jnp.float32))
        st = ssm.rwkv_init_state(B, d, d // hd, hd, jnp.float32)
        h1, st = ssm.rwkv_block(x[:, : T // 2], p, head_dim=hd, norm_eps=1e-5,
                                state=st)
        h2, _ = ssm.rwkv_block(x[:, T // 2 :], p, head_dim=hd, norm_eps=1e-5,
                               state=st)
        got = jnp.concatenate([h1, h2], axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   atol=1e-4, rtol=1e-3)

    def test_decode_steps_equal_scan(self):
        """T one-token decode steps == one length-T pass (serving path)."""
        d, ff, hd, B, T = 16, 32, 8, 1, 6
        p = rwkv_params(jax.random.key(2), d, ff, hd)
        x = jax.random.normal(jax.random.key(3), (B, T, d))
        full, _ = ssm.rwkv_block(
            x, p, head_dim=hd, norm_eps=1e-5,
            state=ssm.rwkv_init_state(B, d, d // hd, hd, jnp.float32))
        st = ssm.rwkv_init_state(B, d, d // hd, hd, jnp.float32)
        outs = []
        for t in range(T):
            o, st = ssm.rwkv_block(x[:, t : t + 1], p, head_dim=hd,
                                   norm_eps=1e-5, state=st)
            outs.append(o)
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   atol=1e-4, rtol=1e-3)

    def test_padding_freezes_state(self):
        d, ff, hd, B = 16, 32, 8, 1
        p = rwkv_params(jax.random.key(0), d, ff, hd)
        x = jax.random.normal(jax.random.key(1), (B, 8, d))
        st0 = ssm.rwkv_init_state(B, d, d // hd, hd, jnp.float32)
        xpad = jnp.concatenate([x[:, :5], jnp.zeros((B, 3, d))], axis=1)
        valid = jnp.arange(8)[None] < 5
        _, st_pad = ssm.rwkv_block(xpad, p, head_dim=hd, norm_eps=1e-5,
                                   state=st0, valid=valid,
                                   chunk_lens=jnp.array([5]))
        _, st_exact = ssm.rwkv_block(x[:, :5], p, head_dim=hd, norm_eps=1e-5,
                                     state=st0)
        np.testing.assert_allclose(np.asarray(st_pad.wkv),
                                   np.asarray(st_exact.wkv),
                                   atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(st_pad.tm_x),
                                   np.asarray(st_exact.tm_x), atol=1e-5)
