"""Engine-level prefix caching (DESIGN.md §13): a request that adopts a
cached prefix — its first chunk resuming at `num_prefilled = cached`
over KV written by an *earlier* request — must produce exactly the greedy
tokens of the dense full-recompute reference.  Rotary positions make this
sharp: the adopted pages must hold the prefix at absolute positions
0..cached-1 or every downstream logit moves.

Also pins the serving-cost claim: adoption rides the existing chunked
prefill path, so the warm-started bucketed engine never recompiles for a
cache hit (`compile_count()` stays flat).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, make_reduced
from repro.core import SamplingParams, ThrottleConfig
from repro.jax_compat import ensure_jax_compat
from repro.models import transformer as tfm
from repro.models.reference import greedy_generate
from repro.models.serve import ServeDims
from repro.runtime.engine import PipelineEngine

ensure_jax_compat()   # jax may be imported after repro in combined runs


def build_engine(arch="qwen1.5-0.5b", *, pages=256, page=8):
    cfg = make_reduced(get_config(arch)).with_plan(pp=1, tp=1,
                                                   ep_over_data=False)
    cf = float(max(cfg.num_experts, 1))
    cfg = dataclasses.replace(cfg, dtype="float32", moe_capacity_factor=cf)
    mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    dims = ServeDims(Sp=1, C=16, Sd=8, pages=pages, page=page, Bp=32, Bd=32,
                     slots=16, Te=0)
    th = ThrottleConfig(pipeline_depth=1, max_prefill_tokens=16,
                        min_prefill_tokens=4, num_iters_T=2)
    with jax.set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, tfm.param_pspecs(cfg),
            is_leaf=lambda x: isinstance(x, P))
        eng = PipelineEngine(cfg, dims, params, mesh, th,
                             enable_prefix_caching=True)
    return cfg, params, eng


@pytest.fixture(scope="module")
def setup():
    return build_engine()


def test_prefix_adopted_request_matches_dense_reference(setup):
    cfg, params, eng = setup
    rng = np.random.default_rng(7)
    shared = list(rng.integers(0, cfg.vocab_size, 24))    # 3 full pages
    tail_a = list(rng.integers(0, cfg.vocab_size, 9))
    tail_b = list(rng.integers(0, cfg.vocab_size, 5))
    max_new = 6

    r1 = eng.add_request(shared + tail_a, SamplingParams(max_new_tokens=max_new))
    eng.drain(max_ticks=500)
    assert r1.is_finished
    want1 = greedy_generate(cfg, params, shared + tail_a, max_new)
    assert r1.output_token_ids == want1, (r1.output_token_ids, want1)

    # r1's full prompt pages are now frozen in the prefix index; the
    # second request's head is served from them with zero recompute
    warm_compiles = eng.backend.compile_count()
    assert eng.scheduler.kv.peek_prefix((shared + tail_b)[:-1]) == 24
    hits_before = eng.scheduler.stats.prefix_hits

    r2 = eng.add_request(shared + tail_b, SamplingParams(max_new_tokens=max_new))
    eng.drain(max_ticks=500)
    assert r2.is_finished
    assert eng.scheduler.stats.prefix_hits == hits_before + 1
    assert eng.scheduler.stats.prefix_tokens_avoided >= 24
    want2 = greedy_generate(cfg, params, shared + tail_b, max_new)
    assert r2.output_token_ids == want2, (r2.output_token_ids, want2)
    # a cache hit is a data-path event, not a shape event: no recompiles
    assert eng.backend.compile_count() == warm_compiles
    eng.scheduler.check_invariants()


def test_identical_prompt_reuses_all_but_last_token(setup):
    cfg, params, eng = setup
    rng = np.random.default_rng(11)
    prompt = list(rng.integers(0, cfg.vocab_size, 32))    # 4 full pages
    max_new = 5

    r1 = eng.add_request(prompt, SamplingParams(max_new_tokens=max_new))
    eng.drain(max_ticks=500)
    avoided_before = eng.scheduler.stats.prefix_tokens_avoided

    # the probe drops the final prompt token (the first chunk must consume
    # it to sample from), so an identical re-ask reuses 3 of 4 pages
    r2 = eng.add_request(list(prompt), SamplingParams(max_new_tokens=max_new))
    eng.drain(max_ticks=500)
    assert r2.is_finished
    assert eng.scheduler.stats.prefix_tokens_avoided == avoided_before + 24
    assert r2.output_token_ids == r1.output_token_ids
    assert r1.output_token_ids == greedy_generate(cfg, params, prompt, max_new)
