"""End-to-end system behaviour: workload -> engine -> metrics, plus the
streaming frontend and engine padding stats (the bubble metric)."""

import asyncio
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, make_reduced
from repro.core import SamplingParams, ThrottleConfig
from repro.models import transformer as tfm
from repro.models.serve import ServeDims
from repro.runtime.engine import PipelineEngine


def make_engine(arch="qwen1.5-0.5b", dims_kw=None, **th_kw):
    cfg = make_reduced(get_config(arch)).with_plan(pp=1, tp=1,
                                                   ep_over_data=False)
    cfg = dataclasses.replace(cfg, dtype="float32")
    mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    dims = ServeDims(**{**dict(Sp=1, C=16, Sd=8, pages=256, page=8, Bp=32,
                               Bd=32, slots=16), **(dims_kw or {})})
    with jax.set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        pspecs = tfm.param_pspecs(cfg)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, pspecs, is_leaf=lambda x: isinstance(x, P))
        th = ThrottleConfig(pipeline_depth=1, max_prefill_tokens=16,
                            min_prefill_tokens=4, num_iters_T=2, **th_kw)
        return cfg, PipelineEngine(cfg, dims, params, mesh, th)


def test_serving_a_workload_end_to_end():
    cfg, eng = make_engine()
    rng = np.random.default_rng(0)
    reqs = [eng.add_request(list(rng.integers(0, cfg.vocab_size,
                                              rng.integers(4, 40))),
                            SamplingParams(max_new_tokens=int(n)))
            for n in rng.integers(1, 8, 12)]
    eng.drain(max_ticks=1200)
    assert all(r.is_finished for r in reqs)
    assert eng.kv.kv_free_rate == 1.0
    assert eng.stats.tokens_out >= sum(r.num_output_tokens for r in reqs)
    # metrics populated
    for r in reqs:
        assert r.metrics.ttft() is not None and r.metrics.ttft() >= 0
        assert r.metrics.e2el() >= r.metrics.ttft()


def test_engine_reports_bucket_padding():
    """Padding stats are the TPU bubble metric Token Throttling minimizes."""
    cfg, eng = make_engine()
    rng = np.random.default_rng(1)
    for _ in range(4):
        eng.add_request(list(rng.integers(0, cfg.vocab_size, 20)),
                        SamplingParams(max_new_tokens=4))
    eng.drain(max_ticks=200)
    total_p = eng.stats.scheduled_prefill + eng.stats.padded_prefill
    assert total_p == eng.stats.ticks * eng.dims.Sp * eng.dims.C
    assert eng.stats.scheduled_prefill == 4 * 20


def test_streaming_frontend_streams_tokens():
    """The decoupled-frontend split (paper §3.3) on a raw engine: LLMServer
    wraps it directly and streams two concurrent requests."""
    from repro.serving import LLMServer
    cfg, eng = make_engine()
    rng = np.random.default_rng(2)
    server = LLMServer(eng)

    async def collect(prompt, n):
        return [d async for d in server.generate_stream(
            prompt, SamplingParams(max_new_tokens=n))]

    async def main():
        return await asyncio.gather(
            collect(list(rng.integers(0, cfg.vocab_size, 9)), 4),
            collect(list(rng.integers(0, cfg.vocab_size, 14)), 3),
        )

    outs = asyncio.run(main())
    toks = [[d.token for d in deltas if d.token is not None]
            for deltas in outs]
    assert len(toks[0]) == 4 and len(toks[1]) == 3
    assert outs[0][-1].finish_reason == "length"


def test_throttling_reduces_padding_variance_vs_sarathi():
    """On this tiny setup, gLLM's scheduled prefill counts are steadier than
    Sarathi's (paper Fig. 1 in miniature)."""
    from repro.core import PrefillPolicy
    stats = {}
    for pol in (PrefillPolicy.GLLM, PrefillPolicy.SARATHI):
        cfg, eng = make_engine(policy=pol)
        rng = np.random.default_rng(3)
        for _ in range(6):
            eng.add_request(list(rng.integers(0, cfg.vocab_size, 30)),
                            SamplingParams(max_new_tokens=6))
        eng.drain(max_ticks=400)
        counts = [c for c in eng.scheduler.stats.scheduled_prefill_tokens
                  if c >= 0]
        busy = [c for c in counts if c > 0]
        stats[pol] = np.std(busy) if busy else 0.0
    assert stats[PrefillPolicy.GLLM] <= stats[PrefillPolicy.SARATHI] + 1e-9


def test_state_slots_released_on_preemption():
    """Regression: state slots are tied to residency.  A preempted request
    (KV pressure, recompute recovery) must release its slot while it waits —
    otherwise waiting requests pin slots and the allocator exhausts."""
    cfg, eng = make_engine(dims_kw=dict(pages=10))
    rng = np.random.default_rng(3)
    reqs = [eng.add_request(list(rng.integers(0, cfg.vocab_size, 16)),
                            SamplingParams(max_new_tokens=18))
            for _ in range(3)]
    steps = 0
    while (eng.has_work or eng.busy) and steps < 900:
        eng.step()
        steps += 1
        waiting = {r.request_id for r in eng.scheduler.waiting}
        leaked = set(eng.slots.owner) & waiting
        assert not leaked, f"preempted requests holding slots: {leaked}"
    assert eng.scheduler.stats.preemptions >= 1, "test needs KV pressure"
    assert all(r.is_finished for r in reqs)
    # every slot back in the pool after the drain
    assert eng.slots.owner == {}
    assert sorted(eng.slots.free) == list(range(eng.dims.slots))


def test_state_slots_released_on_abort_batch():
    """Regression: abort_batch (worker-death recovery) releases the slots of
    the affected in-flight requests."""
    cfg, eng = make_engine()
    r = eng.add_request([1] * 30, SamplingParams(max_new_tokens=4))
    batch = eng.scheduler.schedule(0.0)
    eng.backend.prepare(batch)            # tick metadata assigns the slot
    assert r.request_id in eng.slots.owner
    eng.scheduler.abort_batch(batch.batch_id)
    assert r.request_id not in eng.slots.owner
    assert r in eng.scheduler.waiting
    eng.drain(max_ticks=300)              # recompute completes normally
    assert r.is_finished
    assert eng.slots.owner == {}


def test_temperature_sampling_changes_outputs():
    """temperature>0 draws stochastic tokens; temperature=0 stays greedy."""
    from repro.core import SamplingParams
    outs = {}
    for temp in (0.0, 5.0):
        cfg, eng = make_engine()
        rng = np.random.default_rng(9)
        prompt = list(rng.integers(0, cfg.vocab_size, 15))
        r = eng.add_request(prompt,
                            SamplingParams(max_new_tokens=8,
                                           temperature=temp))
        eng.drain(max_ticks=200)
        assert r.is_finished
        outs[temp] = r.output_token_ids
    from repro.models.reference import greedy_generate
    # greedy path unchanged; hot sampling diverges from it
    assert outs[0.0] != outs[5.0]
