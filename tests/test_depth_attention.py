"""Depth-bucketed paged attention is bit-identical to full-width (PR 8).

The depth bucket cuts the block-table width to the smallest ladder step
covering the pages actually in use; pages past a sequence's context hold no
in-context keys, so every flash update they produce is exactly zero
(NEG_INF scores underflow to p == 0.0 with alpha == 1.0).  That makes
dropping them *bit*-identical — asserted here with exact equality, not
tolerances — for the jnp path, the interpret-mode Pallas kernel (which also
skips dead pages inside the full-width walk), and the MLA path (jnp-only,
checked against a dense oracle too).
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

from repro.kernels.paged_attention import paged_flash_attention
from repro.models.attention import paged_attention, paged_attention_mla
from repro.models.serve import depth_steps

S, H, KH, D, PAGE, B, PPB = 3, 4, 2, 16, 8, 8, 2
KLR, DN, DV, DR = 8, 8, 8, 4


def _case(seed, ctx_max=None, TQ=1):
    """Random q/cache/tables with every row holding real context."""
    rng = np.random.default_rng(seed)
    P = S * B + 2
    ctx_max = ctx_max or B * PAGE
    q = jnp.asarray(rng.normal(size=(S, TQ, H, D)), jnp.float32)
    cache = jnp.asarray(rng.normal(size=(P, PAGE, 2, KH, D)), jnp.float32)
    tables = np.zeros((S, B), np.int32)
    ctx = rng.integers(TQ, ctx_max + 1, S).astype(np.int32)
    for s in range(S):
        live = -(-int(ctx[s]) // PAGE)
        tables[s, :live] = rng.choice(P, live, replace=False)
    qpos = jnp.asarray(ctx[:, None] - TQ + np.arange(TQ)[None, :], jnp.int32)
    return q, cache, jnp.asarray(tables), jnp.asarray(ctx), qpos


def _sliced_width(ctx, steps):
    need = max(-(-int(c) // PAGE) for c in np.asarray(ctx))
    return min(w for w in steps if w >= need)


def test_jnp_depth_slice_bit_identical():
    steps = depth_steps(B, pages_per_block=PPB)
    for seed in range(4):
        q, cache, tables, ctx, qpos = _case(seed, ctx_max=3 * PAGE, TQ=4)
        w = _sliced_width(ctx, steps)
        assert w < B, "case must actually shrink the table"
        full = paged_attention(q, cache, tables, ctx, qpos,
                               pages_per_block=PPB)
        cut = paged_attention(q, cache, tables[:, :w], ctx, qpos,
                              pages_per_block=PPB)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(cut))


def test_pallas_depth_slice_bit_identical():
    for seed in range(3):
        q, cache, tables, ctx, qpos = _case(seed, ctx_max=3 * PAGE)
        need = max(-(-int(c) // PAGE) for c in np.asarray(ctx))
        full = paged_flash_attention(q, cache, tables, ctx, qpos,
                                     interpret=True)
        cut = paged_flash_attention(q, cache, tables[:, :need], ctx, qpos,
                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(cut))


def test_pallas_dead_pages_never_read():
    """Corrupting the KV content *and table entries* of every dead page must
    not change the output: the kernel's clamped index_map never fetches them
    and the pl.when guard never touches their FLOPs."""
    q, cache, tables, ctx, qpos = _case(7, ctx_max=2 * PAGE)
    out_a = paged_flash_attention(q, cache, tables, ctx, qpos, interpret=True)
    cache2 = np.asarray(cache).copy()
    tables2 = np.asarray(tables).copy()
    for s in range(S):
        live = -(-int(ctx[s]) // PAGE)
        for b in range(live, B):
            cache2[tables2[s, b]] = np.nan     # poison the dead page content
            tables2[s, b] = (s + b) % cache2.shape[0]   # and the indirection
    out_b = paged_flash_attention(q, jnp.asarray(cache2),
                                  jnp.asarray(tables2), ctx, qpos,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))


def test_pallas_padded_row_outputs_zeros():
    """A ctx=0 padding row has no live page: the guard skips every update and
    finalize emits exact zeros (previously garbage; never read either way)."""
    q, cache, tables, ctx, qpos = _case(11)
    ctx = jnp.asarray(np.where(np.arange(S) == 0, 0, np.asarray(ctx)),
                      jnp.int32)
    out = np.asarray(paged_flash_attention(q, cache, tables, ctx, qpos,
                                           interpret=True))
    assert np.all(out[0] == 0.0)
    assert np.all(np.isfinite(out))


def _mla_case(seed, TQ=1):
    rng = np.random.default_rng(seed)
    P = S * B + 2
    q = jnp.asarray(rng.normal(size=(S, TQ, H, DN + DR)), jnp.float32)
    cache = jnp.asarray(rng.normal(size=(P, PAGE, KLR + DR)), jnp.float32)
    w_ukv = jnp.asarray(rng.normal(size=(KLR, H * (DN + DV))) * 0.3,
                        jnp.float32)
    tables = np.zeros((S, B), np.int32)
    ctx = rng.integers(TQ, 3 * PAGE + 1, S).astype(np.int32)
    for s in range(S):
        live = -(-int(ctx[s]) // PAGE)
        tables[s, :live] = rng.choice(P, live, replace=False)
    qpos = jnp.asarray(ctx[:, None] - TQ + np.arange(TQ)[None, :], jnp.int32)
    return q, cache, w_ukv, jnp.asarray(tables), jnp.asarray(ctx), qpos


def _mla_dense_ref(q, cache, w_ukv, tables, ctx, qpos):
    """Dense oracle: gather + expand the whole context, plain softmax."""
    q, cache, w_ukv = map(np.asarray, (q, cache, w_ukv))
    tables, ctx, qpos = map(np.asarray, (tables, ctx, qpos))
    S_, TQ = q.shape[:2]
    out = np.zeros((S_, TQ, H, DV), np.float32)
    for s in range(S_):
        lat = cache[tables[s]].reshape(B * PAGE, KLR + DR)
        c_kv, k_rope = lat[:, :KLR], lat[:, KLR:]
        kv = (c_kv @ w_ukv).reshape(B * PAGE, H, DN + DV)
        k = np.concatenate(
            [kv[..., :DN], np.broadcast_to(k_rope[:, None, :],
                                           (B * PAGE, H, DR))], axis=-1)
        v = kv[..., DN:]
        kpos = np.arange(B * PAGE)
        scale = (DN + DR) ** -0.5
        for t in range(TQ):
            mask = (kpos < ctx[s]) & (kpos <= qpos[s, t])
            sc = np.einsum("hd,khd->hk", q[s, t], k) * scale
            sc = np.where(mask[None, :], sc, -np.inf)
            w = np.exp(sc - sc.max(axis=-1, keepdims=True))
            w /= w.sum(axis=-1, keepdims=True)
            out[s, t] = np.einsum("hk,khd->hd", w, v)
    return out


def test_mla_depth_slice_bit_identical_and_matches_dense():
    steps = depth_steps(B, pages_per_block=PPB)
    for seed in range(3):
        q, cache, w_ukv, tables, ctx, qpos = _mla_case(seed, TQ=2)
        w = _sliced_width(ctx, steps)
        assert w < B
        kw = dict(kv_lora_rank=KLR, qk_nope_dim=DN, v_head_dim=DV,
                  pages_per_block=PPB)
        full = paged_attention_mla(q, cache, w_ukv, tables, ctx, qpos, **kw)
        cut = paged_attention_mla(q, cache, w_ukv, tables[:, :w], ctx, qpos,
                                  **kw)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(cut))
        dense = _mla_dense_ref(q, cache, w_ukv, tables, ctx, qpos)
        np.testing.assert_allclose(np.asarray(full), dense, atol=3e-5)


def test_misaligned_width_raises_clear_error():
    q, cache, tables, ctx, qpos = _case(0)
    with pytest.raises(ValueError, match="REPRO_PAGES_PER_BLOCK"):
        paged_attention(q, cache, tables[:, :B - 1], ctx, qpos,
                        pages_per_block=PPB)


if HAS_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           ctx_pages=st.integers(1, B))
    def test_depth_slice_property(seed, ctx_pages):
        """Any slice width covering the live pages gives bit-identical
        outputs on both execution paths (jnp flash scan and interpret-mode
        Pallas), for random contexts and tables."""
        rng = np.random.default_rng(seed)
        q, cache, tables, _, _ = _case(seed)
        ctx = jnp.asarray(
            rng.integers(max((ctx_pages - 1) * PAGE, 1), ctx_pages * PAGE + 1,
                         S), jnp.int32)
        qpos = jnp.asarray(np.asarray(ctx)[:, None] - 1, jnp.int32)
        steps = depth_steps(B, pages_per_block=PPB)
        w = _sliced_width(ctx, steps)
        full = paged_attention(q, cache, tables, ctx, qpos,
                               pages_per_block=PPB)
        cut = paged_attention(q, cache, tables[:, :w], ctx, qpos,
                              pages_per_block=PPB)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(cut))
        need = max(-(-int(c) // PAGE) for c in np.asarray(ctx))
        k_full = paged_flash_attention(q, cache, tables, ctx, qpos,
                                       interpret=True)
        k_cut = paged_flash_attention(q, cache, tables[:, :need], ctx, qpos,
                                      interpret=True)
        np.testing.assert_array_equal(np.asarray(k_full), np.asarray(k_cut))
