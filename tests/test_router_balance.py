"""Asymmetric-heterogeneity routing smoke (benchmarks/fig_router_balance.py).

One deterministic seed per case, sized to finish fast: globally-balanced
routing must beat round-robin on p95 TTFT under every heterogeneity model
the ROADMAP lists — uniformly slower silicon, a straggler stage, a smaller
KV pool, and a deeper pipeline.  The sim is exact-replayable, so these are
regression tests on the router policy, not statistical claims.
"""

import pytest

from benchmarks.fig_router_balance import (
    CASE_DEFAULTS,
    HETERO_CASES,
    make_hetero_pair,
    run_cluster,
)
from repro.configs import get_config

# per-case rate: enough load to stress the weak replica under round-robin
# without over-saturating the whole cluster (where p95 is pure backlog)
CASE_RATES = {"slow": 60.0, "straggler": 45.0, "kv": 60.0, "depth": 60.0}


@pytest.mark.parametrize("hetero", HETERO_CASES)
def test_balanced_beats_round_robin_on_p95_ttft(hetero):
    results = {}
    for policy in ("rr", "balanced"):
        c = run_cluster(policy, CASE_RATES[hetero], hetero=hetero,
                        num_requests=150, seed=0)
        assert len(c.finished) == 150
        results[policy] = c
    bal, rr = results["balanced"], results["rr"]
    assert bal.ttft_quantile(0.95) < rr.ttft_quantile(0.95), hetero
    # and balanced actually moved load relative to the even split
    counts = bal.router.routed_counts
    assert counts[0] != counts[1] or hetero == "depth"


def test_declared_capacities_are_never_diluted_by_discovery():
    """Measured service rates conflate capacity with utilization, so
    discovery refines only the *uniform default* — a fleet with explicit
    capacity hints keeps them verbatim no matter what the EWMAs say."""
    cfg = get_config("qwen2.5-14b")
    from repro.runtime.router import ReplicaRouter
    fast, slow = make_hetero_pair("slow", cfg=cfg, slow_factor=2.5)

    declared = ReplicaRouter([fast, slow], capacities=[1.0, 0.4])
    for sim in (fast, slow):   # plant asymmetric measured rates
        sim.sched.stats.service_rate = 100.0
    slow.sched.stats.service_rate = 10.0
    declared.scores(prompt_tokens=64)
    assert declared._caps_eff == [1.0, 0.4]

    undeclared = ReplicaRouter([fast, slow])
    undeclared.scores(prompt_tokens=64)
    assert undeclared._caps_eff[0] > undeclared._caps_eff[1]


def test_discovery_only_cases_use_no_capacity_hints():
    """`kv` and `depth` wins come purely from the scheduler signals the
    paper's Token Throttling exposes — pin that so the benchmark cannot
    silently start cheating with static hints."""
    for hetero in ("kv", "depth"):
        assert CASE_DEFAULTS[hetero]["capacities"] is None


def test_hetero_pairs_are_actually_asymmetric():
    cfg = get_config("qwen2.5-14b")
    fast, straggled = make_hetero_pair("straggler", cfg=cfg, slow_factor=4.0)
    assert straggled.backend.straggler == (2, 4.0)
    assert fast.backend.straggler == (None, 1.0)
    fast, small_kv = make_hetero_pair("kv", cfg=cfg)
    assert small_kv.sched.kv.num_pages < fast.sched.kv.num_pages
    fast, deep = make_hetero_pair("depth", cfg=cfg)
    assert deep.pp == 2 * fast.pp
    assert deep.sched.cfg.pipeline_depth == 2 * fast.sched.cfg.pipeline_depth
