"""Per-architecture smoke tests (deliverable f): a REDUCED same-family config
runs one forward + one train step on CPU — output shapes right, no NaNs.
The FULL configs are exercised only via the dry-run (abstract, no alloc)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, applicable_shapes, get_config, make_reduced
from repro.distributed.optimizer import adam_init
from repro.distributed.pipeline import build_train_step
from repro.models import transformer as tfm
from repro.models.reference import dense_forward


def _mesh1():
    return jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_smoke(arch):
    cfg = make_reduced(get_config(arch)).with_plan(ep_over_data=False)
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    B, T = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    enc = None
    if cfg.is_encoder_decoder:
        enc = jax.random.normal(jax.random.key(2), (B, 8, cfg.d_model)) * 0.05
    logits = dense_forward(cfg, params, toks, enc_embeds=enc)
    Texp = T + (8 if cfg.is_encoder_decoder else 0)
    assert logits.shape == (B, Texp, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg = make_reduced(get_config(arch)).with_plan(pp=1, tp=1,
                                                   ep_over_data=False)
    cfg = dataclasses.replace(cfg, dtype="float32")
    mesh = _mesh1()
    M, mbg, T = 2, 2, 16
    ew = T // 2 if cfg.is_encoder_decoder else 0
    with jax.set_mesh(mesh):
        step = jax.jit(build_train_step(cfg, mesh, enc_width=ew))
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        pspecs = tfm.param_pspecs(cfg)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, pspecs, is_leaf=lambda x: isinstance(x, P))
        opt = adam_init(params)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (M, mbg, T)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (M, mbg, T)), jnp.int32),
        }
        if cfg.family in ("vlm", "audio"):
            Tv = 4 if cfg.family == "vlm" else ew
            batch["embeds"] = jnp.asarray(
                rng.normal(size=(M, mbg, Tv, cfg.d_model)) * 0.02, jnp.float32)
        p2, opt2, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"])), arch
        assert np.isfinite(float(metrics["gnorm"])), arch
        # params actually moved
        delta = sum(float(jnp.max(jnp.abs(a - b)))
                    for a, b in zip(jax.tree.leaves(params)[:5],
                                    jax.tree.leaves(p2)[:5]))
        assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_assigned_cells_defined(arch):
    """Every arch exposes its assigned shape cells with coherent geometry."""
    from repro.launch.shapes import serve_cell_dims, train_cell_dims

    cfg = get_config(arch)
    assert cfg.plan.pp * cfg.plan.tp == 16       # model axis = 16
    shapes = applicable_shapes(cfg)
    names = {s.name for s in shapes}
    assert {"train_4k", "prefill_32k", "decode_32k"} <= names
    if cfg.family in ("ssm", "hybrid"):
        assert "long_500k" in names              # sub-quadratic archs run 500k
    else:
        assert "long_500k" not in names
    for s in shapes:
        if s.kind == "train":
            dims = train_cell_dims(cfg, s)
            assert dims.M * dims.mbg == s.global_batch
        else:
            d = serve_cell_dims(cfg, s)
            assert d.Bp % 8 == 0 and d.Bd % 8 == 0
            assert d.rows > 0
