"""Checkpoint/restore, async writer, elastic repartition."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, make_reduced
from repro.distributed.elastic import repartition_params, replan
from repro.models import transformer as tfm
from repro.runtime.checkpoint import (
    AsyncCheckpointer,
    restore_checkpoint,
    save_checkpoint,
)


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}
    save_checkpoint(str(tmp_path / "ck"), tree, extra={"step": 7})
    got = restore_checkpoint(str(tmp_path / "ck"), tree)
    for (p1, l1), (p2, l2) in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree_util.tree_flatten_with_path(got)[0]):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        assert np.asarray(l1).dtype == np.asarray(l2).dtype


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer()
    tree = {"w": jnp.full((8, 8), 3.0)}
    for i in range(3):
        ck.submit(str(tmp_path / f"s{i}"), tree, extra={"step": i})
    ck.wait()
    ck.close()
    for i in range(3):
        got = restore_checkpoint(str(tmp_path / f"s{i}"), tree)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))


def test_model_params_roundtrip(tmp_path):
    cfg = make_reduced(get_config("qwen1.5-0.5b"))
    params = tfm.init_params(cfg, jax.random.key(0))
    save_checkpoint(str(tmp_path / "m"), params)
    got = restore_checkpoint(str(tmp_path / "m"), params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_repartition_preserves_logical_layers():
    """pp=2 x R=3 stacked layers -> pp=3 x R=2: same logical layer list."""
    cfg = get_config("qwen1.5-0.5b")          # 24 layers, single-kind pattern
    cfg2 = replan(cfg, new_pp=4, new_tp=4)
    assert cfg2.layers_per_stage * 4 == cfg.layers_per_stage * cfg.plan.pp
    cfg_small = make_reduced(cfg)             # pp=2, repeat=1 -> 2 layers
    import dataclasses
    from repro.configs.base import BlockSpec
    cfg_a = dataclasses.replace(
        cfg_small,
        pattern=(BlockSpec(cfg_small.pattern[0].kind, 2),),
        num_layers=4)                         # pp=2 x 2/stage
    params = tfm.init_params(cfg_a, jax.random.key(0))
    cfg_b = replan(cfg_a, new_pp=4, new_tp=1)
    re = repartition_params(params, cfg_a, cfg_b)
    for k, grp in params["stages"].items():
        for name, arr in grp.items():
            old = np.asarray(arr)
            new = np.asarray(re["stages"][k][name])
            assert new.shape[:2] == (4, 1)
            np.testing.assert_array_equal(
                old.reshape((4,) + old.shape[2:]),
                new.reshape((4,) + new.shape[2:]))


def test_engine_snapshot_restore():
    """Engine restart resumes unfinished requests by recompute."""
    import dataclasses as dc

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import SamplingParams, ThrottleConfig
    from repro.models.serve import ServeDims
    from repro.runtime.engine import PipelineEngine

    mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    cfg = make_reduced(get_config("qwen1.5-0.5b")).with_plan(
        pp=1, tp=1, ep_over_data=False)
    cfg = dc.replace(cfg, dtype="float32")
    dims = ServeDims(Sp=1, C=16, Sd=8, pages=256, page=8, Bp=32, Bd=32,
                     slots=16)
    th = ThrottleConfig(pipeline_depth=1, max_prefill_tokens=16,
                        min_prefill_tokens=4, num_iters_T=2)

    def mk_engine(params):
        with jax.set_mesh(mesh):
            return PipelineEngine(cfg, dims, params, mesh, th)

    params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    pspecs = tfm.param_pspecs(cfg)
    params = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                          params, pspecs, is_leaf=lambda x: isinstance(x, P))
    eng = mk_engine(params)
    rng = np.random.default_rng(0)
    prompt = list(rng.integers(0, cfg.vocab_size, 20))
    r = eng.add_request(prompt, SamplingParams(max_new_tokens=8))
    for _ in range(6):
        eng.step()
    snap = eng.snapshot_state()
    partial = list(r.output_token_ids)

    eng2 = mk_engine(params)                   # "restarted" engine
    PipelineEngine.restore_requests(eng2, snap)
    eng2.drain(max_ticks=300)
    r2 = [q for q in eng2.finished if q.request_id == r.request_id][0]
    assert r2.is_finished
    # recompute preserved the already-emitted prefix
    assert r2.output_token_ids[: len(partial)] == partial
    assert len(r2.output_token_ids) == 8
