"""Cross-request prefix caching and its interaction with migration
(DESIGN.md §13).

Four layers:

  * pool — `peek_prefix` is the router's non-mutating probe: same answer
    as `match_prefix`, zero side effects on refcounts or the LRU;
  * scheduler — admission adopts cached heads (hit/avoided counters), and
    the invariant *a WAITING request never holds KV* is enforced on both
    paths that used to violate it: adopt-then-stall under KV pressure
    (release-on-stall) and drain-for-migration (release-on-drain).  The
    latter is the regression test for the steal-of-adopted-prefix crash:
    before the fix, draining a waiting request with an adopted head
    stranded the source block table and the destination's
    `adopt_request` raised ValueError;
  * control plane — `migrate_request` on such a request degrades to a
    plain steal (no KV shipped, re-match at the destination), and a
    cache-aware `select` routes a shared-prefix request to the replica
    that already holds its head;
  * property — random interleavings of adopt/freeze with abort,
    preemption, steal and migrate keep page accounting balanced on every
    replica after every single operation.

The engine-level bit-identity test (a prefix-adopted request's tokens
equal the dense reference, with no steady-state recompile) lives in
tests/test_engine_prefix.py because it needs jax.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

from repro.configs import get_config
from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    PrefillPolicy,
    Request,
    RequestState,
    SamplingParams,
    ThrottleConfig,
)
from repro.data.workload import multi_turn_requests, shared_prefix_requests
from repro.runtime.router import (
    BalanceWeights,
    RebalancePolicy,
    ReplicaRouter,
    ReplicaSnapshot,
    SimCluster,
    balance_score,
)
from repro.runtime.simulator import PipelineSimulator, cost_model_for

CFG = get_config("qwen2.5-14b")


def make_sched(pages=64, page_size=4, *, caching=True, **kw):
    th = ThrottleConfig(pipeline_depth=3, policy=PrefillPolicy.GLLM)
    kv = PagedKVManager(num_pages=pages, page_size=page_size,
                        enable_prefix_caching=caching)
    return PipelineScheduler(th, kv, max_model_len=pages * page_size, **kw)


def _run_ticks(sched, n, clock_start=0.0):
    now = clock_start
    for _ in range(n):
        batch = sched.schedule(now)
        toks = [7] * sum(1 for s in batch.seqs if s.produces_token)
        sched.complete(batch.batch_id, toks, now)
        now += 1.0
    return now


def _warm(sched, prompt, rid="warm", max_new=1):
    """Run one request to completion so its full prompt pages are frozen
    into the prefix index (and, being finished, sit in the evictable LRU)."""
    req = Request(rid, list(prompt), SamplingParams(max_new_tokens=max_new))
    sched.add_request(req)
    _run_ticks(sched, max_new + 8)
    assert req.is_finished
    return req


# ---------------------------------------------------------------------------
# Pool: peek_prefix
# ---------------------------------------------------------------------------

class TestPeekPrefix:
    def test_peek_matches_match_without_side_effects(self):
        a = make_sched()
        prompt = list(range(10))                     # 2 full pages + 2 loose
        _warm(a, prompt)
        free_before = a.kv.num_free_pages
        assert a.kv.peek_prefix(prompt) == 8
        assert a.kv.peek_prefix(prompt) == 8         # idempotent
        assert a.kv.num_free_pages == free_before    # nothing pinned
        # match_prefix still finds the same pages afterwards: peek bumped
        # no refcounts and evicted nothing
        cached, pages = a.kv.match_prefix(prompt)
        assert cached == 8 and len(pages) == 2
        a.kv.release_pages(pages)
        a.kv.check_invariants()

    def test_peek_partial_chain_and_miss(self):
        a = make_sched()
        prompt = list(range(12))                     # 3 full pages
        _warm(a, prompt)
        assert a.kv.peek_prefix(prompt[:7]) == 4     # one full page only
        assert a.kv.peek_prefix([99] * 12) == 0      # diverges at page 0
        # divergence mid-chain: first page matches, second does not
        assert a.kv.peek_prefix(prompt[:4] + [99] * 8) == 4

    def test_peek_disabled_is_zero(self):
        a = make_sched(caching=False)
        _warm(a, list(range(12)))
        assert a.kv.peek_prefix(list(range(12))) == 0


# ---------------------------------------------------------------------------
# Scheduler: admission adoption + counters
# ---------------------------------------------------------------------------

class TestAdmissionAdoption:
    def test_second_request_skips_cached_prefill(self):
        a = make_sched()
        shared = list(range(16))                     # 4 full pages
        _warm(a, shared)
        req = Request("r2", shared + [90, 91, 92, 93, 94],
                      SamplingParams(max_new_tokens=4))
        a.add_request(req)
        _run_ticks(a, 12)
        assert req.is_finished and req.num_output_tokens == 4
        assert a.stats.prefix_lookups >= 1
        assert a.stats.prefix_hits == 1
        assert a.stats.prefix_tokens_avoided == 16
        # the per-tick series (the trace's optional `cached` field) carries
        # the adoption on exactly one tick
        assert sum(a.stats.cached_prefill_tokens) == 16
        a.check_invariants()

    def test_identical_prompt_leaves_final_token_uncached(self):
        """The probe is effective_prompt[:-1]: the first chunk must still
        consume at least the final prompt token to sample from."""
        a = make_sched()
        shared = list(range(16))
        _warm(a, shared)
        req = Request("r2", list(shared), SamplingParams(max_new_tokens=2))
        a.add_request(req)
        _run_ticks(a, 10)
        assert req.is_finished
        assert a.stats.prefix_tokens_avoided == 12   # 3 of 4 pages
        a.check_invariants()

    def test_caching_off_never_probes(self):
        a = make_sched(caching=False)
        _warm(a, list(range(16)))
        req = Request("r2", list(range(16)), SamplingParams(max_new_tokens=2))
        a.add_request(req)
        _run_ticks(a, 10)
        assert req.is_finished
        assert a.stats.prefix_lookups == 0
        assert a.stats.prefix_hits == 0

    def test_release_on_stall_under_kv_pressure(self):
        """Adopt-then-stall: the chunk allocator has no headroom, so the
        request stays WAITING — and must not keep pinning the adopted head
        under the very KV pressure that stalled it."""
        a = make_sched(pages=6, page_size=4)
        shared = list(range(8))                      # 2 full pages
        _warm(a, shared)                             # -> evictable, hashed
        # pin every plain-free page with a resident decode
        pin = Request("pin", list(range(100, 113)),  # 13 tokens = 4 pages
                      SamplingParams(max_new_tokens=3))
        a.add_request(pin)
        _run_ticks(a, 2)
        assert pin.state is RequestState.DECODING
        assert a.kv.num_free_pages == 2              # just the cached head
        hot = Request("hot", shared + [90, 91, 92, 93],
                      SamplingParams(max_new_tokens=2))
        a.add_request(hot)
        lookups_before = a.stats.prefix_lookups      # warm/pin probed too
        batch = a.schedule(10.0)
        toks = [7] * sum(1 for s in batch.seqs if s.produces_token)
        a.complete(batch.batch_id, toks, 10.0)
        # admission adopted the 8-token head, found no page for the chunk,
        # and released the head instead of stranding it
        assert a.stats.prefix_lookups == lookups_before + 1
        assert a.stats.prefix_hits == 0
        assert hot in a.waiting
        assert not a.kv.has_request("hot")
        assert hot.num_prefilled == 0
        assert a.stats.prefix_tokens_avoided == 0
        # pin finished in that same tick's complete(): all 6 pages are free
        # or evictable again — the released head among them, still hashed
        assert a.kv.num_free_pages == 6
        a.check_invariants()
        # pressure is gone: hot re-matches the head for free
        _run_ticks(a, 20, clock_start=11.0)
        assert hot.is_finished
        assert a.stats.prefix_hits == 1
        a.check_invariants()


# ---------------------------------------------------------------------------
# Regression: stealing a waiting request with an adopted prefix head
# ---------------------------------------------------------------------------

class TestStealOfAdoptedPrefix:
    def _waiting_with_adopted_head(self, a, shared):
        """Construct the pre-fix hazard state directly: a WAITING request
        whose block table is an adopted prefix head (what admission creates
        between match_prefix and its first chunk)."""
        victim = Request("victim", shared + [90, 91, 92, 93, 94],
                         SamplingParams(max_new_tokens=3))
        cached, pages = a.kv.match_prefix(victim.effective_prompt[:-1])
        assert cached == len(shared)
        a.kv.adopt_prefix("victim", cached, pages)
        victim.num_prefilled = cached
        a.waiting.append(victim)
        return victim

    def test_drain_releases_head_and_destination_admits(self):
        a, b = make_sched(), make_sched()
        shared = list(range(16))
        _warm(a, shared)
        free_all = a.kv.num_free_pages
        victim = self._waiting_with_adopted_head(a, shared)

        drained = a.drain_request("victim")
        assert drained is victim
        # before the fix: the block table stayed resident on A (page leak)…
        assert not a.kv.has_request("victim")
        assert a.kv.num_free_pages == free_all
        assert victim.num_prefilled == 0
        # …and this raised ValueError (0 resident tokens vs num_prefilled)
        b.adopt_request(drained)
        assert victim in b.waiting
        assert victim not in b.running_prefill
        a.check_invariants()
        b.check_invariants()
        # the destination re-matches against *its* cache at admission: B is
        # cold, so the request simply prefills from scratch and completes
        _run_ticks(b, 20)
        assert victim.is_finished
        assert b.stats.prefix_hits == 0

    def test_steal_candidates_still_skip_kv_holders(self):
        """Defense in depth: the policy layer keeps preferring requests with
        no resident KV, so adopted heads are stolen only as a last resort."""
        a = make_sched()
        shared = list(range(16))
        _warm(a, shared)
        victim = self._waiting_with_adopted_head(a, shared)
        clean = Request("clean", [1] * 8, SamplingParams(max_new_tokens=2))
        a.add_request(clean)
        cands = a.steal_candidates()
        assert clean in cands and victim not in cands

    def test_migrate_request_degrades_to_steal(self):
        """Control-plane path: `migrate_request` on a waiting request with an
        adopted head ships no KV (release-on-drain makes it a plain steal)
        and the destination queues it through normal admission — not
        `running_prefill`, which would bypass the UT guard."""
        pp = 2
        cost = cost_model_for(CFG, pp=pp)
        sims = [PipelineSimulator(make_sched(pages=256, page_size=4), pp, cost)
                for _ in range(2)]
        router = ReplicaRouter(sims, policy="balanced")
        src = sims[0].sched
        shared = list(range(16))
        _warm(src, shared)
        victim = self._waiting_with_adopted_head(src, shared)

        assert router.migrate_request("victim", 0, 1)
        assert router.rebalance_stats.stolen == 1
        assert router.rebalance_stats.migrated == 0  # no KV crossed the wire
        assert not src.kv.has_request("victim")
        dst = sims[1].sched
        assert victim in dst.waiting and victim not in dst.running_prefill
        assert victim.num_prefilled == 0
        src.check_invariants()
        dst.check_invariants()
        sims[1].drain()
        assert victim.is_finished

    def test_adopt_mid_prefill_keeps_running_prefill_lane(self):
        """A genuinely mid-prefill drain (state PREFILLING, KV resident)
        still resumes in running_prefill — placement follows state, and only
        never-admitted requests re-enter through `waiting`."""
        a = make_sched(max_chunk_tokens=8)
        b = make_sched(max_chunk_tokens=8)
        req = Request("x", list(range(32)), SamplingParams(max_new_tokens=2))
        a.add_request(req)
        _run_ticks(a, 1)
        assert req in a.running_prefill
        assert req.state is RequestState.PREFILLING
        assert 0 < req.num_prefilled < 32
        drained = a.drain_request("x")
        export = a.kv.export_kv("x")
        a.kv.free("x")
        b.kv.import_kv(export)
        b.adopt_request(drained)
        assert req in b.running_prefill and req not in b.waiting
        a.check_invariants()
        b.check_invariants()
        _run_ticks(b, 20)
        assert req.is_finished


# ---------------------------------------------------------------------------
# Cache-aware routing
# ---------------------------------------------------------------------------

class TestCacheAwareRouting:
    def test_balance_score_credits_cached_tokens(self):
        w = BalanceWeights(decode_tokens=0.0)
        cold = ReplicaSnapshot(waiting_prefill_tokens=0, running_decode=0,
                               kv_free_rate=1.0)
        hot = ReplicaSnapshot(waiting_prefill_tokens=0, running_decode=0,
                              kv_free_rate=1.0, cached_prefix_tokens=96)
        assert balance_score(hot, 128, w) < balance_score(cold, 128, w)
        # the credit is clamped at the candidate's own charge: a huge cache
        # hit cannot make the replica look *negatively* loaded
        huge = ReplicaSnapshot(waiting_prefill_tokens=10, running_decode=0,
                               kv_free_rate=1.0, cached_prefix_tokens=10_000)
        assert balance_score(huge, 128, w) == pytest.approx(10.0)
        # cache_affinity=0 disables the term entirely
        w0 = BalanceWeights(decode_tokens=0.0, cache_affinity=0.0)
        assert balance_score(hot, 128, w0) == balance_score(cold, 128, w0)

    def test_select_prefers_replica_holding_the_prefix(self):
        pp = 2
        cost = cost_model_for(CFG, pp=pp)
        sims = [PipelineSimulator(make_sched(pages=256, page_size=4), pp,
                                  cost) for _ in range(2)]
        shared = list(range(32))
        _warm(sims[1].sched, shared)                 # only replica 1 is warm
        prompt = shared + [90, 91, 92, 93]
        router = ReplicaRouter(sims, policy="balanced")
        assert router.select(prompt=prompt) == 1
        # without the prompt there is no probe: the tie falls to replica 0
        assert router.select(len(prompt)) == 0
        # load-only weights ignore the cache and break the tie the same way
        blind = ReplicaRouter(sims, policy="balanced",
                              weights=BalanceWeights(cache_affinity=0.0))
        assert blind.select(prompt=prompt) == 0

    def test_snapshot_probe_mirrors_admission(self):
        pp = 2
        sim = PipelineSimulator(make_sched(pages=256, page_size=4), pp,
                                cost_model_for(CFG, pp=pp))
        shared = list(range(16))
        _warm(sim.sched, shared)
        # identical re-ask: the probe drops the final token, like admission
        snap = ReplicaSnapshot.of(sim, prompt=list(shared))
        assert snap.cached_prefix_tokens == 12
        free_before = sim.sched.kv.num_free_pages
        ReplicaSnapshot.of(sim, prompt=shared + [9, 9, 9])
        assert sim.sched.kv.num_free_pages == free_before  # non-mutating

    def test_cluster_end_to_end_avoids_prefill_and_stays_sound(self):
        """Cache-aware routing on a 2-replica cluster with a rebalancing
        control plane: every request completes, pages balance, and the
        pooled-prefix workload actually reuses cached heads."""
        pp = 2
        cost = cost_model_for(CFG, pp=pp)
        sims = [PipelineSimulator(make_sched(pages=1024, page_size=8), pp,
                                  cost) for _ in range(2)]
        router = ReplicaRouter(sims, policy="balanced",
                               rebalance=RebalancePolicy())
        cluster = SimCluster(sims, router)
        arrivals = shared_prefix_requests(80, 40.0, num_pools=4,
                                          prefix_len=64, seed=3)
        finished = cluster.run(arrivals)
        assert len(finished) == 80
        avoided = sum(s.sched.stats.prefix_tokens_avoided for s in sims)
        assert avoided > 0
        for sim in sims:
            sim.sched.check_invariants()


# ---------------------------------------------------------------------------
# Simulator billing: cached tokens are prefill the replica never does
# ---------------------------------------------------------------------------

class TestSimBilling:
    def _run(self, caching):
        pp = 2
        sched = make_sched(pages=2048, page_size=8, caching=caching)
        sim = PipelineSimulator(sched, pp, cost_model_for(CFG, pp=pp))
        sim.add_workload(shared_prefix_requests(
            60, 200.0, num_pools=2, prefix_len=512, mean_suffix=32.0,
            seed=11))
        sim.run()
        assert len(sim.metrics.finished) == 60
        sched.check_invariants()
        return sim

    def test_caching_shortens_the_run(self):
        cold = self._run(caching=False)
        warm = self._run(caching=True)
        assert cold.sched.stats.prefix_tokens_avoided == 0
        assert warm.sched.stats.prefix_tokens_avoided > 0
        # avoided prefill is avoided virtual time: same workload, same cost
        # model, strictly earlier makespan
        assert warm.backend.time < cold.backend.time


# ---------------------------------------------------------------------------
# Workload generators
# ---------------------------------------------------------------------------

class TestPrefixWorkloads:
    def test_shared_prefix_pools_share_heads(self):
        reqs = shared_prefix_requests(50, 10.0, num_pools=3, prefix_len=64,
                                      seed=5)
        assert len(reqs) == 50
        heads = {tuple(p[:64]) for _, p, _ in reqs}
        assert len(heads) == 3
        times = [t for t, _, _ in reqs]
        assert times == sorted(times)
        assert all(len(p) > 64 and o >= 1 for _, p, o in reqs)

    def test_multi_turn_histories_nest(self):
        reqs = multi_turn_requests(12, 5.0, seed=7)
        assert len(reqs) >= 12
        times = [t for t, _, _ in reqs]
        assert times == sorted(times)
        # group turns by conversation via strict prefix nesting: some
        # conversation has >1 turn, and each later turn extends an earlier
        # prompt (that is what makes the workload prefix-heavy)
        prompts = [tuple(p) for _, p, _ in reqs]
        nested = sum(1 for i, p in enumerate(prompts)
                     for q in prompts[:i] if p[:len(q)] == q and len(p) > len(q))
        assert nested > 0

    def test_generators_are_deterministic(self):
        assert shared_prefix_requests(20, 4.0, seed=9) == \
            shared_prefix_requests(20, 4.0, seed=9)
        assert multi_turn_requests(6, 4.0, seed=9) == \
            multi_turn_requests(6, 4.0, seed=9)


# ---------------------------------------------------------------------------
# Trace schema 1.4: the optional per-tick `cached` field
# ---------------------------------------------------------------------------

class TestTraceSchema14:
    def _record(self, caching):
        import io
        from repro.runtime.simulator import record_sim_trace
        sink = io.StringIO()
        arrivals = shared_prefix_requests(12, 50.0, num_pools=2,
                                          prefix_len=64, seed=2)
        sim = record_sim_trace(sink, arrivals, pp=2, pages=1024, page_size=8,
                               enable_prefix_caching=caching)
        return sim, sink.getvalue()

    def test_cached_recorded_and_strict_replay_is_bit_identical(self):
        from repro.runtime.trace import Trace, replay_trace
        sim, text = self._record(caching=True)
        assert sim.sched.stats.prefix_tokens_avoided > 0
        trace = Trace.loads(text)
        assert tuple(trace.header["version"]) >= (1, 4)
        # present on every tick (uniformly trace-wide), and the series sums
        # to the scheduler's adoption counter
        assert all("cached" in r for r in trace.ticks)
        assert sum(r["cached"] for r in trace.ticks) \
            == sim.sched.stats.prefix_tokens_avoided
        report = replay_trace(trace, record=True)
        assert report.recorded.dumps() == text

    def test_cached_omitted_uniformly_when_caching_off(self):
        from repro.runtime.trace import Trace
        _, text = self._record(caching=False)
        trace = Trace.loads(text)
        assert all("cached" not in r for r in trace.ticks)

    def test_divergent_cached_value_fails_strict_replay(self):
        import copy
        from repro.runtime.trace import Trace, TraceDivergence, replay_trace
        _, text = self._record(caching=True)
        trace = Trace.loads(text)
        bad = Trace(copy.deepcopy(trace.header), copy.deepcopy(trace.records))
        rec = next(r for r in bad.records
                   if r["kind"] == "tick" and r.get("cached"))
        rec["cached"] += 8
        with pytest.raises(TraceDivergence) as ei:
            replay_trace(bad)
        assert any(f == "cached" for f, _, _ in ei.value.diffs)

    def test_compaction_round_trips_cached(self):
        import json
        from repro.runtime.trace import (compact_records, dumps_record,
                                         expand_records)
        _, text = self._record(caching=True)
        records = [json.loads(line) for line in text.splitlines() if line]
        out = [dumps_record(r)
               for r in expand_records(compact_records(records))]
        assert out == [dumps_record(r) for r in records]


# ---------------------------------------------------------------------------
# Property: interleaved prefix ops keep every pool balanced
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    class TestInterleavedOpsProperty:
        @given(data=st.data())
        @settings(max_examples=60, deadline=None)
        def test_invariants_after_every_operation(self, data):
            """Random interleavings of admission (with prefix adoption and
            freezing), ticking, abort, waiting-steal and decode-migration
            across two cache-enabled replicas: page accounting balances on
            both after *every* operation, no request is ever resident on two
            replicas, and everything eventually finishes."""
            scheds = [make_sched(pages=48, page_size=4) for _ in range(2)]
            clocks = [0.0, 0.0]
            pools = [[p * 100 + j for j in range(8)] for p in range(3)]
            reqs = []

            def tick(i):
                batch = scheds[i].schedule(clocks[i])
                toks = [7] * sum(1 for s in batch.seqs if s.produces_token)
                scheds[i].complete(batch.batch_id, toks, clocks[i])
                clocks[i] += 1.0

            n_ops = data.draw(st.integers(8, 30), label="n_ops")
            for step in range(n_ops):
                op = data.draw(st.sampled_from(
                    ["add", "tick", "tick", "abort", "steal", "migrate"]),
                    label=f"op{step}")
                if op == "add" and len(reqs) < 10:
                    i = data.draw(st.integers(0, 1))
                    head = pools[data.draw(st.integers(0, 2))]
                    tail_len = data.draw(st.integers(1, 12))
                    r = Request(f"q{len(reqs)}",
                                head + [7000 + len(reqs)] * tail_len,
                                SamplingParams(max_new_tokens=data.draw(
                                    st.integers(1, 6))))
                    reqs.append(r)
                    scheds[i].add_request(r)
                elif op == "tick":
                    tick(data.draw(st.integers(0, 1)))
                elif op == "abort" and reqs:
                    rid = data.draw(st.sampled_from(
                        [r.request_id for r in reqs]))
                    for i, s in enumerate(scheds):
                        if s.abort_request(rid, clocks[i]) is not None:
                            break
                elif op == "steal":
                    src = data.draw(st.integers(0, 1))
                    dst = 1 - src
                    cands = scheds[src].steal_candidates()
                    if cands:
                        drained = scheds[src].drain_request(
                            cands[-1].request_id)
                        if drained is not None:
                            scheds[dst].adopt_request(drained)
                elif op == "migrate":
                    src = data.draw(st.integers(0, 1))
                    dst = 1 - src
                    moved = False
                    for r in list(scheds[src].running_decode):
                        rid = r.request_id
                        drained = scheds[src].drain_request(rid)
                        if drained is None:
                            continue
                        export = scheds[src].kv.export_kv(rid)
                        if scheds[dst].kv.can_allocate(rid, export.num_tokens):
                            scheds[src].kv.free(rid)
                            scheds[dst].kv.import_kv(export)
                            scheds[dst].adopt_request(drained)
                        else:
                            scheds[src].adopt_request(drained)  # no room: stay
                        moved = True
                        break
                    if not moved:
                        tick(src)
                for s in scheds:
                    s.check_invariants()
                    s.kv.check_invariants()
                ids = [{r.request_id
                        for g in (s.waiting, s.running_prefill,
                                  s.running_decode) for r in g}
                       for s in scheds]
                assert not (ids[0] & ids[1]), "resident on both replicas"

            for _ in range(300):
                if all(r.is_finished for r in reqs):
                    break
                tick(0)
                tick(1)
            assert all(r.is_finished for r in reqs)
            for s in scheds:
                s.check_invariants()
