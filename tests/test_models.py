"""Model-block unit tests: every BlockKind, shapes, finiteness, M-RoPE/MLA."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, make_reduced
from repro.models import transformer as tfm
from repro.models.layers import apply_mrope, apply_rope
from repro.models.attention import causal_attention


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_block_kinds_forward(arch):
    cfg = make_reduced(get_config(arch)).with_plan(ep_over_data=False)
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    aux = jnp.zeros((), jnp.float32)
    for i, bs in enumerate(cfg.pattern):
        p = jax.tree.map(lambda a: a[0, 0],
                         params["stages"][tfm._block_key(i, bs)])
        ew = 8 if cfg.is_encoder_decoder else 0
        x2, aux = tfm.block_apply_train(cfg, bs.kind, p, x, aux, enc_width=ew)
        assert x2.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(x2))), (arch, bs.kind)
        x = x2


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_defs_consistent(arch):
    cfg = get_config(arch)
    defs = tfm.model_param_defs(cfg)
    shapes = tfm.param_shapes(cfg)
    specs = tfm.param_pspecs(cfg)
    is_tup = lambda x: isinstance(x, tuple)
    assert jax.tree.structure(shapes, is_leaf=is_tup) == jax.tree.structure(
        specs, is_leaf=lambda x: hasattr(x, "index") or x is None)
    # stacked stage dims match the plan
    for k, grp in defs["stages"].items():
        for name, (shape, spec, init) in grp.items():
            assert shape[0] == cfg.plan.pp, (k, name)
    # vocab pads evenly over stage x tensor
    assert cfg.padded_vocab % (cfg.plan.pp * cfg.plan.tp) == 0
    assert cfg.padded_vocab >= cfg.vocab_size


def test_mrope_reduces_to_rope_for_text():
    """With identical (t,h,w) position streams, M-RoPE == RoPE."""
    x = jax.random.normal(jax.random.key(0), (2, 8, 4, 32))
    pos = jnp.arange(8)[None, :].repeat(2, 0)
    pos3 = jnp.broadcast_to(pos, (3, 2, 8))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, (4, 6, 6), 1e4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_causal_attention_matches_naive():
    B, T, H, KH, D = 2, 32, 4, 2, 16
    q = jax.random.normal(jax.random.key(0), (B, T, H, D))
    k = jax.random.normal(jax.random.key(1), (B, T, KH, D))
    v = jax.random.normal(jax.random.key(2), (B, T, KH, D))
    out = causal_attention(q, k, v, block_k=8)
    # naive
    G = H // KH
    qf = q.reshape(B, T, KH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k) * D**-0.5
    mask = jnp.tril(jnp.ones((T, T), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, T, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_padded_layers_are_identity():
    """Layers beyond num_layers contribute h + 0 exactly (kimi/minicpm3)."""
    cfg = make_reduced(get_config("kimi-k2-1t-a32b")).with_plan(
        ep_over_data=False)
    cfg = dataclasses.replace(cfg, dtype="float32", num_layers=1)
    # pp=2, 1 block/stage, num_layers=1 => stage-1 layer is padding
    from repro.models.reference import dense_forward
    params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    full = dense_forward(cfg, params, toks)
    # a 1-stage model holding only the first layer's weights must agree
    cfg1 = dataclasses.replace(
        cfg, plan=dataclasses.replace(cfg.plan, pp=1))
    params1 = dict(params, stages=jax.tree.map(lambda a: a[:1],
                                               params["stages"]))
    one = dense_forward(cfg1, params1, toks)
    np.testing.assert_allclose(np.asarray(full), np.asarray(one), atol=1e-5)
