"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.moe_gemm import fused_moe_ffn
from repro.kernels.paged_attention import paged_flash_attention
from repro.kernels.rwkv6_scan import rwkv6_chunked_scan


@pytest.mark.parametrize("S,TQ,H,KH,D,page,B", [
    (2, 1, 4, 2, 64, 8, 4),        # decode, GQA
    (1, 16, 4, 4, 128, 8, 4),      # prefill chunk, MHA
    (3, 8, 8, 2, 64, 16, 8),       # prefill, deep tables
    (2, 1, 8, 8, 128, 8, 8),       # decode, MHA, D=128
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_vs_oracle(S, TQ, H, KH, D, page, B, dtype):
    rng = np.random.default_rng(hash((S, TQ, H, D)) % 2**31)
    P = S * B + 2
    q = jnp.asarray(rng.normal(size=(S, TQ, H, D)), dtype)
    kv = jnp.asarray(rng.normal(size=(P, page, 2, KH, D)), dtype)
    tables = jnp.asarray(rng.permutation(P)[: S * B].reshape(S, B), jnp.int32)
    ctx = jnp.asarray(rng.integers(TQ, B * page + 1, S), jnp.int32)
    qpos = jnp.asarray(ctx[:, None] - TQ + np.arange(TQ)[None, :], jnp.int32)
    out_k = paged_flash_attention(q, kv, tables, ctx, qpos, interpret=True,
                                  q_block=min(8, TQ))
    out_r = ref.paged_flash_attention_ref(q, kv, tables, ctx, qpos)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=tol)


def test_paged_flash_respects_context_len():
    """Tokens beyond context_lens must not contribute (garbage pages)."""
    rng = np.random.default_rng(0)
    S, TQ, H, KH, D, page, B = 1, 1, 2, 2, 64, 8, 4
    q = jnp.asarray(rng.normal(size=(S, TQ, H, D)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(8, page, 2, KH, D)), jnp.float32)
    tables = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    qpos = jnp.asarray([[9]], jnp.int32)
    out_a = paged_flash_attention(q, kv, tables, jnp.asarray([10]), qpos,
                                  interpret=True)
    # corrupt pages beyond ctx=10: output must not change
    kv2 = kv.at[2:].set(1e4)
    out_b = paged_flash_attention(q, kv2, tables, jnp.asarray([10]), qpos,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                               atol=1e-6)


@pytest.mark.parametrize("B,T,H,D,chunk", [
    (2, 64, 2, 32, 16), (1, 128, 4, 64, 64), (1, 32, 2, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_vs_oracle(B, T, H, D, chunk, dtype):
    rng = np.random.default_rng(hash((B, T, H, D)) % 2**31)
    r = jnp.asarray(rng.normal(size=(B, T, H, D)), dtype) * 0.5
    k = jnp.asarray(rng.normal(size=(B, T, H, D)), dtype) * 0.5
    v = jnp.asarray(rng.normal(size=(B, T, H, D)), dtype)
    w = jnp.asarray(rng.uniform(0.8, 0.999, size=(B, T, H, D)), dtype)
    u = jnp.asarray(rng.normal(size=(H, D)), dtype) * 0.3
    out_k = rwkv6_chunked_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    out_r = ref.rwkv6_scan_ref(r, k, v, w, u)
    ref_max = float(jnp.max(jnp.abs(out_r.astype(jnp.float32))))
    tol = (1e-4 if dtype == jnp.float32 else 3e-2) * max(ref_max, 1.0)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=tol)


@pytest.mark.parametrize("E,C,d,ff,tb,fb", [
    (4, 16, 32, 64, 8, 32), (2, 32, 64, 128, 16, 64), (3, 8, 16, 32, 8, 16),
])
def test_fused_moe_vs_oracle(E, C, d, ff, tb, fb):
    rng = np.random.default_rng(hash((E, C, d)) % 2**31)
    x = jnp.asarray(rng.normal(size=(E, C, d)), jnp.float32) * 0.5
    wg = jnp.asarray(rng.normal(size=(E, d, ff)), jnp.float32) * 0.1
    wu = jnp.asarray(rng.normal(size=(E, d, ff)), jnp.float32) * 0.1
    wd = jnp.asarray(rng.normal(size=(E, ff, d)), jnp.float32) * 0.1
    o_k = fused_moe_ffn(x, wg, wu, wd, token_block=tb, ff_block=fb,
                        interpret=True)
    o_r = ref.fused_moe_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-5)


@pytest.mark.parametrize("B,T,di,ds,chunk,cb", [
    (1, 32, 16, 4, 8, 8), (2, 64, 32, 8, 16, 16), (1, 16, 8, 4, 16, 8),
])
def test_mamba_chunked_scan_vs_oracle(B, T, di, ds, chunk, cb):
    from repro.kernels.mamba_scan import mamba_chunked_scan
    rng = np.random.default_rng(hash((B, T, di)) % 2**31)
    dA = jnp.asarray(rng.uniform(0.7, 0.999, (B, T, di, ds)), jnp.float32)
    dBx = jnp.asarray(rng.normal(size=(B, T, di, ds)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.normal(size=(B, T, ds)), jnp.float32)
    got = mamba_chunked_scan(dA, dBx, C, chunk=chunk, channel_block=cb,
                             interpret=True)
    want = ref.mamba_scan_ref(dA, dBx, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)
