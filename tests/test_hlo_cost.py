"""Trip-count-aware HLO cost parser: loop scaling, fusion classification."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import HloCostModel, analyse_hlo_text, parse_hlo

HLO = """
HloModule jit_f

%add_comp (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%fused_convert (p0: bf16[64,128]) -> f32[64,128] {
  %p0 = bf16[64,128]{1,0} parameter(0)
  ROOT %c = f32[64,128]{1,0} convert(%p0)
}

%fused_gather (p0: f32[1000,128], p1: s32[8]) -> f32[8,128] {
  %p0 = f32[1000,128]{1,0} parameter(0)
  %p1 = s32[8]{0} parameter(1)
  %cmp = pred[8]{0} compare(%p1, %p1), direction=LT
  ROOT %g = f32[8,128]{1,0} gather(%p0, %p1), offset_dims={1}
}

%loop_body (t: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
  %t = (s32[], f32[16,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[16,16]{1,0} get-tuple-element(%t), index=1
  %d = f32[16,16]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %r = (s32[], f32[16,16]{1,0}) tuple(%i2, %d)
}

%loop_cond (t: (s32[], f32[16,16])) -> pred[] {
  %t = (s32[], f32[16,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: f32[16,16], q: bf16[64,128], pool: f32[1000,128], idx: s32[8]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %q = bf16[64,128]{1,0} parameter(1)
  %pool = f32[1000,128]{1,0} parameter(2)
  %idx = s32[8]{0} parameter(3)
  %cast = f32[64,128]{1,0} fusion(%q), kind=kLoop, calls=%fused_convert
  %gat = f32[8,128]{1,0} fusion(%pool, %idx), kind=kLoop, calls=%fused_gather
  %zero = s32[] constant(0)
  %init = (s32[], f32[16,16]{1,0}) tuple(%zero, %p)
  %w = (s32[], f32[16,16]{1,0}) while(%init), condition=%loop_cond, body=%loop_body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[16,16]{1,0} get-tuple-element(%w), index=1
}
"""


class TestParser:
    def test_computations_and_ops(self):
        comps = parse_hlo(HLO)
        assert {"add_comp", "fused_convert", "fused_gather", "loop_body",
                "loop_cond", "main"} <= set(comps)
        assert any(o.kind == "while" for o in comps["main"].ops)

    def test_loop_flops_scaled_by_trip_count(self):
        res = analyse_hlo_text(HLO)
        # dot 16x16x16 = 2*16^3 = 8192 flops, x10 trips
        assert res["flops"] == pytest.approx(8192 * 10)

    def test_cast_fusion_free_gather_fusion_touched_bytes(self):
        m = HloCostModel(HLO)
        assert m._fusion_kind("fused_convert") == "cast"
        assert m._fusion_kind("fused_gather") == "gather"
        main = m.comps["main"]
        cast_op = next(o for o in main.ops if o.name.startswith("cast"))
        gat_op = next(o for o in main.ops if o.name.startswith("gat"))
        assert m._op_bytes(cast_op, main) == 0.0
        # 2 x result (8x128xf32), NOT the 1000x128 pool
        assert m._op_bytes(gat_op, main) == 2 * 8 * 128 * 4

    def test_real_compiled_module_parses(self):
        @jax.jit
        def f(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y

        x = jnp.ones((8, 8), jnp.float32)
        compiled = f.lower(x, x).compile()
        res = analyse_hlo_text(compiled.as_text())
        # 7 iterations x 2*8^3 flops
        assert res["flops"] == pytest.approx(7 * 2 * 8**3, rel=0.01)


class TestCollectivesHelpers:
    def test_int8_psum_single_device_identity_scale(self):
        # axis size 1: quantize/dequantize round trip within int8 precision
        from repro.distributed.collectives import int8_psum
        import jax
        mesh = jax.make_mesh((1,), ("d",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = jnp.linspace(-3, 3, 64)

        def f(x):
            return int8_psum(x, "d")

        got = jax.shard_map(f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
                            out_specs=jax.sharding.PartitionSpec(),
                            axis_names={"d"}, check_vma=False)(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x),
                                   atol=3.0 / 127 + 1e-6)

    def test_compressed_psum_small_tensors_stay_exact(self):
        from repro.distributed.collectives import compressed_psum
        import jax
        mesh = jax.make_mesh((1,), ("d",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = jnp.arange(16, dtype=jnp.float32)   # < 4096 elements => f32 path

        def f(x):
            return compressed_psum(x, ("d",), mode="int8")

        got = jax.shard_map(f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
                            out_specs=jax.sharding.PartitionSpec(),
                            axis_names={"d"}, check_vma=False)(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
